"""Decision-plane benchmarks: interaction models, prefetch gating, horizon.

Three synthetic interaction-trace families stress the predictors the way
real notebook users do:

* **loops** — stable execution cycles (the paper's Fig. 4 regime): every
  predictor should converge to near-perfect next-cell accuracy.
* **drift** — the user's loop structure *changes* mid-session (same cells,
  different successor order).  A pure Algorithm-1 frequency miner
  fossilizes on the old regime; Markov contexts and decayed recency adapt.
* **jumps** — a stable loop with random exploratory jumps: measures how
  gracefully predictors degrade under noise (and how much wasted prefetch
  an ungated speculator pays).

Four measurements, written to ``BENCH_context.json``:

1. predictor-accuracy sweep (model x trace, online top-1 next-cell);
2. FrequencyModel scaling: incremental per-event update+query cost vs the
   legacy per-query ``sequence_stats`` rescan, at 250 vs 1000 events;
3. confidence-gated vs always-on speculative prefetch: hit-rate and wasted
   bytes at equal prediction quality;
4. modeled wall-clock vs an oracle predictor (a correct next-hop
   prediction overlaps the next transfer with the current execution).
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.context import sequence_stats
from repro.core.interaction import (
    ConfidenceGate, EnsembleModel, FrequencyModel, InteractionModel,
    MarkovModel, RecencyModel,
)

PREFETCH_BYTES = 4 << 20          # modeled bytes per speculative prefetch


# ----------------------------------------------------------------------
# trace generators (deterministic)
# ----------------------------------------------------------------------

def loops_trace(n: int = 1000) -> list[int]:
    """Stable cycles over 8 cells with a full 15-cell pass every 5 cycles."""
    order: list[int] = []
    cycle = 0
    while len(order) < n:
        cycle += 1
        if cycle % 5 == 0:
            order += list(range(15))
        else:
            order += list(range(8))
    return order[:n]

def drift_trace(n: int = 1000) -> list[int]:
    """Same four cells, but the successor structure flips a third in: the
    user's tweak loop 0-1-2-3 becomes 0-3-1-2 (three of four transitions
    change)."""
    order: list[int] = []
    phase1 = [0, 1, 2, 3]
    phase2 = [0, 3, 1, 2]
    while len(order) < n // 3:
        order += phase1
    while len(order) < n:
        order += phase2
    return order[:n]

def jumps_trace(n: int = 1000, seed: int = 7) -> list[int]:
    """A stable 6-cell loop with 15% exploratory jumps over 12 cells."""
    rng = np.random.default_rng(seed)
    order: list[int] = []
    pos = 0
    for _ in range(n):
        if rng.random() < 0.15:
            pos = int(rng.integers(0, 12))
        else:
            pos = (pos + 1) % 6 if pos < 6 else 0
        order.append(pos)
    return order

TRACE_MAKERS = {"loops": loops_trace, "drift": drift_trace,
                "jumps": jumps_trace}

MODEL_MAKERS = {
    "frequency": FrequencyModel,
    "markov": MarkovModel,
    "recency": RecencyModel,
    "ensemble": EnsembleModel,
}


# ----------------------------------------------------------------------
# 1. accuracy sweep
# ----------------------------------------------------------------------

def online_accuracy(model: InteractionModel, orders: list[int]) -> float:
    """Online top-1 next-cell accuracy, with the runtime's query timing:
    when a cell is about to run (and is not yet in the history), predict
    its successor; score that prediction against the next event."""
    hits = total = 0
    pending: int | None = None
    first = True
    for o in orders:
        if not first:
            total += 1                       # abstaining counts as a miss
            hits += int(pending == o)
        first = False
        pending = model.predict_next("t", o)
        model.observe("t", o)
    return hits / max(total, 1)


# ----------------------------------------------------------------------
# 2. incremental-vs-rescan scaling
# ----------------------------------------------------------------------

def _per_event_seconds_incremental(orders: list[int]) -> float:
    m = FrequencyModel()
    t0 = time.perf_counter()
    for o in orders:
        m.predict_block_scored("t", o)
        m.observe("t", o)
    return (time.perf_counter() - t0) / len(orders)

def _per_event_seconds_legacy(orders: list[int]) -> float:
    """The original detector: a full sequence_stats rescan per query."""
    hist: list[int] = []
    t0 = time.perf_counter()
    for o in orders:
        stats = sequence_stats(hist, o)
        if stats:
            max(stats.items(), key=lambda kv: (kv[1], len(kv[0])))
        hist.append(o)
    return (time.perf_counter() - t0) / len(orders)

def scaling_report() -> dict:
    out: dict = {"events": [250, 1000], "incremental_us": [],
                 "legacy_rescan_us": []}
    for n in out["events"]:
        tr = loops_trace(n)
        out["incremental_us"].append(_per_event_seconds_incremental(tr) * 1e6)
        out["legacy_rescan_us"].append(_per_event_seconds_legacy(tr) * 1e6)
    inc, leg = out["incremental_us"], out["legacy_rescan_us"]
    # amortized O(1): per-event cost roughly flat as history 4x's, while
    # the rescan's grows with the history length
    out["incremental_growth_250_to_1000"] = inc[1] / max(inc[0], 1e-12)
    out["legacy_growth_250_to_1000"] = leg[1] / max(leg[0], 1e-12)
    out["speedup_vs_legacy_at_1000"] = leg[1] / max(inc[1], 1e-12)
    return out


# ----------------------------------------------------------------------
# 3. confidence-gated vs always-on speculative prefetch
# ----------------------------------------------------------------------

def prefetch_sim(orders: list[int], gated: bool) -> dict:
    model = MarkovModel()
    gate = ConfidenceGate() if gated else None
    issued = hits = 0
    wasted = useful = 0
    pending: tuple[int, float] | None = None
    for o in orders:
        if pending is not None:
            pred, _prob = pending
            issued += 1
            if pred == o:
                hits += 1
                useful += PREFETCH_BYTES
            else:
                wasted += PREFETCH_BYTES
            if gate is not None:
                gate.observe(pred == o)
        # the cell `o` is about to run: speculate on its successor
        pending = None
        dist = model.distribution("t", o)
        if dist:
            pred, prob = max(dist.items(), key=lambda kv: (kv[1], -kv[0]))
            if gate is None or gate.allow(prob):
                pending = (pred, prob)
        model.observe("t", o)
    return {"issued": issued, "hits": hits,
            "hit_rate": hits / max(issued, 1),
            "wasted_bytes": wasted, "useful_bytes": useful,
            "final_threshold": gate.threshold if gate else None}


# ----------------------------------------------------------------------
# 4. modeled wall-clock vs oracle
# ----------------------------------------------------------------------

def wallclock(orders: list[int], model: InteractionModel | None,
              exec_s: float = 1.0, mig_s: float = 0.8) -> float:
    """Every step executes for ``exec_s`` and needs its state staged for
    ``mig_s``; a correct next-hop prediction overlaps the staging with the
    previous execution (charge ``max(0, mig - exec)``), a miss pays it
    synchronously.  ``model=None`` is the oracle (always right)."""
    total = 0.0
    pending: int | None = None
    first = True
    for o in orders:
        if not first:
            predicted = o if model is None else pending
            total += max(0.0, mig_s - exec_s) if predicted == o else mig_s
        first = False
        if model is not None:
            pending = model.predict_next("t", o)
            model.observe("t", o)
        total += exec_s
    return total


# ----------------------------------------------------------------------
# harness entry
# ----------------------------------------------------------------------

def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    n = 300 if smoke else 1000
    report: dict = {"trace_events": n, "accuracy": {}, "scaling": {},
                    "prefetch_gate": {}, "wallclock": {}, "criteria": {}}

    # 1. accuracy sweep ------------------------------------------------
    traces = {name: mk(n) for name, mk in TRACE_MAKERS.items()}
    for tname, orders in traces.items():
        report["accuracy"][tname] = {}
        for mname, mk in MODEL_MAKERS.items():
            acc = online_accuracy(mk(), orders)
            report["accuracy"][tname][mname] = acc
            rows.append((f"context/accuracy/{tname}/{mname}", acc,
                         "online top-1 next-cell"))

    # 2. scaling (always the full 1k row — it IS the acceptance evidence)
    sc = scaling_report()
    report["scaling"] = sc
    rows.append(("context/scaling/incremental_us_at_1000",
                 sc["incremental_us"][1], "per-event, 1k-event history"))
    rows.append(("context/scaling/legacy_rescan_us_at_1000",
                 sc["legacy_rescan_us"][1], "per-event, 1k-event history"))
    rows.append(("context/scaling/speedup_vs_legacy_at_1000",
                 sc["speedup_vs_legacy_at_1000"],
                 "incremental Algorithm 1 vs per-query rescan"))
    rows.append(("context/scaling/incremental_growth_250_to_1000",
                 sc["incremental_growth_250_to_1000"],
                 "~1 = amortized O(1) per event"))

    # 3. prefetch gate --------------------------------------------------
    noisy = traces["drift"] + traces["jumps"]
    always = prefetch_sim(noisy, gated=False)
    gated = prefetch_sim(noisy, gated=True)
    report["prefetch_gate"] = {"always": always, "gated": gated}
    rows.append(("context/prefetch/always/hit_rate", always["hit_rate"], ""))
    rows.append(("context/prefetch/gated/hit_rate", gated["hit_rate"], ""))
    rows.append(("context/prefetch/always/wasted_mb",
                 always["wasted_bytes"] / 1e6, ""))
    rows.append(("context/prefetch/gated/wasted_mb",
                 gated["wasted_bytes"] / 1e6,
                 "gate skips low-confidence speculation"))

    # 4. wall-clock vs oracle ------------------------------------------
    for tname in ("loops", "drift"):
        orders = traces[tname]
        oracle = wallclock(orders, None)
        report["wallclock"][tname] = {"oracle": oracle}
        for mname, mk in MODEL_MAKERS.items():
            wc = wallclock(orders, mk())
            report["wallclock"][tname][mname] = wc
            rows.append((f"context/wallclock/{tname}/{mname}_vs_oracle",
                         wc / oracle, "1.0 = perfect prefetch overlap"))

    # acceptance criteria ----------------------------------------------
    acc_d = report["accuracy"]["drift"]
    crit = {
        "markov_beats_frequency_on_drift":
            acc_d["markov"] > acc_d["frequency"],
        "ensemble_beats_frequency_on_drift":
            acc_d["ensemble"] > acc_d["frequency"],
        "gate_cuts_wasted_bytes":
            gated["wasted_bytes"] < always["wasted_bytes"],
        "gate_hit_rate_no_worse":
            gated["hit_rate"] >= always["hit_rate"],
        "incremental_amortized_o1":
            sc["incremental_growth_250_to_1000"] < 3.0,
    }
    report["criteria"] = crit
    for k, v in crit.items():
        rows.append((f"context/criteria/{k}", float(v), "must be 1"))

    with open("BENCH_context.json", "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val},{note}")
