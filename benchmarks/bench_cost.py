"""Cost-plane benchmarks: the price-aware horizon DP vs the seconds-only
DP on priced fleets, producing a cost-vs-makespan frontier.

Five sweeps (results also land in ``BENCH_cost.json``):

* **frontier** — the GPU-heavy training workload on three fleets
  (*static*: home + on-demand GPU; *autoscaled*: + an elastic burst GPU;
  *spot*: + a cheap preemptible GPU with a seeded hazard), each run under
  both objectives with the same per-cell latency SLO.  The claim: on the
  spot fleet the dollars DP lands on the cheap preemptible pool and pays
  strictly fewer dollars at equal-or-better SLO attainment, because the
  hazard-weighted recovery surcharge prices preemptions instead of
  ignoring them.
* **data gravity** — the remote-sensing pipeline on a fabric where the
  near-data env is slightly slower but egress out of the fast far region
  is priced per-GB (asymmetrically).  The dollars DP keeps compute at the
  data and pays zero egress; the seconds DP chases the fastest env.
* **degenerate** — zero prices, zero hazards, symmetric links: the
  dollars objective reproduces the seconds objective's schedule exactly,
  and the fig5/fig11 decision sweeps still match the committed goldens
  bit-for-bit (the cost plane must not perturb the seed DP).
* **determinism** — the spot arm twice with the same seed: identical
  ScheduleReports (preemption draws are seeded substreams).
"""
from __future__ import annotations

import json
import os

from repro.core import (
    AutoscalePolicy, EnvironmentRegistry, ExecutionEnvironment,
    SessionScheduler, gpu_training_notebook, remote_sensing_notebook,
)

SEED = 2            # hazard substream: realizes preemptions inside the run
SLO = 30.0          # per-cell latency bound: forces training off home
GRAVITY_SLO = 12.0  # forces the remote-sensing bands off home too


def make_registry(fleet: str) -> EnvironmentRegistry:
    """*static*: home + an on-demand GPU at $3/h.  *autoscaled*: + an
    elastic burst GPU the AutoscalePolicy may provision/cull.  *spot*:
    + a preemptible GPU — slightly slower, $0.9/h, with a hazard."""
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.3)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment(
        "ondemand-gpu", speedup=10.0, price_per_hour=3.0), capacity=2)
    if fleet == "autoscaled":
        reg.register(ExecutionEnvironment(
            "gpu-burst", speedup=10.0, price_per_hour=3.0, status="down",
            cold_start=6.0, idle_timeout=12.0), capacity=2)
    if fleet == "spot":
        reg.register(ExecutionEnvironment(
            "spot-gpu", speedup=8.0, price_per_hour=0.9,
            hazard_rate=120.0 / 3600.0), capacity=4)
    return reg


def run_fleet(fleet: str, objective: str, n_sessions: int):
    sched = SessionScheduler(make_registry(fleet))
    sched.enable_recovery("checkpoint", interval=15.0)
    if fleet == "autoscaled":
        sched.enable_autoscale(AutoscalePolicy(
            ["gpu-burst"], check_interval=4.0, scale_up_wait=1.0))
    for i in range(n_sessions):
        sched.add_notebook(
            gpu_training_notebook(f"gpu-{fleet}-{objective}-{i}"),
            policy="horizon", use_knowledge=False,
            objective=objective, slo=SLO)
    if fleet == "spot":
        sched.enable_spot_hazards(seed=SEED, recover_after=10.0)
    return sched.run()


def frontier(rows, out, n_sessions: int) -> None:
    for fleet in ("static", "autoscaled", "spot"):
        arms = {}
        for objective in ("seconds", "dollars"):
            rep = run_fleet(fleet, objective, n_sessions)
            arms[objective] = rep
            rows.append((f"cost/{fleet}/{objective}/dollars",
                         rep.total_dollars,
                         f"compute {rep.compute_dollars:.4f} + egress "
                         f"{rep.egress_dollars:.4f}"))
            rows.append((f"cost/{fleet}/{objective}/makespan",
                         rep.makespan, f"{rep.preemptions} preemptions"))
            rows.append((f"cost/{fleet}/{objective}/slo_attainment",
                         rep.slo_attainment, f"SLO {SLO:g}s per cell"))
            out["frontier"][fleet][objective] = {
                "dollars": rep.total_dollars,
                "compute_dollars": rep.compute_dollars,
                "egress_dollars": rep.egress_dollars,
                "makespan": rep.makespan,
                "queue_wait": rep.total_queue_wait,
                "slo_attainment": rep.slo_attainment,
                "preemptions": rep.preemptions,
                "recoveries": rep.recoveries,
            }
        sec, dol = arms["seconds"], arms["dollars"]
        ratio = dol.total_dollars / max(sec.total_dollars, 1e-12)
        delta = dol.slo_attainment - sec.slo_attainment
        rows.append((f"cost/{fleet}/dollars_ratio", ratio,
                     "dollars DP vs seconds DP; <1 = price-aware wins"))
        rows.append((f"cost/{fleet}/slo_attainment_delta", delta,
                     ">=0 = no SLO paid for the savings"))
        out["frontier"][fleet]["dollars_ratio"] = ratio
        out["frontier"][fleet]["slo_attainment_delta"] = delta


# ----------------------------------------------------------------------
def make_gravity_registry() -> EnvironmentRegistry:
    """Data gravity: ``near-data`` sits next to the scene archive (free
    in-region transfers, 6x); ``far-gpu`` is faster (8x) but in another
    region — per-GB egress is priced on every link crossing the boundary,
    and asymmetrically (shipping results back out of the far region costs
    double)."""
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.3)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment(
        "near-data", speedup=6.0, price_per_hour=1.0), capacity=4)
    reg.register(ExecutionEnvironment(
        "far-gpu", speedup=8.0, price_per_hour=3.0), capacity=4)
    for src in ("local", "near-data"):
        reg.set_egress(src, "far-gpu", 40.0)
        reg.set_egress("far-gpu", src, 80.0)
    return reg


def data_gravity(rows, out, scenes: int) -> None:
    arms = {}
    for objective in ("seconds", "dollars"):
        sched = SessionScheduler(make_gravity_registry())
        rt = sched.add_notebook(
            remote_sensing_notebook(f"rs-{objective}", scenes=scenes),
            policy="horizon", use_knowledge=False,
            objective=objective, slo=GRAVITY_SLO)
        rep = sched.run()
        heavy = {e: s for e, s in rt.exec_env_seconds.items()
                 if e != "local"}
        arms[objective] = {
            "dollars": rep.total_dollars,
            "compute_dollars": rep.compute_dollars,
            "egress_dollars": rep.egress_dollars,
            "makespan": rep.makespan,
            "slo_attainment": rep.slo_attainment,
            "env_seconds": dict(rt.exec_env_seconds),
            "compute_at_data": float(
                heavy.get("near-data", 0.0) > 0.0
                and heavy.get("far-gpu", 0.0) == 0.0),
        }
        rows.append((f"cost/gravity/{objective}/dollars",
                     rep.total_dollars,
                     f"egress {rep.egress_dollars:.4f}"))
    rows.append(("cost/gravity/compute_at_data",
                 arms["dollars"]["compute_at_data"],
                 "dollars DP keeps the bands next to the scene archive"))
    rows.append(("cost/gravity/dollars/egress_dollars",
                 arms["dollars"]["egress_dollars"],
                 "must stay zero: no priced boundary crossed"))
    rows.append(("cost/gravity/dollars_ratio",
                 arms["dollars"]["dollars"]
                 / max(arms["seconds"]["dollars"], 1e-12),
                 "<1 = staying at the data beats chasing the fast region"))
    out["gravity"] = {
        "seconds": arms["seconds"], "dollars": arms["dollars"],
        "compute_at_data": arms["dollars"]["compute_at_data"],
        "dollars_ratio": arms["dollars"]["dollars"]
        / max(arms["seconds"]["dollars"], 1e-12),
    }


# ----------------------------------------------------------------------
def degenerate(rows, out, n_sessions: int) -> None:
    """Zero prices, zero hazards, symmetric free links: the dollars DP is
    the seconds DP.  Two halves: (a) same fleet under both objectives
    produces the identical schedule; (b) the committed fig5/fig11 decision
    goldens still reproduce bit-for-bit."""
    def run_plain(objective: str):
        reg = EnvironmentRegistry(default_bandwidth=2e8,
                                  default_latency=0.3)
        reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
        reg.register(ExecutionEnvironment("remote", speedup=10.0),
                     capacity=4)
        sched = SessionScheduler(reg)
        for i in range(n_sessions):
            sched.add_notebook(gpu_training_notebook(f"deg-{i}"),
                               policy="horizon", use_knowledge=False,
                               objective=objective)
        return sched.run()

    a, b = run_plain("seconds"), run_plain("dollars")
    schedule_identical = (
        a.makespan == b.makespan
        and a.actual_env_seconds == b.actual_env_seconds
        and [s.makespan for s in a.sessions]
        == [s.makespan for s in b.sessions]
        and b.total_dollars == 0.0)
    rows.append(("cost/degenerate/schedule_identical",
                 float(schedule_identical),
                 "unpriced fleet: dollars DP == seconds DP"))
    out["degenerate"] = {
        "schedule_identical": float(schedule_identical)}

    from benchmarks import fig5_fig6_policy_speedups, fig11_knowledge_policy
    golden_path = os.path.join(os.path.dirname(__file__), os.pardir,
                               "tests", "data",
                               "fig_decisions_golden.json")
    with open(golden_path) as f:
        golden = json.load(f)
    fresh5 = [[n, v, d]
              for n, v, d in fig5_fig6_policy_speedups.run(smoke=True)]
    fresh11 = [[n, v, d]
               for n, v, d in fig11_knowledge_policy.run(smoke=True)]
    bit_identical = (fresh5 == golden["fig5_fig6"]
                     and fresh11 == golden["fig11"])
    rows.append(("cost/degenerate/bit_identical", float(bit_identical),
                 "fig5/fig11 decision goldens reproduce bit-for-bit"))
    out["degenerate"]["bit_identical"] = float(bit_identical)


# ----------------------------------------------------------------------
def determinism(rows, out, n_sessions: int) -> None:
    a = run_fleet("spot", "dollars", n_sessions)
    b = run_fleet("spot", "dollars", n_sessions)
    identical = a == b
    rows.append(("cost/deterministic_replay", float(identical),
                 "same seed => identical preemptions and dollars"))
    out["deterministic_replay"] = float(identical)


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    out: dict = {"frontier": {f: {} for f in
                              ("static", "autoscaled", "spot")}}
    n = 2 if smoke else 4
    frontier(rows, out, n_sessions=n)
    data_gravity(rows, out, scenes=3 if smoke else 6)
    degenerate(rows, out, n_sessions=n)
    determinism(rows, out, n_sessions=n)
    with open("BENCH_cost.json", "w") as f:
        json.dump(out, f, indent=2)
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val},{note}")
