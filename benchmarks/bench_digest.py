"""Digest/delta-plane benchmark: batched single-launch digesting and the
zero-copy wire path against their per-array / copying predecessors.

Four sections, one synthetic namespace (~1 GiB full run, ~32 MiB smoke;
many ragged leaves — the ElasticNotebook-style shape where per-leaf launch
and sync overhead dominates):

- ``digest``  — whole-manifest digesting: per-leaf ``tensor_digest`` (one
  kernel launch + one host round-trip per leaf, what the reducer did
  before) vs ``digest_leaves`` (every leaf packed into one block grid, ONE
  launch, ONE sync).  Reports GB/s, the measured host-sync counts, and a
  bit-identity flag — the batched digests must equal the per-leaf digests
  exactly, or fig5/fig11 decisions and CAS chunk keys would drift.
- ``delta``   — the fused digest->compare->gather path
  (``digest_leaves_delta``): mutate ~1%% of leaves, compare against the
  prior manifest on device, and check the changed-index list is exact.
- ``chunk``   — ``array_chunk_digests_many`` vs per-payload
  ``array_chunk_digests`` on raw buffers (the serialize hot path), plus a
  prior-reuse pass over an almost-unchanged capture (the fused compare
  kernel lets unchanged chunks skip their host blake2b fold).
- ``wire``    — CHUNK-frame encode/decode GB/s: scatter-gather segments +
  view-slicing decoder vs the old join-everything/copy-everything path.
  The decoder must hand back payload *views* into the fed buffer.

Deterministic metrics (sync counts, bit-identity flags) gate tightly in
``benchmarks/baselines/tolerances.json``; throughputs gate with generous
tolerances (machines vary), and speedups are reported for the record.
"""
from __future__ import annotations

import json
import time

import numpy as np

# leaf element counts (float32), mostly small with a few ragged sizes and
# a modest tail — the notebook-realistic profile (many variables, few
# giants) where per-leaf launch+sync overhead is the cost the batched
# path exists to eliminate.
_LEAF_SIZES = (1_024, 1_024, 1_000, 1_024, 2_048, 1_024, 1_024, 3_072,
               1_024, 1_024, 2_048, 1_024, 1_024, 1_024, 4_096, 8_192)


def _namespace(smoke: bool) -> list[np.ndarray]:
    total = (32 << 20) if smoke else (1 << 30)
    rng = np.random.default_rng(0xD161)
    leaves: list[np.ndarray] = []
    acc = 0
    i = 0
    while acc < total:
        n = _LEAF_SIZES[i % len(_LEAF_SIZES)]
        leaves.append(rng.random(n, dtype=np.float32))
        acc += n * 4
        i += 1
    return leaves


def _gbps(nbytes: int, seconds: float) -> float:
    return round(nbytes / max(seconds, 1e-9) / 1e9, 3)


def _timed(fn, reps: int = 2):
    """min-of-``reps`` wall time (noise shield) + the last result.

    Host-sync counters are reset per rep, so ``ops.HOST_SYNCS`` afterwards
    reflects a single pass."""
    from repro.kernels.hash_delta import ops

    best, out = float("inf"), None
    for _ in range(reps):
        ops.reset_host_syncs()
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_manifest_digest(leaves, *, smoke: bool) -> tuple[dict, list[int]]:
    from repro.kernels.hash_delta import ops

    nbytes = sum(a.nbytes for a in leaves)
    # warm both jit caches so neither path pays compile time in the
    # measured pass (per-leaf compiles once per distinct shape, and the
    # size table cycles, so one cycle covers every shape)
    for a in leaves[:len(_LEAF_SIZES)]:
        ops.tensor_digest(a, impl="xla")
    ops.digest_leaves(leaves, impl="xla")

    t_per, per_leaf = _timed(
        lambda: [ops.tensor_digest(a, impl="xla") for a in leaves])
    syncs_per = ops.HOST_SYNCS

    t_bat, batched = _timed(lambda: ops.digest_leaves(leaves, impl="xla"))
    syncs_bat = ops.HOST_SYNCS

    return {
        "namespace_bytes": nbytes,
        "leaves": len(leaves),
        "per_leaf": {"wall_seconds": round(t_per, 4),
                     "gbps": _gbps(nbytes, t_per),
                     "host_syncs": syncs_per},
        "batched": {"wall_seconds": round(t_bat, 4),
                    "gbps": _gbps(nbytes, t_bat),
                    "host_syncs": syncs_bat},
        "speedup": round(t_per / max(t_bat, 1e-9), 2),
        "bit_identical": int(per_leaf == batched),
    }, per_leaf


def bench_delta(leaves, prior, *, smoke: bool) -> dict:
    from repro.kernels.hash_delta import ops

    mutated = list(leaves)
    expect = sorted(range(0, len(leaves), 97))   # ~1% of leaves change
    for j in expect:
        mutated[j] = mutated[j].copy()
        mutated[j][0] += 1.0
    ops.digest_leaves_delta(mutated, prior, impl="xla")   # warm
    t, (digests, changed) = _timed(
        lambda: ops.digest_leaves_delta(mutated, prior, impl="xla"))
    nbytes = sum(a.nbytes for a in leaves)
    return {
        "wall_seconds": round(t, 4),
        "gbps": _gbps(nbytes, t),
        "host_syncs": ops.HOST_SYNCS,
        "changed_expected": len(expect),
        "changed_found": len(changed),
        "exact": int(changed == expect
                     and all(digests[j] == prior[j]
                             for j in range(len(prior))
                             if j not in set(expect))),
    }


def bench_chunk_digests(*, smoke: bool) -> dict:
    from repro.core.chunkstore import (
        array_chunk_digests, array_chunk_digests_many,
    )
    from repro.kernels.hash_delta import ops

    total = (8 << 20) if smoke else (256 << 20)
    cb = 8 << 10     # small chunks so multi-chunk payloads are exercised
    rng = np.random.default_rng(0xCA5)
    payloads, acc = [], 0
    while acc < total:
        # mostly small serialized arrays, some multi-chunk, sizes ragged
        n = (4_352 if len(payloads) % 3 else (32 << 10))
        payloads.append(rng.integers(0, 256, n, dtype=np.uint8).tobytes())
        acc += n
    array_chunk_digests_many(payloads[:2], cb)            # warm
    array_chunk_digests(payloads[0], cb)

    t_per, per = _timed(
        lambda: [array_chunk_digests(p, cb) for p in payloads])
    syncs_per = ops.HOST_SYNCS

    t_many, (many, h64s) = _timed(
        lambda: array_chunk_digests_many(payloads, cb))
    syncs_many = ops.HOST_SYNCS

    # prior-reuse pass: one payload mutated, the rest reuse their prior
    # chunk digests via the fused on-device compare
    priors = [(h, d, len(p)) for h, d, p in zip(h64s, many, payloads)]
    mutated = list(payloads)
    mutated[0] = b"\xff" + mutated[0][1:]
    t_reuse, (again, _h) = _timed(
        lambda: array_chunk_digests_many(mutated, cb, priors=priors))
    fresh = [array_chunk_digests(p, cb) for p in mutated]

    return {
        "payload_bytes": acc,
        "payloads": len(payloads),
        "per_payload": {"wall_seconds": round(t_per, 4),
                        "gbps": _gbps(acc, t_per),
                        "host_syncs": syncs_per},
        "batched": {"wall_seconds": round(t_many, 4),
                    "gbps": _gbps(acc, t_many),
                    "host_syncs": syncs_many},
        "reuse_wall_seconds": round(t_reuse, 4),
        "speedup": round(t_per / max(t_many, 1e-9), 2),
        "bit_identical": int(per == many and again == fresh),
    }


def bench_wire(*, smoke: bool) -> dict:
    from repro.core import wire

    chunk_len = 256 << 10
    total = (16 << 20) if smoke else (256 << 20)
    nframes = total // chunk_len
    payload = np.random.default_rng(7).integers(
        0, 256, chunk_len, dtype=np.uint8).tobytes()
    digests = list(range(nframes))

    # --- encode: old join-everything vs scatter-gather segments ---------
    import struct
    t0 = time.perf_counter()
    legacy = [wire.encode_frame(wire.CHUNK,
                                struct.pack("<Q", d) + payload)
              for d in digests]
    t_copy = time.perf_counter() - t0
    t0 = time.perf_counter()
    seg_lists = [wire.chunk_frame(d, payload).segments() for d in digests]
    t_zero = time.perf_counter() - t0

    # --- decode: payload views vs forced materialization ----------------
    buf = b"".join(legacy)
    dec = wire.FrameDecoder()
    dec.feed(buf)
    t0 = time.perf_counter()
    copied = [bytes(f.payload) for f in dec.frames()]   # the old contract
    t_dcopy = time.perf_counter() - t0
    dec2 = wire.FrameDecoder()
    dec2.feed(buf)
    t0 = time.perf_counter()
    frames = list(dec2.frames())
    t_dzero = time.perf_counter() - t0
    views_ok = (len(frames) == nframes == len(copied)
                and all(isinstance(f.payload, memoryview)
                        for f in frames))
    # scatter-gather bytes must equal the joined-encode bytes exactly
    wire_ok = all(b"".join(bytes(s) for s in segs) == enc
                  for segs, enc in zip(seg_lists[:8], legacy[:8]))

    return {
        "frame_bytes": len(buf),
        "frames": nframes,
        "encode": {"copying_gbps": _gbps(len(buf), t_copy),
                   "zero_copy_gbps": _gbps(len(buf), t_zero),
                   "ratio": round(t_copy / max(t_zero, 1e-9), 2)},
        "decode": {"copying_gbps": _gbps(len(buf), t_dcopy),
                   "zero_copy_gbps": _gbps(len(buf), t_dzero),
                   "ratio": round(t_dcopy / max(t_dzero, 1e-9), 2)},
        "payloads_are_views": int(views_ok),
        "bytes_identical": int(wire_ok),
    }


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    leaves = _namespace(smoke)
    digest, per_leaf = bench_manifest_digest(leaves, smoke=smoke)
    delta = bench_delta(leaves, per_leaf, smoke=smoke)
    chunk = bench_chunk_digests(smoke=smoke)
    wirep = bench_wire(smoke=smoke)
    report = {"digest": digest, "delta": delta, "chunk": chunk,
              "wire": wirep}
    with open("BENCH_digest.json", "w") as f:
        json.dump(report, f, indent=2)

    rows = [
        ("digest/namespace_gib",
         round(digest["namespace_bytes"] / 2**30, 3),
         f"{digest['leaves']} ragged leaves"),
        ("digest/per_leaf_gbps", digest["per_leaf"]["gbps"],
         f"{digest['per_leaf']['host_syncs']} host syncs (one per leaf)"),
        ("digest/batched_gbps", digest["batched"]["gbps"],
         f"{digest['batched']['host_syncs']} host sync, single launch"),
        ("digest/speedup", digest["speedup"],
         "batched vs per-leaf, same bytes"),
        ("digest/bit_identical", digest["bit_identical"],
         "batched digests == per-leaf digests"),
        ("delta/gbps", delta["gbps"],
         f"fused compare+gather, {delta['host_syncs']} host sync"),
        ("delta/exact", delta["exact"],
         f"{delta['changed_found']}/{delta['changed_expected']} changed"),
        ("chunk/batched_gbps", chunk["batched"]["gbps"],
         f"{chunk['payloads']} payloads, one launch"),
        ("chunk/speedup", chunk["speedup"], "vs per-payload digesting"),
        ("chunk/bit_identical", chunk["bit_identical"],
         "CAS chunk keys unchanged"),
        ("wire/encode_zero_copy_gbps", wirep["encode"]["zero_copy_gbps"],
         f"{wirep['encode']['ratio']}x vs joining copy"),
        ("wire/decode_zero_copy_gbps", wirep["decode"]["zero_copy_gbps"],
         f"{wirep['decode']['ratio']}x vs materializing copy"),
        ("wire/payloads_are_views", wirep["payloads_are_views"],
         "decoder slices, never copies"),
    ]
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val},{note}")
