"""Fabric benchmarks (beyond the paper): N-environment placement, pipelined
migration, and multi-session scheduling.

Three sweeps:

* **env-count** — the same notebook under the cost-matrix policy on 2/3/4-env
  registries (cpu-local, gpu-cloud, tpu-mesh, storage).  Reports modeled
  time and where the heavy cell landed: with the third env present the
  heavy cell moves to tpu-mesh and total time drops.
* **pipelined vs synchronous** — a block-policy workload run under both
  engines with identical per-pair links and stage bandwidths; the pipelined
  engine overlaps transfer with execution (prefetch) and chunks the
  serialize/compress/transfer stages, so end-to-end modeled time is lower.
* **session-count** — k concurrent sessions multiplexed by the
  SessionScheduler over a shared fabric with per-env capacity; reports
  makespan, queue waits and accelerator utilization.
"""
from __future__ import annotations

from repro.core import (
    EnvironmentRegistry, ExecutionEnvironment, HybridRuntime,
    MigrationEngine, Notebook, PipelinedMigrationEngine, SessionScheduler,
    StateReducer,
)
from repro.core import telemetry as T


def make_registry(n_envs: int) -> EnvironmentRegistry:
    """2..4 heterogeneous envs with per-pair link costs."""
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.5)
    reg.register(ExecutionEnvironment("cpu-local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment("gpu-cloud", speedup=8.0), capacity=2)
    reg.connect("cpu-local", "gpu-cloud", bandwidth=5e8, latency=0.3)
    if n_envs >= 3:
        reg.register(ExecutionEnvironment("tpu-mesh", speedup=40.0), capacity=1)
        reg.connect("cpu-local", "tpu-mesh", bandwidth=1e8, latency=1.0)
        reg.connect("gpu-cloud", "tpu-mesh", bandwidth=1e9, latency=0.2)
    if n_envs >= 4:
        reg.register(ExecutionEnvironment("storage", kind="storage"))
        reg.connect("cpu-local", "storage", bandwidth=4e8, latency=0.1)
    return reg


def make_notebook(tag: str = "") -> Notebook:
    """Load -> transform -> heavy train -> light report (the paper's shape)."""
    nb = Notebook(f"fabric-session{tag}")
    nb.add_cell("import numpy as np\n"
                "data = np.arange(1_000_000, dtype=np.float64)", cost=8.0)
    nb.add_cell("model = float(((data - data.mean()) ** 2).sum())", cost=90.0)
    nb.add_cell("report = model / len(data)", cost=0.2)
    return nb


def _run_sessions(rt: HybridRuntime, nb: Notebook, sessions: int) -> None:
    for _ in range(sessions):
        for i in range(len(nb.cells)):
            rt.run_cell(i)
    rt.close()


def _placements(rt: HybridRuntime, nb: Notebook) -> dict[str, str]:
    out = {}
    for m in rt.bus.messages():
        if m.type == T.CELL_EXECUTION_STARTED:
            out[m.cell_id] = m.payload["env"]
    return out


def env_count_sweep(rows, sessions: int) -> None:
    local_only = sessions * sum(c.cost for c in make_notebook().cells)
    for n in (2, 3, 4):
        nb = make_notebook()
        rt = HybridRuntime(nb, registry=make_registry(n), policy="cost",
                           use_knowledge=False)
        _run_sessions(rt, nb, sessions)
        heavy_env = _placements(rt, nb).get(nb.cells[1].cell_id, "?")
        rows.append((f"fabric/envs{n}/modeled_seconds", rt.clock.now(),
                     f"local-only {local_only:.0f}s"))
        rows.append((f"fabric/envs{n}/speedup_vs_local",
                     local_only / rt.clock.now(), ""))
        rows.append((f"fabric/envs{n}/heavy_cell_on_tpu",
                     float(heavy_env == "tpu-mesh"),
                     f"heavy cell ran on {heavy_env}"))
        rows.append((f"fabric/envs{n}/migrations", rt.migrations, ""))


def engine_comparison(rows, sessions: int) -> None:
    """Same block-policy workload, synchronous vs pipelined engine."""
    totals = {}
    for name, cls in (("sync", MigrationEngine),
                      ("pipelined", PipelinedMigrationEngine)):
        nb = make_notebook()
        reg = EnvironmentRegistry.two_env(remote_speedup=10.0,
                                          bandwidth=2e6, latency=0.5)
        eng = cls(StateReducer("none"), registry=reg,
                  serialize_bandwidth=8e6, compress_bandwidth=1.6e7)
        rt = HybridRuntime(nb, registry=reg, policy="block",
                           use_knowledge=False, engine=eng)
        _run_sessions(rt, nb, sessions)
        totals[name] = rt.clock.now()
        rows.append((f"fabric/engine_{name}/modeled_seconds", rt.clock.now(),
                     ""))
        if name == "pipelined":
            rows.append(("fabric/engine_pipelined/prefetch_hits",
                         eng.prefetch_hits, "transfers overlapped execution"))
    rows.append(("fabric/pipelined_speedup_vs_sync",
                 totals["sync"] / totals["pipelined"],
                 "block-policy workload; >1 = overlap pays"))


def session_sweep(rows, counts) -> None:
    for k in counts:
        reg = make_registry(3)
        sched = SessionScheduler(reg)
        for i in range(k):
            sched.add_notebook(make_notebook(f"-{i}"), policy="cost",
                               use_knowledge=False)
        rep = sched.run()
        rows.append((f"fabric/sessions{k}/makespan", rep.makespan, ""))
        rows.append((f"fabric/sessions{k}/total_queue_wait",
                     rep.total_queue_wait,
                     f"{rep.queue_events} queue events"))
        rows.append((f"fabric/sessions{k}/tpu_utilization",
                     rep.env_utilization.get("tpu-mesh", 0.0), ""))
        rows.append((f"fabric/sessions{k}/gpu_utilization",
                     rep.env_utilization.get("gpu-cloud", 0.0), ""))


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    sessions = 1 if smoke else 3
    env_count_sweep(rows, sessions)
    engine_comparison(rows, sessions)
    session_sweep(rows, (2,) if smoke else (2, 4, 8))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val},{note}")
