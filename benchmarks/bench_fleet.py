"""Fleet-plane benchmarks (beyond the paper): event-driven arrivals with
think-time, failure recovery through CAS checkpoints, autoscaling, and the
capacity arbiter's interval-pruning fix.

Four sweeps (results also land in ``BENCH_fleet.json``):

* **arrivals x autoscale** — Poisson session arrivals at several rates with
  exponential think-time, on a static fleet vs the same fleet plus a burst
  env the :class:`AutoscalePolicy` may provision (cold start) and cull
  (idle timeout).  Autoscaling absorbs the queue: total queue wait drops at
  equal-or-better utilization of the always-on accelerator.
* **failure recovery** — an env dies mid-heavy-cell; rerun-from-home
  replays the whole plan, checkpoint recovery restores the latest periodic
  CAS checkpoint and replays only the cells since it.  Checkpoint recovery
  wins on makespan.
* **arbiter pruning** — the O(intervals^2) full-history rescan in
  ``CapacityArbiter.acquire`` vs the pruned scan (intervals ending before
  the fleet's minimum session clock are dropped).
* **degenerate instance** — zero arrivals gap, zero think-time, no
  failures, static fleet: the event loop reproduces the pre-event-driven
  scheduler's report (the paper's setup is the smallest fleet).
"""
from __future__ import annotations

import json
import time

from repro.core import (
    AutoscalePolicy, CapacityArbiter, EnvironmentRegistry,
    ExecutionEnvironment, Notebook, SessionScheduler, WorkloadTrace,
)

ARRIVAL_RATES = (0.05, 0.1, 0.2)     # sessions per second
THINK_MEAN = 4.0
SEED = 20260731


def make_notebook(tag: str = "") -> Notebook:
    """Load -> heavy train block -> light report (the paper's shape)."""
    nb = Notebook(f"fleet-session{tag}")
    nb.add_cell("import numpy as np\n"
                "data = np.arange(400_000, dtype=np.float64)", cost=4.0)
    nb.add_cell("model = float(((data - data.mean()) ** 2).sum())", cost=80.0)
    nb.add_cell("model2 = model * 0.5 + float(data.std())", cost=80.0)
    nb.add_cell("report = model2 / len(data)", cost=0.3)
    return nb


def make_registry(*, burst: bool, always_up: bool = False) -> EnvironmentRegistry:
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.3)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment("gpu-cloud", speedup=10.0), capacity=1)
    if burst:
        reg.register(ExecutionEnvironment(
            "gpu-burst", speedup=10.0,
            status="up" if always_up else "down", cold_start=6.0,
            idle_timeout=12.0), capacity=1)
    return reg


# ----------------------------------------------------------------------
def _effective_utilization(sched, rep, gpu_envs) -> float:
    """Busy seconds / capacity-seconds *while up*: a burst env is only
    accountable for the window between its provision and its cull, so the
    metric compares static and elastic fleets fairly."""
    busy = sum(rep.actual_env_seconds.get(n, 0.0) for n in gpu_envs)
    denom = 0.0
    for name in gpu_envs:
        if name not in sched.registry:
            continue
        cap = sched.registry.capacity(name)
        ups = [t for t, env, _old, new in rep.lifecycle_events
               if env == name and new == "up"]
        downs = [t for t, env, _old, new in rep.lifecycle_events
                 if env == name and new in ("down", "failed")]
        if not ups and sched.registry[name].status == "up":
            denom += cap * rep.makespan           # up the whole run
            continue
        for i, t_up in enumerate(ups):
            t_down = min((t for t in downs if t > t_up),
                         default=rep.makespan)
            denom += cap * (min(t_down, rep.makespan) - t_up)
    return busy / denom if denom > 0 else 0.0


def arrivals_sweep(rows, out, n_sessions: int) -> None:
    """Three arms per arrival rate: *static* (one always-on gpu — tight but
    queues), *overprovisioned* (two always-on gpus — no queue, wasted
    capacity), *autoscale* (second gpu elastic: provisioned under queue
    pressure, culled when idle).  The claim: autoscaling gets (most of) the
    overprovisioned fleet's queue-wait reduction at equal-or-better
    utilization than the always-on fleet of the same peak capacity."""
    for rate in ARRIVAL_RATES:
        waits, utils, spans = {}, {}, {}
        for mode in ("static", "overprovisioned", "autoscale"):
            sched = SessionScheduler(make_registry(
                burst=(mode != "static"),
                always_up=(mode == "overprovisioned")))
            if mode == "autoscale":
                sched.enable_autoscale(AutoscalePolicy(
                    ["gpu-burst"], check_interval=4.0, scale_up_wait=1.0))
            for i in range(n_sessions):
                sched.add_notebook(make_notebook(f"-{rate}-{mode}-{i}"),
                                   policy="cost", use_knowledge=False)
            sched.set_workload(WorkloadTrace.poisson(
                n_sessions, rate=rate, think_mean=THINK_MEAN,
                cells_per_session=4, seed=SEED))
            rep = sched.run()
            waits[mode] = rep.total_queue_wait
            utils[mode] = _effective_utilization(
                sched, rep, ("gpu-cloud", "gpu-burst"))
            spans[mode] = rep.makespan
            rows.append((f"fleet/rate{rate}/{mode}/queue_wait",
                         rep.total_queue_wait,
                         f"{rep.queue_events} queue events"))
            rows.append((f"fleet/rate{rate}/{mode}/gpu_utilization",
                         utils[mode],
                         f"effective (while-up); "
                         f"{len(rep.scale_events)} scale events"))
        rows.append((f"fleet/rate{rate}/wait_reduction_vs_static",
                     (waits["static"] - waits["autoscale"])
                     / max(waits["static"], 1e-9),
                     "autoscale vs static; >0 = autoscaling pays"))
        rows.append((f"fleet/rate{rate}/util_gain_vs_overprovisioned",
                     utils["autoscale"] - utils["overprovisioned"],
                     "same peak capacity; >0 = elastic wastes less"))
        out["arrivals"].append({
            "rate": rate, "think_mean": THINK_MEAN,
            "queue_wait": dict(waits),
            "gpu_utilization": dict(utils),
            "makespan": dict(spans),
        })


# ----------------------------------------------------------------------
def failure_recovery(rows, out, fail_at: float) -> None:
    spans = {}
    for mode in ("rerun", "checkpoint"):
        sched = SessionScheduler(make_registry(burst=False))
        sched.enable_recovery(mode, interval=8.0)
        rt = sched.add_notebook(make_notebook(f"-fail-{mode}"),
                                policy="cost", use_knowledge=False,
                                think=[1.0] * 4)
        sched.inject_failure("gpu-cloud", at=fail_at, recover_after=10.0)
        rep = sched.run()
        spans[mode] = rep.makespan
        assert rep.recoveries >= 1, "failure must interrupt the block"
        assert rt.envs["local"].state.get("report") is not None
        rows.append((f"fleet/failure/{mode}/makespan", rep.makespan,
                     f"{rep.recoveries} recoveries, "
                     f"{rep.checkpoints} checkpoints"))
        out["failure"][mode] = {
            "makespan": rep.makespan, "recoveries": rep.recoveries,
            "checkpoints": rep.checkpoints,
            "checkpoint_bytes": rep.checkpoint_bytes,
            "restored_bytes": rep.restored_bytes,
        }
    rows.append(("fleet/failure/checkpoint_speedup_vs_rerun",
                 spans["rerun"] / spans["checkpoint"],
                 ">1 = restoring the CAS checkpoint beats rerun-from-home"))
    out["failure"]["checkpoint_speedup_vs_rerun"] = (
        spans["rerun"] / spans["checkpoint"])


# ----------------------------------------------------------------------
def arbiter_prune_bench(rows, out, n_intervals: int) -> None:
    """The O(history^2) rescan vs the pruned scan, same admission results."""

    def replay(prune: bool) -> float:
        reg = EnvironmentRegistry()
        reg.register(ExecutionEnvironment("local"), home=True, capacity=2)
        arb = CapacityArbiter(reg)
        t0 = time.perf_counter()
        now = 0.0
        for i in range(n_intervals):
            start = arb.acquire("local", now, 1.0)
            arb.release("local", start, start + 1.0)
            now = start + 0.5
            if prune and i % 64 == 0:
                arb.prune(now)
        return time.perf_counter() - t0

    unpruned = replay(False)
    pruned = replay(True)
    rows.append(("fleet/arbiter/unpruned_seconds", unpruned,
                 f"{n_intervals} acquire/release cycles"))
    rows.append(("fleet/arbiter/pruned_seconds", pruned, ""))
    rows.append(("fleet/arbiter/prune_speedup", unpruned / pruned,
                 "full-history rescan vs pruned scan"))
    out["arbiter"] = {"intervals": n_intervals, "unpruned_seconds": unpruned,
                      "pruned_seconds": pruned,
                      "speedup": unpruned / pruned}


# ----------------------------------------------------------------------
def determinism(rows, out) -> None:
    def run_once():
        sched = SessionScheduler(make_registry(burst=True))
        sched.enable_recovery("checkpoint", interval=8.0)
        sched.enable_autoscale(AutoscalePolicy(["gpu-burst"]))
        for i in range(3):
            sched.add_notebook(make_notebook(f"-det-{i}"), policy="cost",
                               use_knowledge=False)
        sched.set_workload(WorkloadTrace.poisson(
            3, rate=0.1, think_mean=THINK_MEAN, cells_per_session=4,
            seed=SEED))
        sched.inject_failure("gpu-cloud", at=20.0, recover_after=15.0)
        return sched.run()

    a, b = run_once(), run_once()
    identical = a == b
    rows.append(("fleet/deterministic_replay", float(identical),
                 "same trace + seed => identical ScheduleReport"))
    out["deterministic_replay"] = identical


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    out: dict = {"arrivals": [], "failure": {}}
    arrivals_sweep(rows, out, n_sessions=2 if smoke else 6)
    failure_recovery(rows, out, fail_at=14.0)
    arbiter_prune_bench(rows, out, n_intervals=256 if smoke else 4096)
    determinism(rows, out)
    with open("BENCH_fleet.json", "w") as f:
        json.dump(out, f, indent=2)
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val},{note}")
