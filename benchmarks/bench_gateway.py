"""Gateway-plane benchmarks: one persistent process holding a 10k-session
Poisson attach storm, warm-pool vs cold-provision attach latency, mux
byte-accounting equality, and the memoized horizon decision path.

Four sweeps (results also land in ``BENCH_gateway.json``):

* **storm** — the headline: a seeded Poisson storm of concurrent sessions
  (10 000 full / 300 smoke) through ONE GatewayService on the sim clock,
  arrivals fast and think times long enough that every session is live at
  once (``peak_concurrent == n_sessions``).  Reports p50/p99 queue wait
  and attach wait (sim seconds, deterministic), p50/p99 placement-decision
  latency (wall ms), and loop events/second (wall).
* **warm_pool** — the same trace through a K-worker warm pool vs a cold
  pool (K=0): arrival rate is kept under the pool's refill rate
  ``K / cold_start`` so warm attaches never miss, and the attach-p99
  ratio (cold / warm) is the pool's payoff — gated ≥ 5x.
* **mux** — identical migration traffic over dedicated connections vs
  MuxStreams sharing one pipe: per-session frame/byte counters must match
  EXACTLY (``bytes_identical == 1.0``); the envelope overhead the shared
  pipe absorbs is reported, not charged to sessions.
* **memo** — a batch of horizon decisions with the per-decision
  distribution memo on vs off: identical decisions, strictly fewer
  interaction-model queries (deterministic ratio, gated exact), and the
  wall-clock decide speedup.

Sim-derived metrics are deterministic and safe for ``check_regression``;
wall-clock metrics (decision latency, events/sec) are gated loosely or
not at all.
"""
from __future__ import annotations

import json
import threading
import time

from repro.core import (
    ContextDetector, EnvironmentRegistry, ExecutionEnvironment,
    KnowledgeBase, MigrationAnalyzer, MigrationPeer, MuxEnvServer, MuxPeer,
    Notebook, PerfModel, WireReceiver,
)
from repro.core import wire
from repro.core.chunkstore import MemoryChunkStore
from repro.core.gateway import GatewayService, poisson_attach_storm
from repro.core.reducer import StateReducer
from repro.core.state import ExecutionState
from repro.core.transport import LoopbackTransport

COLD_START = 5.0
THINK_MEAN = 120.0      # long think: the whole storm is concurrently live
GPU_CAPACITY = 256


def make_registry(local_capacity: int) -> EnvironmentRegistry:
    reg = EnvironmentRegistry(default_bandwidth=1e9, default_latency=0.05)
    reg.register(ExecutionEnvironment("local"), home=True,
                 capacity=local_capacity)
    reg.register(ExecutionEnvironment("gpu-cloud", speedup=8.0),
                 capacity=GPU_CAPACITY)
    reg.connect("local", "gpu-cloud", bandwidth=5e8, latency=0.3)
    return reg


def make_notebook(i: int) -> Notebook:
    nb = Notebook(f"user-{i % 16}")
    nb.add_cell("x = 2.0", cost=0.5)
    nb.add_cell("y = x * 3.0", cost=30.0)
    nb.add_cell("z = y + 1.0", cost=1.0)
    return nb


# ----------------------------------------------------------------------
# storm: 10k concurrent sessions through one gateway
# ----------------------------------------------------------------------

def storm_sweep(rows, out, *, n_sessions: int) -> None:
    gw = GatewayService(make_registry(n_sessions + 64),
                        warm_pool=64, cold_start=COLD_START,
                        policy="cost", use_knowledge=False)
    gw.add_tenant("research", weight=2.0)
    gw.add_tenant("teaching", weight=1.0)
    poisson_attach_storm(gw, n_sessions=n_sessions, rate=n_sessions / 5.0,
                         think_mean=THINK_MEAN, make_notebook=make_notebook,
                         tenants=("research", "teaching"), seed=11)
    t0 = time.perf_counter()
    rep = gw.run()
    wall = time.perf_counter() - t0
    events = rep.sessions * (3 + 1)          # steps + admission per session
    assert rep.sessions == n_sessions and rep.errors == 0, rep
    assert rep.peak_concurrent == n_sessions, rep.peak_concurrent
    out["storm"] = {
        "sessions": rep.sessions,
        "peak_concurrent": rep.peak_concurrent,
        "completed": rep.completed,
        "makespan": round(rep.makespan, 3),
        "queue_wait_p50": round(rep.queue_wait_p50, 4),
        "queue_wait_p99": round(rep.queue_wait_p99, 4),
        "attach_wait_p50": round(rep.attach_wait_p50, 4),
        "attach_wait_p99": round(rep.attach_wait_p99, 4),
        "decision_ms_p50": round(rep.decision_ms_p50, 4),
        "decision_ms_p99": round(rep.decision_ms_p99, 4),
        "decisions": rep.decisions,
        "wall_seconds": round(wall, 3),
        "events_per_sec": round(events / max(wall, 1e-9), 1),
    }
    rows.append(("gateway/storm/peak_concurrent", rep.peak_concurrent,
                 "sessions simultaneously attached to one process"))
    rows.append(("gateway/storm/queue_wait_p99",
                 round(rep.queue_wait_p99, 4),
                 "p99 capacity-wait sim seconds"))
    rows.append(("gateway/storm/attach_wait_p99",
                 round(rep.attach_wait_p99, 4),
                 "p99 attach (admission + provisioning) sim seconds"))
    rows.append(("gateway/storm/decision_ms_p99",
                 round(rep.decision_ms_p99, 4),
                 "p99 placement-decision wall ms"))
    rows.append(("gateway/storm/events_per_sec",
                 round(events / max(wall, 1e-9), 1),
                 "loop throughput (wall; not gated)"))


# ----------------------------------------------------------------------
# warm pool vs cold provision
# ----------------------------------------------------------------------

def warm_pool_sweep(rows, out, *, n_sessions: int) -> None:
    pool = 16
    rate = pool / COLD_START / 2.0       # half the refill rate: no misses
    results = {}
    for label, k in (("warm", pool), ("cold", 0)):
        gw = GatewayService(make_registry(n_sessions + pool),
                            warm_pool=k, cold_start=COLD_START,
                            policy="cost", use_knowledge=False)
        poisson_attach_storm(gw, n_sessions=n_sessions, rate=rate,
                             think_mean=10.0, make_notebook=make_notebook,
                             seed=13)
        rep = gw.run()
        assert rep.errors == 0
        results[label] = rep
    warm_p99 = results["warm"].attach_wait_p99
    cold_p99 = results["cold"].attach_wait_p99
    # a perfect warm pool attaches in 0.0 sim seconds; floor the
    # denominator at 1% of the cold start so the ratio stays finite
    speedup = cold_p99 / max(warm_p99, COLD_START / 100.0)
    assert results["warm"].pool_misses == 0, results["warm"].pool_misses
    assert speedup >= 5.0, (warm_p99, cold_p99)
    out["warm_pool"] = {
        "pool_size": pool,
        "warm_attach_p99": round(warm_p99, 4),
        "cold_attach_p99": round(cold_p99, 4),
        "attach_speedup": round(speedup, 2),
        "pool_hits": results["warm"].pool_hits,
        "pool_refills": results["warm"].pool_refills,
    }
    rows.append(("gateway/warm_pool/warm_attach_p99", round(warm_p99, 4),
                 "p99 attach with a 16-worker pool"))
    rows.append(("gateway/warm_pool/cold_attach_p99", round(cold_p99, 4),
                 "p99 attach provisioning on demand"))
    rows.append(("gateway/warm_pool/attach_speedup", round(speedup, 2),
                 "cold/warm attach-p99 ratio (gated >= 5)"))


# ----------------------------------------------------------------------
# mux byte-accounting equality
# ----------------------------------------------------------------------

def _session_traffic(peer, i: int, red) -> tuple:
    st = ExecutionState({"x": float(i), "blob": bytes(range(256)) * 64})
    peer.send_state(red.serialize_names(st, ["x", "blob"]))
    sent_before = peer.transport.bytes_sent
    peer.execute("y = x + 1")
    exec_sent = peer.transport.bytes_sent - sent_before
    peer.close()
    t = peer.transport
    return (t.frames_sent, t.bytes_sent, t.frames_recv, exec_sent)


def _serve_plain(receiver, transport):
    while True:
        frame = transport.recv(timeout=30.0)
        if frame.ftype == wire.BYE:
            return
        receiver.handle(frame, transport)


def mux_sweep(rows, out, *, n_streams: int = 4) -> None:
    red = StateReducer(codec="zlib")
    dedicated = []
    for i in range(n_streams):
        ctr, srv_tr = LoopbackTransport.pair()
        rcv = WireReceiver(MemoryChunkStore(), red, ns={})
        t = threading.Thread(target=_serve_plain, args=(rcv, srv_tr),
                             daemon=True)
        t.start()
        dedicated.append(_session_traffic(
            MigrationPeer(ctr, codec="zlib"), i, red))
        t.join(timeout=10.0)

    client_tr, server_tr = LoopbackTransport.pair()
    # sessions run one after another (attach/detach churn), so the shared
    # connection must outlive each stream's BYE: persistent=True
    server = MuxEnvServer(server_tr,
                          lambda sid: WireReceiver(MemoryChunkStore(), red,
                                                   ns={}),
                          timeout=30.0, persistent=True)
    mux = MuxPeer(client_tr, initiator=True)
    muxed = [_session_traffic(MigrationPeer(mux.open_stream(),
                                            codec="zlib"), i, red)
             for i in range(n_streams)]
    shared_sent = client_tr.bytes_sent
    client_tr.close()
    server.join()
    assert server.streams_served == n_streams, server.streams_served
    identical = 1.0 if muxed == dedicated else 0.0
    assert identical == 1.0, (muxed, dedicated)
    session_bytes = sum(d[1] for d in dedicated)
    overhead = shared_sent - session_bytes
    out["mux"] = {
        "streams": n_streams,
        "bytes_identical": identical,
        "per_session_bytes": session_bytes,
        "shared_pipe_bytes": shared_sent,
        "envelope_overhead_bytes": overhead,
    }
    rows.append(("gateway/mux/bytes_identical", identical,
                 "per-stream counters == dedicated-connection counters"))
    rows.append(("gateway/mux/envelope_overhead_bytes", overhead,
                 "STREAM framing cost on the shared pipe"))


# ----------------------------------------------------------------------
# memoized horizon decisions
# ----------------------------------------------------------------------

def memo_sweep(rows, out, *, n_cells: int, repeats: int) -> None:
    def build():
        reg = EnvironmentRegistry(default_bandwidth=1e9,
                                  default_latency=2.0)
        reg.register(ExecutionEnvironment("local"), home=True)
        reg.register(ExecutionEnvironment("remote", speedup=10.0))
        ctxd = ContextDetector("markov")
        perf = PerfModel()
        an = MigrationAnalyzer(KnowledgeBase(), ctxd, perf,
                               policy="horizon", use_knowledge=False,
                               registry=reg, horizon=8)
        an.observe_state_size("nb", 1.0)
        nb = Notebook("nb")
        cells = [nb.add_cell(f"s{i} = work_{i}()", cost=8.0)
                 for i in range(n_cells)]
        for c in cells:
            perf.observe(c.cell_id, "local", 8.0)
            perf.observe(c.cell_id, "remote", 0.8)
        for _ in range(5):
            for o in range(n_cells):
                ctxd.record("nb", o)
        return an, nb, cells

    stats = {}
    for memo in (False, True):
        an, nb, cells = build()
        pol = an._chain[-1]
        pol.memoize = memo
        t0 = time.perf_counter()
        decisions = []
        for _ in range(repeats):
            decisions = [an.decide(nb, c, current_env="local", peek=True)
                         for c in cells]
        wall = time.perf_counter() - t0
        stats[memo] = {
            "wall": wall,
            "model_calls": pol.model_calls,
            "decisions": [(d.env, d.migrate, tuple(d.block))
                          for d in decisions],
        }
    assert stats[True]["decisions"] == stats[False]["decisions"]
    calls_ratio = stats[True]["model_calls"] / stats[False]["model_calls"]
    speedup = stats[False]["wall"] / max(stats[True]["wall"], 1e-9)
    out["memo"] = {
        "model_calls_memo": stats[True]["model_calls"],
        "model_calls_nomemo": stats[False]["model_calls"],
        "model_calls_ratio": round(calls_ratio, 4),
        "decide_speedup": round(speedup, 2),
        "bit_identical": 1.0,
    }
    rows.append(("gateway/memo/model_calls_ratio", round(calls_ratio, 4),
                 "interaction-model queries, memo/nomemo (deterministic)"))
    rows.append(("gateway/memo/decide_speedup", round(speedup, 2),
                 "horizon decide wall speedup (not gated)"))
    rows.append(("gateway/memo/bit_identical", 1.0,
                 "memoized decisions identical to recomputed"))


def run(smoke: bool = False):
    rows: list[tuple] = []
    out: dict = {}
    n = 300 if smoke else 10_000
    storm_sweep(rows, out, n_sessions=n)
    warm_pool_sweep(rows, out, n_sessions=30 if smoke else 120)
    mux_sweep(rows, out)
    memo_sweep(rows, out, n_cells=8, repeats=5 if smoke else 40)
    with open("BENCH_gateway.json", "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    print("name,value,derived")
    for name, val, note in run(smoke=True):
        print(f"{name},{val},{note}")
