"""Live replication benchmarks: think-time delta trickling vs pipelined
prefetch, plus liveness pruning of dead state.

Three sweeps (results also land in ``BENCH_live.json``):

* **decision-to-ready** — the fig5/fig11 trace families (synthetic loops;
  adapted TF guide) replayed as real notebooks with think-time gaps, under
  (a) the pipelined engine's execution-overlapped prefetch and (b) the
  background delta replicator.  The replicator trickles dirty state to the
  likely next envs *during think time*, so by decision time the target
  already banks the bytes and the migration ships a manifest plus the last
  cell's delta — the summed migration wait (what the user actually sits
  through) drops several-fold at (near-)equal total bytes moved.
* **dead-state liveness** — a notebook whose early cells build large
  intermediates no later cell reads: live-variable analysis over the
  remaining plan prunes them from both the trickle and the full-state
  return trip, cutting shipped bytes vs the same run with liveness off.
* **degenerate case** — replication off is the identity: the scheduler
  takes the exact pre-replication path (asserted bit-identically in the
  test suite against committed fig5/fig11 decision goldens).

All gated metrics are deterministic (sim-clock seconds and byte counts on
seeded traces) — safe for ``check_regression``.
"""
from __future__ import annotations

import json

from repro.core import (
    EnvironmentRegistry, ExecutionEnvironment, Notebook, SessionScheduler,
)
from repro.core.simulator import synthetic_loops_trace, tf_guide_trace

BANDWIDTH = 2e5          # bytes/s: state transfers are worth hiding
LATENCY = 0.01           # per-frame floor (intra-cloud RTT); cheap cells
                         # clamp below it so placement keeps them at home
                         # in BOTH arms
REMOTE_SPEEDUP = 10.0
THINK = 6.0              # seconds of think time between cells
TRICKLE_RATE = 1e6       # replicator budget (well above the link: the
                         # trickle converges within one think gap)


def make_registry() -> EnvironmentRegistry:
    reg = EnvironmentRegistry(default_bandwidth=BANDWIDTH,
                              default_latency=LATENCY)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment("remote", speedup=REMOTE_SPEEDUP),
                 capacity=4)
    return reg


N_BASE = 3               # working-set arrays the heavy cells read
HEAVY_COST = 5.0         # trace cells at/above this offload to remote


#: the working set is size-asymmetric like a real session — a big raw
#: table, a medium feature matrix, a small parameter vector — so the
#: residual delta (whatever the *last* pre-decision cell touched) is
#: usually a fraction of what think-time trickling already banked
_SIZE_DIV = {0: 1, 1: 2, 2: 16}


def make_trace_notebook(trace, arr_elems: int) -> Notebook:
    """The trace's cells as a data-science session: cell 0 loads a working
    set of base arrays, cheap cells mutate one of them in place (the dirty
    delta the trickle chases), heavy cells aggregate the whole set into a
    scalar (so offloading needs the full working set on the remote, but the
    return trip is one float — the paper's load/train/report shape)."""
    nb = Notebook(f"live-{trace.name}")
    ncells = max(trace.order) + 1
    for i in range(ncells):
        if i == 0:
            lines = ["import numpy as np"] + [
                f"d{j} = np.arange({arr_elems // _SIZE_DIV[j]},"
                f" dtype=np.float64) + {j}"
                for j in range(N_BASE)]
            src = "\n".join(lines)
        elif trace.costs[i] >= HEAVY_COST:
            terms = " + ".join(f"float((d{j} * {i}).sum())"
                               for j in range(N_BASE))
            src = f"m{i} = {terms}"
        else:
            j = i % N_BASE
            src = f"d{j} = d{j} * 1.0001 + {i}"
        # non-heavy cells clamp below the migration latency so placement
        # keeps them at home under either arm's cost model — the sweep
        # compares how the arms move the working set, not where borderline
        # cells land
        cost = (trace.costs[i] if trace.costs[i] >= HEAVY_COST
                else min(trace.costs[i], LATENCY * 0.4))
        nb.add_cell(src, cost=cost)
    return nb


def run_arm(trace, *, interactions: int, arr_elems: int,
            replicate: bool, pipeline: bool, liveness: bool = True) -> dict:
    sched = SessionScheduler(make_registry())
    nb = make_trace_notebook(trace, arr_elems)
    plan = list(trace.order[:interactions])
    sched.add_notebook(nb, plan=plan, policy="cost", use_knowledge=False,
                       pipeline=pipeline, think=[THINK] * len(plan))
    if replicate:
        sched.enable_replication(rate=TRICKLE_RATE, liveness=liveness,
                                 interval=THINK / 4.0)
    rep = sched.run()
    s = sched._sessions[0]
    eng = s.runtime.engine
    migrations = [m for m in eng.log if not m.noop]
    return {
        "decision_wait_seconds": round(sum(m.seconds for m in migrations), 3),
        "migrated_bytes": sum(m.nbytes for m in migrations),
        "trickled_bytes": s.rep.trickled_bytes if s.rep else 0,
        "claimed_bytes": s.rep.claimed_bytes if s.rep else 0,
        "wasted_bytes": getattr(eng, "prefetch_wasted_bytes", 0),
        "migrations": len(migrations),
        "makespan": round(rep.makespan, 3),
    }


def decision_ready_sweep(rows, out, *, interactions: int,
                         arr_elems: int) -> None:
    for trace_fn, key in ((synthetic_loops_trace, "synthetic_loops"),
                          (tf_guide_trace, "tf_guide")):
        trace = trace_fn()
        base = run_arm(trace, interactions=interactions,
                       arr_elems=arr_elems, replicate=False, pipeline=True)
        live = run_arm(trace, interactions=interactions,
                       arr_elems=arr_elems, replicate=True, pipeline=False)
        base_total = base["migrated_bytes"]
        live_total = live["migrated_bytes"] + live["trickled_bytes"]
        speedup = (base["decision_wait_seconds"]
                   / max(live["decision_wait_seconds"], 1e-9))
        ratio = live_total / max(base_total, 1)
        out[key] = {
            "pipelined": base, "replicated": live,
            "decision_ready_speedup": round(speedup, 3),
            "total_bytes_ratio": round(ratio, 4),
        }
        rows.append((f"live/{key}/pipelined_wait_s",
                     base["decision_wait_seconds"],
                     f"{base['migrations']} migrations"))
        rows.append((f"live/{key}/replicated_wait_s",
                     live["decision_wait_seconds"],
                     f"{live['migrations']} migrations, "
                     f"{live['claimed_bytes']} B claimed"))
        rows.append((f"live/{key}/decision_ready_speedup", round(speedup, 3),
                     "replicated vs pipelined decision-to-ready"))
        rows.append((f"live/{key}/total_bytes_ratio", round(ratio, 4),
                     f"replicated {live_total} B vs pipelined "
                     f"{base_total} B (incl. trickle)"))


def make_dead_notebook(arr_elems: int) -> Notebook:
    """Early cells build big intermediates no later cell reads: after the
    heavy block, only ``model``/``result`` are live."""
    nb = Notebook("live-deadstate")
    nb.add_cell(f"import numpy as np\n"
                f"raw = np.arange({arr_elems * 4}, dtype=np.float64)",
                cost=0.2)
    nb.add_cell("feat = raw * 2.0 + 1.0", cost=0.2)
    nb.add_cell("model = float(feat.sum())", cost=60.0)
    nb.add_cell("result = model * 0.5 + 1.0", cost=60.0)
    nb.add_cell("summary = result / 1e6", cost=0.1)
    return nb


def dead_state_sweep(rows, out, *, arr_elems: int) -> None:
    stats = {}
    for liveness in (True, False):
        sched = SessionScheduler(make_registry())
        nb = make_dead_notebook(arr_elems)
        plan = list(range(len(nb.cells)))
        sched.add_notebook(nb, plan=plan, policy="cost", use_knowledge=False,
                           think=[THINK] * len(plan))
        sched.enable_replication(rate=TRICKLE_RATE, liveness=liveness,
                                 interval=THINK / 4.0)
        sched.run()
        s = sched._sessions[0]
        eng = s.runtime.engine
        total = (sum(m.nbytes for m in eng.log)
                 + s.rep.trickled_bytes)
        stats["on" if liveness else "off"] = total
    ratio = stats["on"] / max(stats["off"], 1)
    out["dead_state"] = {
        "liveness_on_bytes": stats["on"],
        "liveness_off_bytes": stats["off"],
        "liveness_bytes_ratio": round(ratio, 4),
    }
    rows.append(("live/dead_state/liveness_on_bytes", stats["on"],
                 "trickle + migrations, dead names pruned"))
    rows.append(("live/dead_state/liveness_off_bytes", stats["off"],
                 "same workload, liveness off"))
    rows.append(("live/dead_state/liveness_bytes_ratio", round(ratio, 4),
                 "shipped-bytes ratio (lower = pruning pays)"))


def run(smoke: bool = False):
    rows: list[tuple] = []
    out: dict = {}
    interactions = 30 if smoke else 90
    arr_elems = 20_000 if smoke else 50_000
    decision_ready_sweep(rows, out, interactions=interactions,
                         arr_elems=arr_elems)
    dead_state_sweep(rows, out, arr_elems=arr_elems)
    with open("BENCH_live.json", "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    print("name,value,derived")
    for name, val, note in run(smoke=True):
        print(f"{name},{val},{note}")
