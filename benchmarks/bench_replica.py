"""Replica-plane benchmarks: zero-replay failover and speculative racing.

Two sweeps (results also land in ``BENCH_replica.json``):

* **failover** — an env dies mid-heavy-cell at several namespace sizes;
  three recovery arms on an identical fleet: *rerun* replays the whole
  plan from home, *checkpoint* restores the latest periodic CAS
  checkpoint and replays the cells since it, *replica* promotes the
  most-converged warm follower and resumes the plan with zero replay.
  The claim: promotion's recovery overhead (makespan minus the no-failure
  makespan of the same fleet) beats checkpoint-restore by >10x at the
  largest namespace size, because the follower already holds the state
  that checkpoint recovery has to ship and the cells it has to replay.
* **race** — first-result-wins speculative execution on two equal-cost
  envs, admission-gated by the interaction model.  Correctness gate: the
  committed result of every raced cell is bit-identical to a solo run of
  the same plan (the loser leg executes against a discarded overlay), and
  the wasted leg is charged to the speculation ledger.
"""
from __future__ import annotations

import json


from repro.core import (
    EnvironmentRegistry, ExecutionEnvironment, HybridRuntime, Notebook,
    SessionScheduler,
)

# The failure strikes the light report cell, after the heavy training
# cells committed, at the point where the periodic checkpoint is maximally
# stale — the snapshot predates the last two heavy cells, so checkpoint
# recovery replays them while promotion resumes with zero replay.  All
# arms pay the same heartbeat miss window and the same re-execution of
# the one interrupted cell; everything else is the recovery mechanism.
FAIL_AT = 29.9
CKPT_INTERVAL = 15.0
BEAT_INTERVAL = 0.2      # 3-miss window => 0.6s detection latency
N_CELLS = 5


def make_notebook(n_elems: int, tag: str) -> Notebook:
    """Load -> three heavy train cells -> light report; the loaded array
    is the namespace the recovery arms have to reconstruct."""
    nb = Notebook(f"replica-session-{tag}")
    nb.add_cell("import numpy as np\n"
                f"data = np.arange({n_elems}, dtype=np.float64)", cost=4.0)
    nb.add_cell("model = float((data ** 2).sum())", cost=80.0)
    nb.add_cell("model2 = model + float(data.sum())", cost=80.0)
    nb.add_cell("model3 = model2 * 0.5 + float(data[-1])", cost=80.0)
    nb.add_cell("out = model3 / 2", cost=5.0)
    return nb


def make_registry() -> EnvironmentRegistry:
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.3)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment("gpu-cloud", speedup=10.0), capacity=1)
    reg.register(ExecutionEnvironment("gpu-standby", speedup=10.0),
                 capacity=1)
    return reg


def _run_arm(n_elems: int, mode: str | None):
    """One fleet run; ``mode`` None = no failure (the overhead baseline)."""
    sched = SessionScheduler(make_registry(), beat_interval=BEAT_INTERVAL)
    if mode == "replica":
        sched.enable_replicas(2)
        sched.enable_recovery("rerun")       # fallback when no follower
    elif mode is not None:
        sched.enable_recovery(mode, interval=CKPT_INTERVAL)
    if mode is not None:
        sched.inject_failure("gpu-cloud", at=FAIL_AT, recover_after=10.0)
    sched.add_notebook(make_notebook(n_elems, f"{n_elems}-{mode}"),
                       policy="cost", use_knowledge=False,
                       think=[1.0] * N_CELLS)
    return sched.run()


def failover_sweep(rows, out, sizes) -> None:
    for n_elems in sizes:
        base = _run_arm(n_elems, None)
        entry = {"n_elems": n_elems, "nofail_makespan": base.makespan}
        overhead = {}
        for mode in ("rerun", "checkpoint", "replica"):
            rep = _run_arm(n_elems, mode)
            assert rep.recoveries >= 1, "failure must interrupt the run"
            assert rep.sessions[0].cells_run == N_CELLS
            overhead[mode] = rep.makespan - base.makespan
            entry[mode] = {
                "makespan": rep.makespan,
                "recovery_overhead": overhead[mode],
                "recoveries": rep.recoveries,
                "promotions": rep.promotions,
                "replicated_bytes": rep.replicated_bytes,
                "replica_shared_bytes": rep.replica_shared_bytes,
                "checkpoints": rep.checkpoints,
                "restored_bytes": rep.restored_bytes,
            }
            rows.append((f"replica/n{n_elems}/{mode}/recovery_overhead",
                         overhead[mode],
                         f"makespan {rep.makespan:.2f}s vs "
                         f"{base.makespan:.2f}s no-failure"))
        assert entry["replica"]["promotions"] == 1, \
            "the replica arm must recover by promotion, not rerun"
        for rival in ("rerun", "checkpoint"):
            entry[f"promote_speedup_vs_{rival}"] = (
                overhead[rival] / max(overhead["replica"], 1e-9))
            rows.append((f"replica/n{n_elems}/promote_speedup_vs_{rival}",
                         entry[f"promote_speedup_vs_{rival}"],
                         ">1 = promoting the warm follower wins"))
        out["failover"].append(entry)
    largest = out["failover"][-1]
    out["promote_speedup_vs_checkpoint"] = (
        largest["promote_speedup_vs_checkpoint"])
    out["promote_speedup_vs_rerun"] = largest["promote_speedup_vs_rerun"]
    assert largest["promote_speedup_vs_checkpoint"] >= 10.0, (
        f"promotion must beat checkpoint-restore >=10x at the largest "
        f"namespace; got {largest['promote_speedup_vs_checkpoint']:.1f}x")


# ----------------------------------------------------------------------
def _race_run(race: bool):
    """Two passes over a three-cell plan on two equal-speed cloud envs:
    the second pass carries predictions, the equal pricing lands inside
    the race band, and the heavy cell races."""
    nb = Notebook("replica-race")
    nb.add_cell("import numpy as np\n"
                "a = np.arange(4000, dtype=np.float64)", cost=0.1)
    nb.add_cell("t = float((a * 3) @ a)", cost=30.0)
    nb.add_cell("u = t / 7", cost=0.1)
    envs = {"local": ExecutionEnvironment("local"),
            "fast-a": ExecutionEnvironment("fast-a", speedup=10.0),
            "fast-b": ExecutionEnvironment("fast-b", speedup=10.0)}
    rt = HybridRuntime(nb, envs=envs, policy="cost", use_knowledge=False,
                       latency=0.01, bandwidth=1e8)
    rs = rt.attach_replicas(["fast-a", "fast-b"], race=race, rate=1e9)
    for _pass in range(2):
        for order in range(3):
            rt.run_cell(order)
            rs.sync(rt.clock.now() + 1.0, budget_bytes=1 << 30)
    final = {}
    for name in ("t", "u"):
        env = next(e for e in rt.envs.values() if name in e.state.ns)
        final[name] = float(env.state.ns[name])
    rt.close()
    return rs, final


def race_bench(rows, out) -> None:
    solo_rs, solo_final = _race_run(race=False)
    raced_rs, raced_final = _race_run(race=True)
    assert solo_rs.races == 0
    assert raced_rs.races >= 1, "the heavy cell must race"
    identical = float(all(
        solo_final[k] == raced_final[k] for k in solo_final))
    out["race"] = {
        "races": raced_rs.races,
        "race_wins": dict(raced_rs.race_wins),
        "race_waste_seconds": raced_rs.race_waste_seconds,
        "race_leg_bytes": raced_rs.race_leg_bytes,
        "bit_identical": identical,
    }
    rows.append(("replica/race/races", float(raced_rs.races),
                 f"wins {dict(raced_rs.race_wins)}"))
    rows.append(("replica/race/waste_seconds", raced_rs.race_waste_seconds,
                 "loser legs charged to the speculation ledger"))
    rows.append(("replica/race/bit_identical", identical,
                 "raced committed results == solo run (hard gate)"))
    assert identical == 1.0, "racing must never change the committed result"


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    out: dict = {"failover": []}
    sizes = (50_000, 500_000) if smoke else (50_000, 500_000, 5_000_000)
    failover_sweep(rows, out, sizes)
    race_bench(rows, out)
    with open("BENCH_replica.json", "w") as f:
        json.dump(out, f, indent=2)
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val},{note}")
