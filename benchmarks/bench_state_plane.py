"""State-plane benchmarks: chunk-level CAS delta vs whole-name baseline.

Three workloads, each run twice with the *same* engine/reducer stack and
only the state-plane granularity flipped:

* **small-mutation** — a large array migrates once, then a 1-element
  in-place update repeats.  Whole-name delta re-ships the array every time;
  the chunk manifest ships one chunk.
* **append-only** — the array grows by one chunk per step.  Whole-name
  re-ships the whole prefix; chunk delta ships only the new tail.
* **multi-session shared dataset** — k scheduler sessions each load the
  same dataset and migrate it to the accelerator env.  With the registry-
  level shared chunk store the dataset's chunks cross the wire once;
  without it (and at whole-name granularity) every session pays full price.

Reports bytes-moved and wall-clock per workload and writes
``BENCH_state_plane.json`` (uploaded as a CI artifact from the smoke run).
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import (
    EnvironmentRegistry, ExecutionEnvironment, MigrationEngine, Notebook,
    SessionScheduler, StateReducer,
)

CHUNK = 64 << 10          # 64 KiB chunks keep mutation locality visible


def _two_env(bandwidth: float = 1e9) -> EnvironmentRegistry:
    reg = EnvironmentRegistry(default_bandwidth=bandwidth, default_latency=0.1)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment("gpu-cloud", speedup=8.0), capacity=2)
    return reg


def _engine(chunked: bool, reg: EnvironmentRegistry) -> MigrationEngine:
    # codec "none" isolates the chunking effect from compression luck
    red = StateReducer("none", chunk_bytes=CHUNK if chunked else 0)
    return MigrationEngine(red, registry=reg)


def _moved(eng: MigrationEngine) -> int:
    return sum(m.nbytes for m in eng.log)


def small_mutation(chunked: bool, smoke: bool) -> tuple[int, float]:
    n = (1 << 18) if smoke else (1 << 20)          # 1 MiB / 4 MiB array
    steps = 5
    reg = _two_env()
    eng = _engine(chunked, reg)
    l, r = reg["local"], reg["gpu-cloud"]
    l.state["big"] = np.arange(n, dtype=np.float32)
    t0 = time.perf_counter()
    eng.migrate(l, r, names={"big"})               # initial sync (both pay)
    base = _moved(eng)
    for i in range(steps):
        l.state["big"][i * 7] += 1.0               # 1-element in-place update
        eng.migrate(l, r, names={"big"})
    wall = time.perf_counter() - t0
    np.testing.assert_array_equal(r.state["big"], l.state["big"])
    return _moved(eng) - base, wall                # steady-state bytes only


def append_only(chunked: bool, smoke: bool) -> tuple[int, float]:
    n0 = (1 << 16) if smoke else (1 << 20)
    grow = CHUNK // 4                              # one chunk of float32/step
    steps = 5
    reg = _two_env()
    eng = _engine(chunked, reg)
    l, r = reg["local"], reg["gpu-cloud"]
    l.state["logbuf"] = np.arange(n0, dtype=np.float32)
    t0 = time.perf_counter()
    eng.migrate(l, r, names={"logbuf"})
    base = _moved(eng)
    for i in range(steps):
        tail = np.full(grow, float(i), np.float32)
        l.state["logbuf"] = np.concatenate([l.state["logbuf"], tail])
        eng.migrate(l, r, names={"logbuf"})
    wall = time.perf_counter() - t0
    np.testing.assert_array_equal(r.state["logbuf"], l.state["logbuf"])
    return _moved(eng) - base, wall


def multi_session(chunked: bool, smoke: bool) -> tuple[int, float]:
    n = (1 << 14) if smoke else (1 << 18)
    sessions = 6
    reg = _two_env()
    sched = SessionScheduler(reg, share_chunks=chunked)
    red_kw = dict(chunk_bytes=CHUNK if chunked else 0)
    runtimes = []
    t0 = time.perf_counter()
    for i in range(sessions):
        nb = Notebook(f"shared-ds-{i}")
        nb.add_cell("import numpy as np\n"
                    f"dataset = np.arange({n}, dtype=np.float64)", cost=1.0)
        nb.add_cell("model = float(((dataset - dataset.mean()) ** 2).sum())",
                    cost=60.0)
        nb.add_cell("report = model / len(dataset)", cost=0.1)
        runtimes.append(sched.add_notebook(
            nb, policy="cost", use_knowledge=False,
            reducer=StateReducer("none", **red_kw)))
    sched.run()
    wall = time.perf_counter() - t0
    return sum(_moved(rt.engine) for rt in runtimes), wall


WORKLOADS = [("small_mutation", small_mutation),
             ("append_only", append_only),
             ("multi_session", multi_session)]


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    report: dict[str, dict] = {}
    for name, fn in WORKLOADS:
        base_bytes, base_wall = fn(chunked=False, smoke=smoke)
        cas_bytes, cas_wall = fn(chunked=True, smoke=smoke)
        ratio = base_bytes / max(cas_bytes, 1)
        report[name] = {
            "baseline_bytes": base_bytes, "chunked_bytes": cas_bytes,
            "bytes_ratio": ratio,
            "baseline_wall_seconds": base_wall,
            "chunked_wall_seconds": cas_wall,
        }
        rows.append((f"state_plane/{name}/baseline_bytes", base_bytes,
                     "whole-name delta"))
        rows.append((f"state_plane/{name}/chunked_bytes", cas_bytes,
                     "CAS chunk delta"))
        rows.append((f"state_plane/{name}/bytes_ratio", ratio,
                     "acceptance: >=5x on small_mutation + multi_session"))
    with open("BENCH_state_plane.json", "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val},{note}")
