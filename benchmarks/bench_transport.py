"""Transport-plane benchmark: the first honest wall-clock numbers.

Three transport modes move the same two workloads between the paper's two
environments and we measure what actually happened:

- ``loopback``  — the default in-process path (simulated timing; the
  figures' baseline).  Wall seconds here are pure engine overhead.
- ``socket``    — every migration streams CRC-framed manifests + chunks
  over a real TCP connection to a receiver thread (same machine, so this
  isolates protocol + framing cost).
- ``socket_shaped`` — the same socket behind a token bucket
  (:class:`~repro.core.transport.TokenBucket`), so the wall numbers stay
  controlled instead of measuring whatever localhost felt like.

Workloads mirror the state plane's: ``small_mutation`` (one element of a
large array changes per step — chunk-level delta should keep the socket
traffic tiny) and ``append_only`` (the array grows per step).  The codec is
``none`` and sizes are fixed, so the byte/frame metrics are deterministic —
they are the regression-gate keys in ``BENCH_transport.json``; wall-clock
metrics are reported but too machine-dependent to gate tightly.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.fabric import EnvironmentRegistry
from repro.core.migration import MigrationEngine
from repro.core.reducer import StateReducer
from repro.core.transport import TokenBucket, attach_peer

# shaping floor for the socket_shaped rows: low enough that the shaper —
# not localhost — dominates, high enough that --smoke stays quick
_SHAPED_RATE = 2_000_000.0      # bytes/second
_SHAPED_LATENCY = 0.002         # seconds/frame


def _engine(mode: str):
    reg = EnvironmentRegistry.two_env()
    red = StateReducer("none", chunk_bytes=4096)
    eng = MigrationEngine(red, registry=reg)
    peer = None
    if mode != "loopback":
        shaper = (TokenBucket(_SHAPED_RATE, burst=1 << 14,
                              latency=_SHAPED_LATENCY)
                  if mode == "socket_shaped" else None)
        peer = attach_peer(reg["remote"], red, kind="socket", shaper=shaper)
    return reg, eng, peer


def small_mutation(mode: str, *, smoke: bool = False) -> dict:
    steps = 4 if smoke else 16
    size = 16_384 if smoke else 262_144
    reg, eng, peer = _engine(mode)
    local, remote = reg["local"], reg["remote"]
    local.state.ns["big"] = np.arange(size, dtype=np.float32)
    t0 = time.perf_counter()
    eng.migrate(local, remote, "s = float(big.sum())")
    for i in range(steps):
        local.state.ns["big"][(i * 997) % size] = -1.0 - i
        eng.invalidate("local", ["big"])
        eng.migrate(local, remote, "s = float(big.sum())")
    return _harvest(eng, peer, time.perf_counter() - t0)


def append_only(mode: str, *, smoke: bool = False) -> dict:
    steps = 4 if smoke else 16
    base = 8_192 if smoke else 65_536
    reg, eng, peer = _engine(mode)
    local, remote = reg["local"], reg["remote"]
    t0 = time.perf_counter()
    for i in range(steps):
        local.state.ns["log"] = np.arange(base * (i + 1), dtype=np.float32)
        eng.invalidate("local", ["log"])
        eng.migrate(local, remote, "n = int(log.size)")
    return _harvest(eng, peer, time.perf_counter() - t0)


def _harvest(eng, peer, wall: float) -> dict:
    out = {
        "wire_bytes": int(sum(m.nbytes for m in eng.log)),
        "frames": int(sum(m.wire_frames for m in eng.log)),
        "migrations": sum(1 for m in eng.log if not m.noop),
        "modeled_seconds": round(sum(m.seconds for m in eng.log), 6),
        "transfer_wall_seconds": round(
            sum(m.wall_seconds for m in eng.log), 6),
        "wall_seconds": round(wall, 6),
    }
    if peer is not None:
        peer.close()
    return out


WORKLOADS = [("small_mutation", small_mutation),
             ("append_only", append_only)]
MODES = ("loopback", "socket", "socket_shaped")


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    report: dict[str, dict] = {}
    for name, fn in WORKLOADS:
        per_mode = {mode: fn(mode, smoke=smoke) for mode in MODES}
        # chunk-manifest exchange must charge the same wire bytes whether
        # the receiver answers in process or over TCP
        per_mode["socket_vs_loopback_bytes"] = (
            per_mode["socket"]["wire_bytes"]
            / max(per_mode["loopback"]["wire_bytes"], 1))
        report[name] = per_mode
        for mode in MODES:
            r = per_mode[mode]
            rows.append((f"transport/{name}/{mode}/wire_bytes",
                         r["wire_bytes"], "deterministic (codec=none)"))
            rows.append((f"transport/{name}/{mode}/wall_seconds",
                         r["wall_seconds"],
                         "measured wall clock, machine-dependent"))
        rows.append((f"transport/{name}/socket_frames",
                     per_mode["socket"]["frames"], "frames on the wire"))
    with open("BENCH_transport.json", "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val},{note}")
