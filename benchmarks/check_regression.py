"""Benchmark regression gate.

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--baselines benchmarks/baselines] [--current .] [--update]

Compares the fresh smoke-run ``BENCH_*.json`` reports against committed
baselines with per-metric tolerances and exits non-zero on any regression
— CI runs it right after the benchmark smoke, so a PR that quietly makes
the transport ship more bytes, the CAS dedup less effective, or the
predictor less accurate fails its build instead of landing.

Rules live in ``<baselines>/tolerances.json``:

    {"BENCH_transport.json": [
        {"metric": "small_mutation.socket.wire_bytes",
         "cmp": "max", "tol": 0.10}, ...], ...}

``metric`` is a dotted path into the report.  ``cmp: "max"`` gates a
lower-is-better metric (fresh must stay <= baseline * (1 + tol));
``cmp: "min"`` gates higher-is-better (fresh >= baseline * (1 - tol)).
Only *deterministic* metrics belong here (byte counts, frame counts,
seeded ratios) — wall-clock seconds vary by machine and would flake.

``--update`` rewrites the baseline files from the current reports (run a
fresh ``--smoke`` first); tolerances are never auto-updated.

When ``$GITHUB_STEP_SUMMARY`` is set (always, inside a GitHub Actions
step) a per-metric markdown table — metric, baseline, observed,
tolerance, PASS/FAIL — is appended to it so the gate's full scoreboard
shows on the run's summary page instead of only the failing lines.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINES = os.path.join(os.path.dirname(__file__), "baselines")


def lookup(doc, dotted: str):
    """Dotted path into a report; integer parts index into lists
    (``arrivals.0.queue_wait.static``)."""
    cur = doc
    for part in dotted.split("."):
        if isinstance(cur, list):
            if not part.isdigit() or int(part) >= len(cur):
                raise KeyError(dotted)
            cur = cur[int(part)]
        elif isinstance(cur, dict) and part in cur:
            cur = cur[part]
        else:
            raise KeyError(dotted)
    if not isinstance(cur, (int, float)) or isinstance(cur, bool):
        raise TypeError(f"{dotted} is {type(cur).__name__}, not a number")
    return float(cur)


def check_file(rules: list[dict], baseline: dict, current: dict,
               fname: str, rows: list[dict] | None = None) -> list[str]:
    """Apply one file's rules; returns human-readable failure lines.
    When ``rows`` is given, a structured record per rule is appended to
    it (for the markdown step summary): file, metric, cmp, tol,
    baseline, observed, ok."""
    failures = []
    for rule in rules:
        metric, cmp_, tol = rule["metric"], rule["cmp"], float(rule["tol"])
        row = {"file": fname, "metric": metric, "cmp": cmp_, "tol": tol,
               "baseline": None, "observed": None, "ok": False}
        if rows is not None:
            rows.append(row)
        try:
            base = lookup(baseline, metric)
        except (KeyError, TypeError) as e:
            failures.append(f"{fname}:{metric}: missing in baseline ({e})")
            continue
        row["baseline"] = base
        try:
            cur = lookup(current, metric)
        except (KeyError, TypeError) as e:
            failures.append(f"{fname}:{metric}: missing in fresh report "
                            f"({e}) — did the benchmark stop emitting it?")
            continue
        row["observed"] = cur
        if cmp_ == "max":
            bound = base * (1.0 + tol)
            if cur > bound:
                failures.append(
                    f"{fname}:{metric}: REGRESSION {cur:g} > {bound:g} "
                    f"(baseline {base:g}, tol +{tol:.0%})")
            else:
                row["ok"] = True
        elif cmp_ == "min":
            bound = base * (1.0 - tol)
            if cur < bound:
                failures.append(
                    f"{fname}:{metric}: REGRESSION {cur:g} < {bound:g} "
                    f"(baseline {base:g}, tol -{tol:.0%})")
            else:
                row["ok"] = True
        else:
            failures.append(f"{fname}:{metric}: unknown cmp {cmp_!r}")
    return failures


def check_all(baselines_dir: str, current_dir: str,
              rows: list[dict] | None = None) -> list[str]:
    tol_path = os.path.join(baselines_dir, "tolerances.json")
    with open(tol_path) as f:
        spec = json.load(f)
    failures: list[str] = []
    for fname, rules in sorted(spec.items()):
        base_path = os.path.join(baselines_dir, fname)
        cur_path = os.path.join(current_dir, fname)
        if not os.path.exists(base_path):
            failures.append(f"{fname}: no committed baseline at {base_path} "
                            f"(run with --update to create it)")
            continue
        if not os.path.exists(cur_path):
            failures.append(f"{fname}: fresh report missing at {cur_path} "
                            f"(did the benchmark smoke run?)")
            continue
        with open(base_path) as f:
            baseline = json.load(f)
        with open(cur_path) as f:
            current = json.load(f)
        failures.extend(check_file(rules, baseline, current, fname,
                                   rows=rows))
    return failures


def render_summary(rows: list[dict], failures: list[str]) -> str:
    """Markdown table for ``$GITHUB_STEP_SUMMARY``: one row per gated
    metric with its baseline, the observed value, the tolerance and a
    PASS/FAIL verdict; spec-level failures (missing files) follow as
    bullets."""
    lines = ["## Benchmark regression gate", "",
             "| metric | baseline | observed | tolerance | verdict |",
             "|---|---|---|---|---|"]

    def num(v):
        return "—" if v is None else f"{v:g}"

    for row in rows:
        sign = "+" if row["cmp"] == "max" else "-"
        tol = (f"{sign}{row['tol']:.0%} ({row['cmp']})"
               if row["cmp"] in ("max", "min") else f"?{row['cmp']}?")
        verdict = "PASS" if row["ok"] else "**FAIL**"
        lines.append(f"| {row['file']}:{row['metric']} "
                     f"| {num(row['baseline'])} | {num(row['observed'])} "
                     f"| {tol} | {verdict} |")
    spec_failures = [f for f in failures if "REGRESSION" not in f]
    if spec_failures:
        lines.append("")
        lines.extend(f"- {f}" for f in spec_failures)
    lines.append("")
    lines.append(f"**{len(failures)} failure(s)**" if failures
                 else "All metrics within tolerance.")
    return "\n".join(lines) + "\n"


def update_baselines(baselines_dir: str, current_dir: str) -> list[str]:
    tol_path = os.path.join(baselines_dir, "tolerances.json")
    with open(tol_path) as f:
        spec = json.load(f)
    written = []
    for fname in spec:
        cur_path = os.path.join(current_dir, fname)
        if not os.path.exists(cur_path):
            raise SystemExit(f"--update: {cur_path} missing — run the "
                             f"benchmark smoke first")
        with open(cur_path) as f:
            doc = json.load(f)
        out = os.path.join(baselines_dir, fname)
        with open(out, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        written.append(out)
    return written


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baselines", default=DEFAULT_BASELINES)
    ap.add_argument("--current", default=".")
    ap.add_argument("--update", action="store_true",
                    help="rewrite baselines from the current reports")
    args = ap.parse_args(argv)
    if args.update:
        for path in update_baselines(args.baselines, args.current):
            print(f"baseline updated: {path}")
        return 0
    rows: list[dict] = []
    failures = check_all(args.baselines, args.current, rows=rows)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(render_summary(rows, failures))
    if failures:
        print("benchmark regression gate FAILED:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print("benchmark regression gate: all metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
