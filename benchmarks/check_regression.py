"""Benchmark regression gate.

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--baselines benchmarks/baselines] [--current .] [--update]

Compares the fresh smoke-run ``BENCH_*.json`` reports against committed
baselines with per-metric tolerances and exits non-zero on any regression
— CI runs it right after the benchmark smoke, so a PR that quietly makes
the transport ship more bytes, the CAS dedup less effective, or the
predictor less accurate fails its build instead of landing.

Rules live in ``<baselines>/tolerances.json``:

    {"BENCH_transport.json": [
        {"metric": "small_mutation.socket.wire_bytes",
         "cmp": "max", "tol": 0.10}, ...], ...}

``metric`` is a dotted path into the report.  ``cmp: "max"`` gates a
lower-is-better metric (fresh must stay <= baseline * (1 + tol));
``cmp: "min"`` gates higher-is-better (fresh >= baseline * (1 - tol)).
Only *deterministic* metrics belong here (byte counts, frame counts,
seeded ratios) — wall-clock seconds vary by machine and would flake.

``--update`` rewrites the baseline files from the current reports (run a
fresh ``--smoke`` first); tolerances are never auto-updated.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINES = os.path.join(os.path.dirname(__file__), "baselines")


def lookup(doc, dotted: str):
    """Dotted path into a report; integer parts index into lists
    (``arrivals.0.queue_wait.static``)."""
    cur = doc
    for part in dotted.split("."):
        if isinstance(cur, list):
            if not part.isdigit() or int(part) >= len(cur):
                raise KeyError(dotted)
            cur = cur[int(part)]
        elif isinstance(cur, dict) and part in cur:
            cur = cur[part]
        else:
            raise KeyError(dotted)
    if not isinstance(cur, (int, float)) or isinstance(cur, bool):
        raise TypeError(f"{dotted} is {type(cur).__name__}, not a number")
    return float(cur)


def check_file(rules: list[dict], baseline: dict, current: dict,
               fname: str) -> list[str]:
    """Apply one file's rules; returns human-readable failure lines."""
    failures = []
    for rule in rules:
        metric, cmp_, tol = rule["metric"], rule["cmp"], float(rule["tol"])
        try:
            base = lookup(baseline, metric)
        except (KeyError, TypeError) as e:
            failures.append(f"{fname}:{metric}: missing in baseline ({e})")
            continue
        try:
            cur = lookup(current, metric)
        except (KeyError, TypeError) as e:
            failures.append(f"{fname}:{metric}: missing in fresh report "
                            f"({e}) — did the benchmark stop emitting it?")
            continue
        if cmp_ == "max":
            bound = base * (1.0 + tol)
            if cur > bound:
                failures.append(
                    f"{fname}:{metric}: REGRESSION {cur:g} > {bound:g} "
                    f"(baseline {base:g}, tol +{tol:.0%})")
        elif cmp_ == "min":
            bound = base * (1.0 - tol)
            if cur < bound:
                failures.append(
                    f"{fname}:{metric}: REGRESSION {cur:g} < {bound:g} "
                    f"(baseline {base:g}, tol -{tol:.0%})")
        else:
            failures.append(f"{fname}:{metric}: unknown cmp {cmp_!r}")
    return failures


def check_all(baselines_dir: str, current_dir: str) -> list[str]:
    tol_path = os.path.join(baselines_dir, "tolerances.json")
    with open(tol_path) as f:
        spec = json.load(f)
    failures: list[str] = []
    for fname, rules in sorted(spec.items()):
        base_path = os.path.join(baselines_dir, fname)
        cur_path = os.path.join(current_dir, fname)
        if not os.path.exists(base_path):
            failures.append(f"{fname}: no committed baseline at {base_path} "
                            f"(run with --update to create it)")
            continue
        if not os.path.exists(cur_path):
            failures.append(f"{fname}: fresh report missing at {cur_path} "
                            f"(did the benchmark smoke run?)")
            continue
        with open(base_path) as f:
            baseline = json.load(f)
        with open(cur_path) as f:
            current = json.load(f)
        failures.extend(check_file(rules, baseline, current, fname))
    return failures


def update_baselines(baselines_dir: str, current_dir: str) -> list[str]:
    tol_path = os.path.join(baselines_dir, "tolerances.json")
    with open(tol_path) as f:
        spec = json.load(f)
    written = []
    for fname in spec:
        cur_path = os.path.join(current_dir, fname)
        if not os.path.exists(cur_path):
            raise SystemExit(f"--update: {cur_path} missing — run the "
                             f"benchmark smoke first")
        with open(cur_path) as f:
            doc = json.load(f)
        out = os.path.join(baselines_dir, fname)
        with open(out, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        written.append(out)
    return written


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baselines", default=DEFAULT_BASELINES)
    ap.add_argument("--current", default=".")
    ap.add_argument("--update", action="store_true",
                    help="rewrite baselines from the current reports")
    args = ap.parse_args(argv)
    if args.update:
        for path in update_baselines(args.baselines, args.current):
            print(f"baseline updated: {path}")
        return 0
    failures = check_all(args.baselines, args.current)
    if failures:
        print("benchmark regression gate FAILED:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print("benchmark regression gate: all metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
