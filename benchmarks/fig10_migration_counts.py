"""Paper Fig. 10: impact of migration counts on the block/single speedup
ratio — a slice of Fig. 8 at remote speedup = 150."""
from __future__ import annotations

from repro.core import simulate, synthetic_loops_trace

MIGRATION_TIMES = [0.1, 0.3, 0.5, 0.7, 0.9, 1.0, 1.5, 2.0, 3.0, 5.0]
REMOTE_SPEEDUP = 150


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    mig_times = MIGRATION_TIMES if not smoke else MIGRATION_TIMES[:2]
    tr = synthetic_loops_trace()
    local = simulate(tr, "local", migration_time=0, remote_speedup=1)
    prev_key = None
    for mt in mig_times:
        blk = simulate(tr, "block", migration_time=mt, remote_speedup=REMOTE_SPEEDUP)
        sng = simulate(tr, "single", migration_time=mt, remote_speedup=REMOTE_SPEEDUP)
        ratio = (local.total_seconds / blk.total_seconds) / max(
            local.total_seconds / sng.total_seconds, 1e-9)
        rows.append((f"fig10/mig{mt}s/ratio", ratio, ""))
        rows.append((f"fig10/mig{mt}s/block_migrations", blk.migrations, ""))
        rows.append((f"fig10/mig{mt}s/single_migrations", sng.migrations, ""))
        key = (blk.migrations, sng.migrations)
        note = ("migration counts constant -> ratio keeps rising with mig time"
                if key == prev_key else "migration-count regime change")
        rows[-3] = (rows[-3][0], rows[-3][1], note)
        prev_key = key
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val},{note}")
