"""Paper Fig. 11: knowledge-aware policy / Algorithm 2.

A DL-training cell ``model = train(data, epochs=e)`` is probed at small
epoch counts {1,2,3} in both environments (remote 4.43x faster, migration
2 minutes, max probe budget 5 minutes — the paper's exact protocol); linear
regressors are fitted and the KB threshold becomes their intersection.
Paper result: migration pays off for e > 7.
"""
from __future__ import annotations


from repro.core import (
    ContextDetector, EnvironmentRegistry, KnowledgeBase,
    MigrationAnalyzer, Notebook,
)

REMOTE_SPEEDUP = 4.43       # paper: "local executions run 4.43x slower"
MIGRATION_TIME = 120.0      # paper: "migration time to 2 minutes"
MAX_WAIT = 300.0            # paper: "maximum waiting time to 5 minutes"
BASE = 4.4                  # small fixed overhead (paper's Fig. 11 lines
                            # start near the origin before the migration shift)
PER_EPOCH = 21.5            # paper: local slope coefficient 21.5


class _ProbeRuntime:
    """Real probe execution: cells run a measurable synthetic epoch loop and
    the SimClock scaling applies the environment speedup (paper §III)."""

    def __init__(self, registry: EnvironmentRegistry):
        self.envs = registry.envs()
        seed = ("import numpy as np\n"
                "data = np.ones((64, 64))\n"
                "def train(data, epochs=1):\n"
                "    acc = data.copy()\n"
                "    for _ in range(int(epochs)):\n"
                "        acc = acc @ data.T / 64\n"
                "    return acc\n")
        for e in self.envs.values():
            e.execute(seed)

    def probe(self, src: str, env_name: str) -> float:
        import re
        env = self.envs[env_name]
        env.execute(src)  # actually runs (state effects are real)
        e = int(re.search(r"epochs=(\d+)", src).group(1))
        return (BASE + PER_EPOCH * e) / env.speedup  # §III forced timing


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    kb = KnowledgeBase()
    kb.seed("epochs", 50.0)  # expert prior (paper: e=50 hand-seeded)
    # the paper's dyad expressed as a fabric registry: one home env, one
    # remote candidate, a home<->remote link costing the forced 2 minutes
    registry = EnvironmentRegistry.two_env(
        remote_speedup=REMOTE_SPEEDUP, bandwidth=1e15, latency=MIGRATION_TIME)
    an = MigrationAnalyzer(kb, ContextDetector(),
                           migration_latency=MIGRATION_TIME,
                           migration_bandwidth=1e15, registry=registry)
    an.state_size_estimate["default"] = 0.0
    nb = Notebook("dl-train")
    cell = nb.add_cell("model = train(data, epochs=20)")
    rt = _ProbeRuntime(registry)
    updated = an.update_parameters(cell, rt, probe_values=(1, 2, 3),
                                   max_wait=MAX_WAIT)
    thr = updated["epochs"]
    rows.append(("fig11/learned_threshold_epochs", thr,
                 "paper: migration pays off for e > 7"))
    rows.append(("fig11/expert_prior", 50.0, "hand-seeded estimate"))
    rows.append(("fig11/threshold_in_paper_range", float(6.0 < thr < 8.5), ""))
    rec = kb.records("kb-update")[-1]
    ml, mr = rec.params["local"], rec.params["remote"]
    rows.append(("fig11/local_slope", ml[0], "paper: 21.5"))
    rows.append(("fig11/remote_slope", mr[0], "paper: 4.85"))
    rows.append(("fig11/slope_ratio", ml[0] / mr[0], "paper: 4.43x"))
    for e, want in ((3, "local"), (10, "remote"), (50, "remote")):
        c = nb.add_cell(f"model = train(data, epochs={e})")
        d = an.decide(nb, c)
        rows.append((f"fig11/decision_epochs{e}", float(d.env == want),
                     f"expect {want}"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.4f},{note}")
