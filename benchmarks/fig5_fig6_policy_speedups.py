"""Paper Figs. 5 & 6: speedup grids for block-cell and single-cell migration
over (migration time x remote speedup), for both interaction traces.

Each grid point now goes through the fabric's registry API (a two-env
EnvironmentRegistry per point, ``use_registry=True``); the derived scalars
are identical to the paper protocol, so the decisions are unchanged."""
from __future__ import annotations

import numpy as np

from repro.core import TRACES, policy_grid

MIGRATION_TIMES = [0.1, 0.3, 0.5, 0.9, 1.0, 2.0, 5.0, 10.0, 30.0]
REMOTE_SPEEDUPS = [2, 5, 10, 25, 50, 100, 150, 200]


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    mig_times = MIGRATION_TIMES if not smoke else [1.0]
    speedups = REMOTE_SPEEDUPS if not smoke else [50]
    for tname, maker in TRACES.items():
        tr = maker()
        fig = "fig5" if tname == "synthetic-loops" else "fig6"
        grid = policy_grid(tr, mig_times, speedups, use_registry=True)
        for p in ("single", "block"):
            sp = np.array(grid["speedup"][p])
            rows.append((f"{fig}/{tname}/{p}/max_speedup", float(sp.max()),
                         "corner: min mig time, max remote speedup"))
            rows.append((f"{fig}/{tname}/{p}/min_speedup", float(sp.min()), ""))
            # the paper's headline operating point: block-cell gains up to 3.25x
            i, j = mig_times.index(1.0), speedups.index(50)
            rows.append((f"{fig}/{tname}/{p}/speedup@mig1s_rs50",
                         float(sp[i, j]), "paper reports gains up to 3.25x"))
        blk = np.array(grid["speedup"]["block"])
        sng = np.array(grid["speedup"]["single"])
        rows.append((f"{fig}/{tname}/block_ge_single_everywhere",
                     float((blk >= sng * 0.999).all()),
                     "paper: block outperforms single for ALL combinations"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.4f},{note}")
