"""Paper Figs. 8 & 9: block-cell/single-cell speedup ratios over the grid."""
from __future__ import annotations

import numpy as np

from repro.core import TRACES, policy_grid

MIGRATION_TIMES = [0.1, 0.5, 1.0, 2.0, 5.0, 10.0]
REMOTE_SPEEDUPS = [2, 10, 50, 150]


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    mig_times = MIGRATION_TIMES if not smoke else [0.5, 5.0]
    speedups = REMOTE_SPEEDUPS if not smoke else [2, 150]
    for tname, maker in TRACES.items():
        tr = maker()
        fig = "fig8" if tname == "synthetic-loops" else "fig9"
        grid = policy_grid(tr, mig_times, speedups)
        blk = np.array(grid["speedup"]["block"])
        sng = np.array(grid["speedup"]["single"])
        ratio = blk / np.maximum(sng, 1e-9)
        rows.append((f"{fig}/{tname}/ratio_max", float(ratio.max()), ""))
        # paper: ratio ~1 at small remote speedup, rises with speedup
        lo = ratio[:, 0].mean()
        hi = ratio[:, -1].mean()
        rows.append((f"{fig}/{tname}/ratio@low_speedup", float(lo),
                     "paper: close to one when remote speedup is small"))
        rows.append((f"{fig}/{tname}/ratio@high_speedup", float(hi),
                     "paper: rises as the speedup increases"))
        rows.append((f"{fig}/{tname}/ratio_monotone_in_speedup",
                     float(hi >= lo), ""))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.4f},{note}")
