"""Kernel micro-benches (XLA reference path on CPU; Pallas kernels are
TPU-targeted and validated via interpret=True in tests — wall-clock numbers
here time the jnp oracle implementations the dry-run lowers)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

KEY = jax.random.PRNGKey(0)


def _time(fn, *args, iters=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    it = 1 if smoke else 5
    ks = jax.random.split(KEY, 5)

    from repro.kernels.flash_attention.ops import flash_attention
    B, H, S, hd = 1, 4, 512, 64
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, hd), jnp.float32)
    us = _time(lambda *a: flash_attention(*a, impl="xla"), q, k, v, iters=it)
    flops = 4 * B * H * S * S * hd
    rows.append(("kernel/attention_ref_512", us, f"{flops/us/1e3:.1f} GFLOP/s"))

    from repro.kernels.ssd_scan.ops import ssd_scan
    x = jax.random.normal(ks[0], (1, 512, 8, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 512, 8)))
    A = -jnp.exp(jax.random.normal(ks[2], (8,)) * 0.3)
    Bi = jax.random.normal(ks[3], (1, 512, 64), jnp.float32)
    Ci = jax.random.normal(ks[4], (1, 512, 64), jnp.float32)
    us = _time(lambda *a: ssd_scan(*a, chunk=128, impl="xla")[0], x, dt, A, Bi, Ci,
               iters=it)
    rows.append(("kernel/ssd_ref_512", us, "chunked SSD"))

    from repro.kernels.rg_lru.ops import rglru_scan
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 512, 256))) * 0.98
    b = jax.random.normal(ks[1], (2, 512, 256)) * 0.1
    us = _time(lambda *x: rglru_scan(*x, impl="xla")[0], a, b, iters=it)
    rows.append(("kernel/rglru_ref_512", us, "associative scan"))

    from repro.kernels.quant_blockwise.ops import quantize
    big = jax.random.normal(ks[0], (1024, 1024), jnp.float32)
    us = _time(lambda x: quantize(x, impl="xla")[0], big, iters=it)
    rows.append(("kernel/quant8_1M", us, f"{big.nbytes/us*1e6/1e9:.2f} GB/s"))

    from repro.kernels.hash_delta.ops import tensor_digest
    us = _time(lambda x: tensor_digest(x, impl="xla"), big, iters=it)
    rows.append(("kernel/hash_1M", us, f"{big.nbytes/us*1e6/1e9:.2f} GB/s"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.1f},{note}")
