"""Roofline summary rows from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Emits one row per (arch x shape x mesh) cell found under experiments/dryrun.
"""
from __future__ import annotations

import importlib.util
import os

_HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_ROOFLINE = os.path.join(_HERE, "src", "repro", "launch", "roofline.py")

spec = importlib.util.spec_from_file_location("roofline_mod", _ROOFLINE)
R = importlib.util.module_from_spec(spec)
spec.loader.exec_module(R)


def run() -> list[tuple[str, float, str]]:
    d = os.path.join(_HERE, "experiments", "dryrun")
    if not os.path.isdir(d):
        return [("roofline/missing", 0.0, "run repro.launch.dryrun first")]
    rows = []
    for rec in R.load_all(d):
        a = R.analyze(rec)
        if a is None or "skip" in a:
            continue
        key = f"roofline/{a['arch']}/{a['shape']}/{a['mesh']}/{a['tag']}"
        rows.append((key + "/frac", a["roofline_frac"],
                     f"dominant={a['dominant']} useful={a['useful_ratio']:.2f} "
                     f"mem={a['mem_peak_gb']:.1f}GB fits={a['fits_hbm']}"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.4f},{note}")
