"""Benchmark harness: one module per paper table/figure (+ roofline dump).

    PYTHONPATH=src python -m benchmarks.run [--only table2]

Prints ``name,value,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "table2_state_sizes",         # Table II
    "fig5_fig6_policy_speedups",  # Figs 5-6
    "fig8_fig9_ratio",            # Figs 8-9
    "fig10_migration_counts",     # Fig 10
    "fig11_knowledge_policy",     # Fig 11
    "kernel_bench",               # kernels
    "roofline_dump",              # §Roofline table feed
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = 0
    print("name,value,derived")
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{modname}", fromlist=["run"])
            for name, val, note in mod.run():
                print(f"{name},{val},{note}")
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{modname},ERROR,", file=sys.stderr)
        print(f"# {modname}: {time.perf_counter()-t0:.1f}s", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
