"""Benchmark harness: one module per paper table/figure (+ fabric sweeps).

    PYTHONPATH=src python -m benchmarks.run [--only table2] [--smoke]

``--smoke`` runs every module for one tiny iteration (CI-friendly).
Prints ``name,value,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

MODULES = [
    "table2_state_sizes",         # Table II
    "fig5_fig6_policy_speedups",  # Figs 5-6
    "fig8_fig9_ratio",            # Figs 8-9
    "fig10_migration_counts",     # Fig 10
    "fig11_knowledge_policy",     # Fig 11
    "bench_fabric",               # N-env fabric / pipeline / scheduler
    "bench_state_plane",          # CAS chunk delta vs whole-name baseline
    "bench_context",              # interaction models / prefetch gate
    "bench_fleet",                # event-driven fleet: arrivals/failures/scaling
    "kernel_bench",               # kernels
    "roofline_dump",              # §Roofline table feed
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny iteration per benchmark")
    args = ap.parse_args()
    failures = 0
    print("name,value,derived")
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{modname}", fromlist=["run"])
            kw = {}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
                kw["smoke"] = True
            for name, val, note in mod.run(**kw):
                print(f"{name},{val},{note}")
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{modname},ERROR,", file=sys.stderr)
        print(f"# {modname}: {time.perf_counter()-t0:.1f}s", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
