"""Benchmark harness: one module per paper table/figure (+ fabric sweeps).

    PYTHONPATH=src python -m benchmarks.run [--only table2] [--smoke]

``--smoke`` runs every module for one tiny iteration (CI-friendly).
Prints ``name,value,derived`` CSV rows, then a per-benchmark PASS/FAIL
summary on stderr; exits non-zero if any benchmark raised.

Benchmarks that emit a ``BENCH_*.json`` artifact have it *deleted before
they run*: a benchmark that dies mid-list must leave no stale artifact
behind for CI to upload as if it were fresh (the upload step then fails on
the missing file instead).
"""
from __future__ import annotations

import argparse
import inspect
import os
import sys
import time
import traceback

MODULES = [
    "table2_state_sizes",         # Table II
    "fig5_fig6_policy_speedups",  # Figs 5-6
    "fig8_fig9_ratio",            # Figs 8-9
    "fig10_migration_counts",     # Fig 10
    "fig11_knowledge_policy",     # Fig 11
    "bench_fabric",               # N-env fabric / pipeline / scheduler
    "bench_state_plane",          # CAS chunk delta vs whole-name baseline
    "bench_context",              # interaction models / prefetch gate
    "bench_fleet",                # event-driven fleet: arrivals/failures/scaling
    "bench_transport",            # wire protocol: loopback vs socket vs shaped
    "bench_digest",               # batched digest/delta + zero-copy wire
    "bench_live",                 # background delta replication / liveness
    "bench_gateway",              # persistent gateway: 10k-session storm
    "bench_replica",              # replica plane: failover promotion / racing
    "bench_cost",                 # cost plane: dollars DP / spot / data gravity
    "kernel_bench",               # kernels
    "roofline_dump",              # §Roofline table feed
]

# module -> the JSON artifact it (re)writes; used for stale-artifact removal
ARTIFACTS = {
    "bench_state_plane": "BENCH_state_plane.json",
    "bench_context": "BENCH_context.json",
    "bench_fleet": "BENCH_fleet.json",
    "bench_transport": "BENCH_transport.json",
    "bench_digest": "BENCH_digest.json",
    "bench_live": "BENCH_live.json",
    "bench_gateway": "BENCH_gateway.json",
    "bench_replica": "BENCH_replica.json",
    "bench_cost": "BENCH_cost.json",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny iteration per benchmark")
    args = ap.parse_args()
    results: list[tuple[str, bool, float]] = []
    print("name,value,derived")
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        artifact = ARTIFACTS.get(modname)
        if artifact and os.path.exists(artifact):
            os.remove(artifact)          # never upload a stale report
        t0 = time.perf_counter()
        ok = True
        try:
            mod = __import__(f"benchmarks.{modname}", fromlist=["run"])
            kw = {}
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
                kw["smoke"] = True
            for name, val, note in mod.run(**kw):
                print(f"{name},{val},{note}")
        except Exception:  # noqa: BLE001
            ok = False
            traceback.print_exc()
        results.append((modname, ok, time.perf_counter() - t0))
    print("#\n# summary:", file=sys.stderr)
    for modname, ok, secs in results:
        print(f"#   {modname:<28} {'PASS' if ok else 'FAIL':<4} {secs:6.1f}s",
              file=sys.stderr)
    failures = sum(1 for _, ok, _ in results if not ok)
    if failures:
        print(f"# {failures} benchmark(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
