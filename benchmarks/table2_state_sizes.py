"""Paper Table II: notebook state sizes — full vs reduced x raw vs compressed,
both migration directions.

The paper's workload is the Spacenet7 pipeline (720 satellite images loaded,
93 survive filtering, one compute-heavy K-Means cell migrates).  We rebuild
that notebook shape-for-shape at a CPU-friendly scale: a large raw image
stack + intermediate products dominate the full state, while the migrated
cell needs only the filtered subset — the same structural imbalance that
gives the paper its 55x/8x reductions.
"""
from __future__ import annotations


from repro.core import ExecutionEnvironment, MigrationEngine, StateReducer


# scaled Spacenet7-like session: ~180 MB full state instead of ~17 GB
SETUP = """
import numpy as np
rng = np.random.default_rng(0)
# 60 scenes of 256x256x3 uint8 mosaics ("images from 30 regions")
scenes = [rng.integers(0, 255, (256, 256, 3)).astype(np.uint8)
          for _ in range(60)]
# normalized float copies (pipeline intermediates; never needed again)
normalized = [s.astype(np.float32) / 255.0 for s in scenes]
histograms = [np.histogram(s, bins=64)[0] for s in scenes]
# Wasserstein-style distances between adjacent histograms
dists = np.array([np.abs(np.cumsum(a) - np.cumsum(b)).sum()
                  for a, b in zip(histograms, histograms[1:])], np.float64)
threshold = np.quantile(dists, 0.85)
keep_idx = [i for i, d in enumerate(dists) if d > threshold]
filtered = [normalized[i] for i in keep_idx]     # "93 distinct images"
def sobel(img):
    gray = img.mean(axis=-1)
    gx = np.zeros_like(gray); gy = np.zeros_like(gray)
    gx[1:-1] = gray[2:] - gray[:-2]
    gy[:, 1:-1] = gray[:, 2:] - gray[:, :-2]
    return np.sqrt(gx ** 2 + gy ** 2)
edges = [sobel(f) for f in filtered]
k_clusters = 4
"""

# the compute-intensive cell the Migration Analyzer sends remote (K-Means)
KMEANS_CELL = """
centroids_out = []
for img in edges:
    flat = img.reshape(-1, 1)
    cent = np.linspace(flat.min(), flat.max(), k_clusters)[:, None]
    for _ in range(5):
        d = np.abs(flat[None, :, 0] - cent[:, 0:1])
        assign = d.argmin(axis=0)
        for c in range(k_clusters):
            sel = flat[assign == c]
            if len(sel):
                cent[c, 0] = sel.mean()
    centroids_out.append(cent.copy())
"""


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    rows = []
    setup = SETUP
    if smoke:  # tiny scene stack: exercises the pipeline, not the ratios
        setup = setup.replace("(256, 256, 3)", "(32, 32, 3)").replace(
            "for _ in range(60)", "for _ in range(6)")
    local = ExecutionEnvironment("local")
    local.execute(setup)

    import types

    def _no_modules(env, names):
        return {n for n in names
                if not isinstance(env.state.get(n), types.ModuleType)}

    def size(reduce_state: bool, codec: str, direction: str) -> tuple[int, int]:
        """(per-reference bytes, CAS-deduped bytes).

        The paper's Table-II protocol serializes whole names with no
        cross-name sharing — ``ref_nbytes`` reproduces that measurement;
        ``nbytes`` is what the chunk store actually ships (identical arrays
        dedup, e.g. ``filtered`` aliases ``normalized`` entries)."""
        red = StateReducer(codec=codec, reduce_state=reduce_state)
        if direction == "to_remote":
            names, _, _ = red.reduce(local.state, KMEANS_CELL)
            names = _no_modules(local, names)
            ser = red.serialize_names(local.state, names)
            return ser.ref_nbytes, ser.nbytes
        # remote -> local: remote ran the cell; only new/changed return
        remote = ExecutionEnvironment("remote")
        eng = MigrationEngine(red)
        eng.migrate(local, remote, KMEANS_CELL)
        remote.execute(KMEANS_CELL)
        eng.invalidate("remote", {"centroids_out"})
        if reduce_state:
            send, _, _ = red.delta_names(
                remote.state, set(remote.state.names()),
                eng.synced.get("local", {}))
        else:
            send = set(remote.state.names())
        send = _no_modules(remote, send)
        ser = red.serialize_names(remote.state, send, on_error="skip")
        return ser.ref_nbytes, ser.nbytes

    cases = [
        ("local_to_remote/full_state", False, "none", "to_remote"),
        ("local_to_remote/full_state_compressed", False, "zlib", "to_remote"),
        ("local_to_remote/reduced_state", True, "none", "to_remote"),
        ("local_to_remote/reduced_state_compressed", True, "zlib", "to_remote"),
        ("remote_to_local/full_state", False, "none", "back"),
        ("remote_to_local/full_state_compressed", False, "zlib", "back"),
        ("remote_to_local/reduced_delta", True, "none", "back"),
        ("remote_to_local/reduced_delta_compressed", True, "zlib", "back"),
    ]
    sizes, cas_sizes = {}, {}
    for name, reduce_state, codec, direction in cases:
        sizes[name], cas_sizes[name] = size(reduce_state, codec, direction)

    fwd_ratio_raw = sizes["local_to_remote/full_state"] / max(
        sizes["local_to_remote/reduced_state"], 1)
    fwd_ratio_z = sizes["local_to_remote/full_state"] / max(
        sizes["local_to_remote/reduced_state_compressed"], 1)
    back_ratio_raw = sizes["remote_to_local/full_state"] / max(
        sizes["remote_to_local/reduced_delta"], 1)
    back_ratio_z = sizes["remote_to_local/full_state"] / max(
        sizes["remote_to_local/reduced_delta_compressed"], 1)

    for name, _, _, _ in cases:
        rows.append((f"table2/{name}_bytes", sizes[name], ""))
    rows.append(("table2/forward_reduction_raw", fwd_ratio_raw,
                 "paper: 7.8x (17468/2231 MB)"))
    rows.append(("table2/forward_reduction_compressed", fwd_ratio_z,
                 "paper: 55x (17468/320 MB)"))
    rows.append(("table2/back_reduction_raw", back_ratio_raw,
                 "paper: 4.9x (21932/4463 MB)"))
    rows.append(("table2/back_reduction_compressed", back_ratio_z,
                 "paper: 13.3x (21932/1652 MB)"))
    # beyond the paper: cross-name chunk dedup shrinks even the full state
    full = "local_to_remote/full_state"
    rows.append(("table2/cas_full_state_bytes", cas_sizes[full],
                 "CAS-deduped full state (filtered aliases normalized)"))
    rows.append(("table2/cas_dedup_savings_ratio",
                 sizes[full] / max(cas_sizes[full], 1),
                 ">1 = chunk store dedups identical arrays across names"))
    return rows


if __name__ == "__main__":
    for name, val, note in run():
        print(f"{name},{val:.2f},{note}")
