"""The paper end-to-end: a data-science notebook on a hybrid local/remote
setup with context-aware block migration + the knowledge-aware policy —
then the same notebook on a 3-env fabric under the cost-matrix policy.

    PYTHONPATH=src python examples/hybrid_notebook.py
"""
from repro.core import (
    EnvironmentRegistry, ExecutionEnvironment, HybridRuntime, Notebook,
)
from repro.core import telemetry as T

# A Spacenet7-flavored notebook: load -> filter -> heavy cluster -> report.
nb = Notebook("spacenet-mini")
nb.add_cell("""
import numpy as np
rng = np.random.default_rng(0)
scenes = [rng.integers(0, 255, (64, 64, 3)).astype(np.uint8) for _ in range(24)]
""", cost=0.4)
nb.add_cell("""
hists = [np.histogram(s, bins=32)[0] for s in scenes]
dists = np.array([np.abs(np.cumsum(a) - np.cumsum(b)).sum()
                  for a, b in zip(hists, hists[1:])])
keep = [s for s, d in zip(scenes, dists) if d > np.median(dists)]
""", cost=0.8)
nb.add_cell("""
edges = []
for s in keep:
    g = s.mean(axis=-1)
    gx = np.zeros_like(g); gx[1:-1] = g[2:] - g[:-2]
    edges.append(np.abs(gx))
""", cost=1.5)
heavy = nb.add_cell("""
centroids = []
for e in edges:
    flat = e.reshape(-1, 1)
    cent = np.linspace(flat.min(), flat.max() + 1e-6, 4)[:, None]
    for _ in range(8):
        d = np.abs(flat[None, :, 0] - cent[:, 0:1])
        a = d.argmin(axis=0)
        for c in range(4):
            sel = flat[a == c]
            if len(sel):
                cent[c, 0] = sel.mean()
    centroids.append(cent)
""", cost=45.0)
nb.add_cell("summary = float(np.mean([c.mean() for c in centroids]))", cost=0.2)

# the paper's dyad as the smallest environment fabric
registry = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.8)
registry.register(ExecutionEnvironment("local"), home=True)
registry.register(ExecutionEnvironment("remote", speedup=12.0))
rt = HybridRuntime(nb, registry=registry, policy="block", use_knowledge=True)
rt.kb.seed("epochs", 7.0)  # expert-seeded KB entry (knowledge-aware policy)

print("=== three working sessions over the notebook ===")
for session in range(3):
    for i in range(len(nb.cells)):
        rt.run_cell(i)
rt.close()

local_only = 3 * sum(c.cost for c in nb.cells)
print(f"\nlocal-only time : {local_only:9.1f}s")
print(f"hybrid time     : {rt.clock.now():9.1f}s  "
      f"(speedup x{local_only / rt.clock.now():.2f}, "
      f"{rt.migrations} migrations)")
print(f"migrated bytes  : {sum(m.nbytes for m in rt.engine.log)/1e6:9.2f} MB "
      f"(reduced+delta+zlib)")

print("\n=== explainability annotations on the heavy cell ===")
for note in heavy.annotations[-3:]:
    print("  -", note)

print("\n=== provenance (PROV-lite) ===")
for rec in rt.kb.records("migration")[-3:]:
    print(f"  - migration -> {rec.env}: {rec.params['bytes']/1e3:.1f} kB, "
          f"objects {list(rec.used)[:4]}")

# ----------------------------------------------------------------------
# beyond the paper: the same notebook on a 3-env fabric, cost-matrix policy
# ----------------------------------------------------------------------
print("\n=== N-env fabric: cpu-local / gpu-cloud / tpu-mesh (cost policy) ===")
fabric = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.8)
fabric.register(ExecutionEnvironment("local"), home=True)
fabric.register(ExecutionEnvironment("gpu-cloud", speedup=12.0))
fabric.register(ExecutionEnvironment("tpu-mesh", speedup=48.0))
fabric.connect("local", "gpu-cloud", bandwidth=5e8, latency=0.3)
fabric.connect("local", "tpu-mesh", bandwidth=1e8, latency=1.2)

nb3 = Notebook.from_ipynb(nb.to_ipynb())
for c in nb3.cells:
    c.annotations.clear()
rt3 = HybridRuntime(nb3, registry=fabric, policy="cost", use_knowledge=False)
for session in range(3):
    for i in range(len(nb3.cells)):
        rt3.run_cell(i)
rt3.close()

placement = {}
for m in rt3.bus.messages():
    if m.type == T.CELL_EXECUTION_STARTED:
        placement[m.payload["order"]] = m.payload["env"]
for order, env in sorted(placement.items()):
    print(f"  cell {order} ({nb3.cells[order].cost:6.1f}s local) -> {env}")
print(f"  fabric time     : {rt3.clock.now():9.1f}s  "
      f"(speedup x{local_only / rt3.clock.now():.2f} vs "
      f"x{local_only / rt.clock.now():.2f} on the two-env setup)")
