"""Quickstart: the migration stack + a model in five minutes (CPU).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.core import ExecutionEnvironment, MigrationEngine, StateReducer
from repro.models import LM
from repro.optim import adamw_update, init_opt_state

# ----------------------------------------------------------------------
# 1. A model from the assigned pool (reduced config), two training steps.
# ----------------------------------------------------------------------
cfg = get_config("yi-6b", reduced=True)
lm = LM(cfg, max_seq=64)
params = lm.init(jax.random.PRNGKey(0))
tc = TrainConfig(total_steps=10, warmup_steps=2)
opt = init_opt_state(params)
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0, cfg.vocab_size)


@jax.jit
def step(params, opt, batch):
    (loss, _), grads = jax.value_and_grad(lm.loss, has_aux=True)(params, batch)
    opt, params, _ = adamw_update(tc, opt, grads, params)
    return params, opt, loss


for i in range(2):
    params, opt, loss = step(params, opt, {"tokens": toks})
    print(f"train step {i}: loss {float(loss):.4f}")

# ----------------------------------------------------------------------
# 2. Prefill + decode through the same API.
# ----------------------------------------------------------------------
logits, cache = lm.prefill(params, {"tokens": toks[:, :32]}, cache_len=48)
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
for _ in range(3):
    logits, cache = lm.decode_step(params, cache, {"token": tok})
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
print("decoded ids:", tok[:, 0].tolist())

# ----------------------------------------------------------------------
# 3. The paper's state migration: reduced + delta + compressed transfer.
# ----------------------------------------------------------------------
local = ExecutionEnvironment("local")
remote = ExecutionEnvironment("remote", speedup=8.0)
local.execute("""
import numpy as np
corpus = np.arange(200000, dtype=np.float32)   # needed by the cell
scratch = np.zeros((1000, 1000))               # NOT needed -> pruned
def summarize(x):
    return float(x.mean())
""")
engine = MigrationEngine(StateReducer(codec="zlib"), bandwidth=1e9, latency=0.1)
cell = "report = summarize(corpus)"
m1 = engine.migrate(local, remote, cell)
print(f"migration 1: sent {m1.names} ({m1.nbytes/1e3:.1f} kB) — scratch pruned")
m2 = engine.migrate(local, remote, cell)
print(f"migration 2 (delta): sent {m2.names} ({m2.nbytes} B)")
remote.execute(cell)
print("remote result:", remote.state["report"])
