"""Serving + live state migration: a serving session whose model weights and
KV cache migrate between environments mid-stream (the paper's migration as
elastic serving infrastructure — DESIGN.md §1).

    PYTHONPATH=src python examples/serve_migrate.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ExecutionEnvironment, MigrationEngine, StateReducer
from repro.models import LM

cfg = get_config("recurrentgemma-9b", reduced=True)
lm = LM(cfg, max_seq=96)
params = lm.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0, cfg.vocab_size)

# --- serve the prompt on the "edge" environment -----------------------
logits, cache = lm.prefill(params, {"tokens": toks}, cache_len=96)
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
first = []
for _ in range(4):
    logits, cache = lm.decode_step(params, cache, {"token": tok})
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    first.append(int(tok[0, 0]))
print("tokens decoded on edge:", first)

# --- migrate the LIVE serving state to the "pod" environment ----------
edge = ExecutionEnvironment("edge")
pod = ExecutionEnvironment("pod")
edge.state.update({"params": params, "cache": cache, "last_tok": tok})
engine = MigrationEngine(StateReducer(codec="zstd"), bandwidth=5e9, latency=0.2)
res = engine.migrate(edge, pod, names={"params", "cache", "last_tok"})
print(f"migrated serving state: {res.nbytes/1e6:.2f} MB "
      f"(params+cache+cursor) in {res.seconds:.3f}s modeled")

# --- continue decoding on the pod: stream must be seamless ------------
p_params, p_cache, p_tok = (pod.state["params"], pod.state["cache"],
                            pod.state["last_tok"])
p_params = jax.tree_util.tree_map(jnp.asarray, p_params)
p_cache = jax.tree_util.tree_map(jnp.asarray, p_cache)
cont_pod, cont_edge = [], []
tok_e = tok
for _ in range(4):
    logits_p, p_cache = lm.decode_step(p_params, p_cache,
                                       {"token": jnp.asarray(p_tok)})
    p_tok = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
    cont_pod.append(int(p_tok[0, 0]))
    logits_e, cache = lm.decode_step(params, cache, {"token": tok_e})
    tok_e = jnp.argmax(logits_e, -1)[:, None].astype(jnp.int32)
    cont_edge.append(int(tok_e[0, 0]))

print("continuation on pod :", cont_pod)
print("continuation on edge:", cont_edge)
assert cont_pod == cont_edge, "migrated stream diverged!"
print("OK: decode stream identical after live migration")
