"""End-to-end training driver: demo-100m with delta checkpointing + restart.

Default runs the REDUCED config for a fast CPU demo; pass ``--full`` to train
the real ~110M-parameter model (slow on CPU — the config is the point).

    PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--full]
"""
import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] if len(sys.argv) > 1 else [])

from repro.launch.train import main as train_main  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    args, _ = ap.parse_known_args()
    argv = ["train", "--arch", "demo-100m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--ckpt-dir", "/tmp/repro_e2e_ckpt",
            "--ckpt-every", "50", "--resume"]
    if not args.full:
        argv.append("--reduced")
    sys.argv = argv
    train_main()


if __name__ == "__main__":
    main()
