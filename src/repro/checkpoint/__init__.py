from repro.checkpoint.checkpointing import (
    AsyncCheckpointer, Checkpointer, CheckpointInfo,
)

__all__ = ["AsyncCheckpointer", "Checkpointer", "CheckpointInfo"]
