"""Checkpointing *is* migration to a storage environment (DESIGN.md §1).

A checkpoint directory is a ``kind="storage"`` :class:`ExecutionEnvironment`
backed by an on-disk content-addressed chunk store.  ``save`` flattens the
trees and migrates them into that env with the same reducer/engine every
other state transfer uses — per-name delta (unchanged leaves don't
re-serialize), per-chunk dedup (changed leaves re-ship only changed chunks),
tombstones for leaves that disappeared.  Each save then writes one
*self-contained* JSON manifest: every leaf's chunk manifest + digest, so any
step restores without replaying a delta chain and GC is just "drop old
manifests, then drop unreferenced chunks".  Manifests are atomic
tmp->rename; chunk files carry an integrity footer, so corrupted or torn
writes surface on restore.  ``AsyncCheckpointer`` overlaps serialization
with compute (background thread).
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.core.chunkstore import CHUNK_BYTES
from repro.core.fabric import ExecutionEnvironment
from repro.core.migration import MigrationEngine
from repro.core.reducer import SerializedName, SerializedState, StateReducer


def _flatten(tree, prefix: str) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {prefix + jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def _unflatten(template, prefix: str, store: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = [store[prefix + jax.tree_util.keystr(p)] for p, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _meta_to_json(blob: SerializedName) -> dict:
    return {"pickle": blob.pickle_bytes.hex(), "arrays": [
        {**a, "shape": list(a["shape"]),
         **({"scales": a["scales"].hex()} if "scales" in a else {})}
        for a in blob.arrays]}


def _meta_from_json(rec: dict) -> SerializedName:
    arrays = []
    for a in rec["arrays"]:
        a = dict(a)
        a["shape"] = tuple(a["shape"])
        if "scales" in a:
            a["scales"] = bytes.fromhex(a["scales"])
        arrays.append(a)
    return SerializedName(bytes.fromhex(rec["pickle"]), arrays)


@dataclass
class CheckpointInfo:
    step: int
    nbytes: int
    n_leaves_written: int
    n_leaves_total: int
    seconds: float


class Checkpointer:
    def __init__(self, directory: str, codec: str = "zstd", keep: int = 3,
                 delta: bool = True, rebase_every: int = 5,
                 chunk_bytes: int = CHUNK_BYTES):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.reducer = StateReducer(codec=codec, reduce_state=False,
                                    chunk_bytes=chunk_bytes)
        self.codec = codec
        self.keep = keep
        self.rebase_every = max(rebase_every, 1)
        self._count = 0
        # the checkpoint target: a storage env over an on-disk CAS — saving
        # is the same engine call as migrating to any other environment
        self.storage = ExecutionEnvironment("ckpt-storage", kind="storage",
                                            storage_dir=directory)
        self.engine = MigrationEngine(self.reducer, delta=delta)
        self._blob_meta: dict[str, SerializedName] = {}  # leaf -> manifest

    # ------------------------------------------------------------------
    def _manifest_path(self, step: int) -> str:
        return os.path.join(self.dir, f"manifest-{step:08d}.json")

    def save(self, step: int, trees: dict) -> CheckpointInfo:
        """trees: e.g. {"params": params, "opt": opt_state, "data_step": ...}"""
        t0 = time.perf_counter()
        store: dict[str, np.ndarray] = {}
        for k, tree in trees.items():
            store.update(_flatten(tree, k + "/"))
        live = ExecutionEnvironment("ckpt-live", globals_seed=store)
        names = set(store)

        res = self.engine.migrate(live, self.storage, names=names)
        for name in res.deleted:
            self._blob_meta.pop(name, None)
        if self.engine.last_ser is not None:
            self._blob_meta.update(self.engine.last_ser.blobs)

        # every k-th manifest is tagged "full" for operator tooling parity
        # with the pre-CAS delta chains — but *every* manifest is
        # self-contained now, so restore never replays a chain
        full = (self._count % self.rebase_every == 0)
        self._count += 1
        view = self.engine.synced.get(self.storage.name, {})
        manifest = {
            "step": step, "codec": self.codec, "full": full,
            "digests": {n: view[n] for n in names},
            "written": sorted(res.names), "deleted": sorted(res.deleted),
            "names": {n: _meta_to_json(self._blob_meta[n]) for n in names},
            "keys": sorted(trees),
        }
        mtmp = self._manifest_path(step) + ".tmp"
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, self._manifest_path(step))

        self._gc()
        return CheckpointInfo(step, res.nbytes, len(res.names), len(names),
                              time.perf_counter() - t0)

    # ------------------------------------------------------------------
    def _steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.dir):
            if fn.startswith("manifest-") and fn.endswith(".json"):
                out.append(int(fn[len("manifest-"):-len(".json")]))
        return sorted(out)

    def _manifest(self, step: int) -> dict:
        with open(self._manifest_path(step)) as f:
            return json.load(f)

    def _gc(self) -> None:
        """Drop manifests beyond ``keep`` (every one is self-contained),
        then drop chunks no surviving manifest references."""
        steps = self._steps()
        if len(steps) <= self.keep + 1:
            return
        drop, survive = steps[:-(self.keep + 1)], steps[-(self.keep + 1):]
        referenced: set[int] = set()
        for s in survive:
            for rec in self._manifest(s)["names"].values():
                for a in rec["arrays"]:
                    referenced.update(a["chunks"])
        for s in drop:
            p = self._manifest_path(s)
            if os.path.exists(p):
                os.remove(p)
        for d in self.storage.chunk_store.digests() - referenced:
            self.storage.chunk_store.remove(d)

    # ------------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore(self, templates: dict, step: int | None = None) -> tuple[dict, int]:
        """Rebuild from the step's self-contained manifest + the disk CAS;
        verifies chunk integrity footers and per-leaf content digests."""
        steps = self._steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        target = step if step is not None else steps[-1]
        candidates = [x for x in steps if x <= target]
        if not candidates:
            raise FileNotFoundError(f"no checkpoint at or before {target}")
        manifest = self._manifest(candidates[-1])

        blobs = {n: _meta_from_json(rec)
                 for n, rec in manifest["names"].items()}
        ser = SerializedState(codec=manifest["codec"], blobs=blobs)
        store = self.reducer.deserialize(
            ser, chunk_store=self.storage.chunk_store)

        for name, want in manifest["digests"].items():
            if name not in store:
                raise IOError(f"checkpoint missing leaf {name}")
            got = self.reducer.digest(store[name])
            if want != -1 and got != want:
                raise IOError(f"checkpoint digest mismatch for {name}")

        out = {k: _unflatten(t, k + "/", store) for k, t in templates.items()}
        return out, manifest["step"]


class AsyncCheckpointer:
    """Overlap checkpoint writes with compute (single background writer)."""

    def __init__(self, inner: Checkpointer):
        self.inner = inner
        self._thread: threading.Thread | None = None
        self.last_info: CheckpointInfo | None = None

    def save(self, step: int, trees: dict) -> None:
        self.wait()
        # snapshot to host first (cheap on CPU; device_get on TPU)
        host = jax.tree_util.tree_map(np.asarray, trees)

        def run():
            self.last_info = self.inner.save(step, host)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
