"""Delta checkpointing = migration to a disk environment (DESIGN.md §1).

A checkpoint is the paper's reduced/delta/compressed state transfer with the
destination being a directory: the first save writes a full base, subsequent
saves write only leaves whose content digest changed (e.g. params + moments
change every step, frozen embeddings or data buffers don't).  A JSON manifest
carries digests + codec; corrupted or torn writes are detected via the
digests and the atomic tmp->rename protocol.  ``AsyncCheckpointer`` overlaps
serialization with compute (background thread).
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.core.reducer import StateReducer
from repro.core.state import ExecutionState


def _flatten(tree, prefix: str) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {prefix + jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def _unflatten(template, prefix: str, store: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = [store[prefix + jax.tree_util.keystr(p)] for p, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclass
class CheckpointInfo:
    step: int
    nbytes: int
    n_leaves_written: int
    n_leaves_total: int
    seconds: float


class Checkpointer:
    def __init__(self, directory: str, codec: str = "zstd", keep: int = 3,
                 delta: bool = True, rebase_every: int = 5):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.reducer = StateReducer(codec=codec, reduce_state=False)
        self.codec = codec
        self.keep = keep
        self.delta = delta
        self.rebase_every = max(rebase_every, 1)  # every k-th save is FULL
        self._count = 0
        self._known: dict[str, int] = {}     # leaf digests on disk

    # ------------------------------------------------------------------
    def _manifest_path(self, step: int) -> str:
        return os.path.join(self.dir, f"manifest-{step:08d}.json")

    def save(self, step: int, trees: dict) -> CheckpointInfo:
        """trees: e.g. {"params": params, "opt": opt_state, "data_step": ...}"""
        t0 = time.perf_counter()
        store: dict[str, np.ndarray] = {}
        for k, tree in trees.items():
            store.update(_flatten(tree, k + "/"))
        state = ExecutionState(dict(store))
        names = set(store)

        # periodic full saves ("rebase") keep delta chains short and make
        # garbage collection of old deltas safe
        full = (self._count % self.rebase_every == 0) or not self.delta
        self._count += 1
        if full:
            send, dead = set(names), set()
            here = self.reducer.digests(state, names)
        else:
            send, dead, here = self.reducer.delta_names(state, names, self._known)

        ser = self.reducer.serialize_names(state, send)
        blob_path = os.path.join(self.dir, f"delta-{step:08d}.bin")
        tmp = blob_path + ".tmp"
        offsets = {}
        with open(tmp, "wb") as f:
            for name in sorted(ser.blobs):
                b = ser.blobs[name]
                rec = {"pickle": b.pickle_bytes.hex(), "arrays": [
                    {**a, "data": a["data"].hex(),
                     **({"scales": a["scales"].hex()} if "scales" in a else {})}
                    for a in b.arrays]}
                raw = json.dumps(rec).encode()
                offsets[name] = (f.tell(), len(raw))
                f.write(raw)
        os.replace(tmp, blob_path)

        manifest = {
            "step": step, "codec": self.codec, "full": full,
            "digests": {n: here[n] for n in names},
            "written": sorted(send), "deleted": sorted(dead),
            "offsets": offsets,
            "keys": sorted(trees),
        }
        mtmp = self._manifest_path(step) + ".tmp"
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, self._manifest_path(step))

        self._known.update(here)
        self._gc()
        nbytes = os.path.getsize(blob_path)
        return CheckpointInfo(step, nbytes, len(send), len(names),
                              time.perf_counter() - t0)

    # ------------------------------------------------------------------
    def _steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.dir):
            if fn.startswith("manifest-") and fn.endswith(".json"):
                out.append(int(fn[len("manifest-"):-len(".json")]))
        return sorted(out)

    def _manifest(self, step: int) -> dict:
        with open(self._manifest_path(step)) as f:
            return json.load(f)

    def _gc(self) -> None:
        # deleting a middle delta would lose leaves that changed only there,
        # so GC only drops steps strictly older than the newest FULL save
        steps = self._steps()
        if len(steps) <= self.keep + 1:
            return
        fulls = [s for s in steps if self._manifest(s).get("full")]
        if not fulls:
            return
        for s in [x for x in steps if x < fulls[-1]]:
            for pat in (f"manifest-{s:08d}.json", f"delta-{s:08d}.bin"):
                p = os.path.join(self.dir, pat)
                if os.path.exists(p):
                    os.remove(p)

    # ------------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore(self, templates: dict, step: int | None = None) -> tuple[dict, int]:
        """Replay base + deltas up to ``step``; verifies digests."""
        from repro.core.reducer import SerializedName, SerializedState
        steps = self._steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        target = step if step is not None else steps[-1]
        upto = [x for x in steps if x <= target]
        # replay from the newest FULL checkpoint at or before the target
        fulls = [x for x in upto
                 if json.load(open(self._manifest_path(x))).get("full")]
        if fulls:
            upto = [x for x in upto if x >= fulls[-1]]
        store: dict[str, np.ndarray] = {}
        final_manifest = None
        for s in upto:
            with open(self._manifest_path(s)) as f:
                manifest = json.load(f)
            final_manifest = manifest
            blob_path = os.path.join(self.dir, f"delta-{s:08d}.bin")
            with open(blob_path, "rb") as f:
                raw_all = f.read()
            blobs = {}
            for name in manifest["written"]:
                off, ln = manifest["offsets"][name]
                rec = json.loads(raw_all[off:off + ln])
                arrays = []
                for a in rec["arrays"]:
                    a = dict(a)
                    a["data"] = bytes.fromhex(a["data"])
                    if "scales" in a:
                        a["scales"] = bytes.fromhex(a["scales"])
                    a["shape"] = tuple(a["shape"])
                    arrays.append(a)
                blobs[name] = SerializedName(bytes.fromhex(rec["pickle"]), arrays)
            ser = SerializedState(codec=manifest["codec"], blobs=blobs)
            store.update(self.reducer.deserialize(ser))
            for name in manifest["deleted"]:
                store.pop(name, None)

        # integrity check against final manifest digests
        st = ExecutionState(dict(store))
        for name, want in final_manifest["digests"].items():
            if name not in store:
                raise IOError(f"checkpoint missing leaf {name}")
            got = self.reducer.digest(store[name])
            if want != -1 and got != want:
                raise IOError(f"checkpoint digest mismatch for {name}")

        out = {k: _unflatten(t, k + "/", store) for k, t in templates.items()}
        return out, final_manifest["step"]


class AsyncCheckpointer:
    """Overlap checkpoint writes with compute (single background writer)."""

    def __init__(self, inner: Checkpointer):
        self.inner = inner
        self._thread: threading.Thread | None = None
        self.last_info: CheckpointInfo | None = None

    def save(self, step: int, trees: dict) -> None:
        self.wait()
        # snapshot to host first (cheap on CPU; device_get on TPU)
        host = jax.tree_util.tree_map(np.asarray, trees)

        def run():
            self.last_info = self.inner.save(step, host)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
