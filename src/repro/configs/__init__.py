from repro.configs.base import (
    ModelConfig,
    RuntimeConfig,
    ShapeConfig,
    TrainConfig,
)
from repro.configs.registry import ASSIGNED_ARCHS, all_configs, get_config
from repro.configs.shapes import SHAPES, shape_applicable

__all__ = [
    "ModelConfig",
    "RuntimeConfig",
    "ShapeConfig",
    "TrainConfig",
    "ASSIGNED_ARCHS",
    "all_configs",
    "get_config",
    "SHAPES",
    "shape_applicable",
]
