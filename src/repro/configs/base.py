"""Configuration dataclasses for models, shapes, training and runtime.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input-shape suites are :class:`ShapeConfig`.  FULL configs are only
ever lowered abstractly (ShapeDtypeStruct) by the dry-run; smoke tests use the
``reduced()`` variants.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Any

VOCAB_PAD_MULTIPLE = 256  # keeps every padded vocab divisible by the model axis


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None

    # --- positional / norm ---
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0            # stablelm uses partial rotary
    pos_embed: str = "rope"          # rope | learned
    norm_eps: float = 1e-5
    qk_norm: bool = False            # qwen3 style RMSNorm on q,k heads
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    experts_per_tok: int = 0
    shared_expert_d_ff: int = 0      # qwen2-moe shared expert
    norm_topk_prob: bool = False
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001   # load-balance auxiliary loss

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # --- hybrid (recurrentgemma) ---
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    local_window: int = 0                 # sliding-window size for local attn

    # --- encoder/decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0                  # stub frontend output length

    # --- vlm stub frontend ---
    num_patches: int = 0

    # --- training defaults ---
    schedule: str = "cosine"              # cosine | wsd (minicpm)

    dtype: str = "bfloat16"

    # Dry-run cost mode: unroll layer loops + un-chunk attention so XLA's
    # HloCostAnalysis (which counts while-loop bodies ONCE) reports exact
    # FLOPs/bytes/collectives.  Never used for real execution.
    exact_costs: bool = False

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        v = self.vocab_size
        m = VOCAB_PAD_MULTIPLE
        return ((v + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode (500k) is supported."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.local_window > 0:
            return True
        return False

    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer block kind for the decoder stack."""
        if self.family == "ssm":
            return tuple("ssm" for _ in range(self.num_layers))
        if self.block_pattern:
            pat = self.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.num_layers))
        return tuple("attn" for _ in range(self.num_layers))

    # ------------------------------------------------------------------
    # Analytic parameter counts (used by roofline MODEL_FLOPS).
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        d = self.d_model
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        return q + kv + o

    def _ffn_params_dense(self, d_ff: int) -> int:
        return 3 * self.d_model * d_ff  # SwiGLU: gate, up, down

    def _layer_params(self, kind: str) -> int:
        d = self.d_model
        norms = 2 * d
        if kind == "ssm":
            din, ns = self.d_inner, self.ssm_state
            in_proj = d * (2 * din + 2 * ns + self.ssm_heads)
            conv = (din + 2 * ns) * self.conv_width
            extra = 3 * self.ssm_heads  # A_log, D, dt_bias
            out = din * d + din  # out_proj + gated norm
            return in_proj + conv + extra + out + d  # single pre-norm
        if kind == "rec":
            w = self.lru_width
            in_proj = 2 * d * w            # x and gate branches
            conv = w * self.conv_width
            lru = 3 * w                    # Lambda, input gate, rec gate (diag approx)
            lru_gates = 2 * w * (w // 8 if w >= 8 else w)  # block-diag gate proj (8 blocks)
            out = w * d
            ffn = self._ffn_params_dense(self.d_ff)
            return in_proj + conv + lru + lru_gates + out + ffn + norms
        # attention-bearing layer
        attn = self._attn_params()
        if kind == "attn" and self.family == "moe":
            ffn = self.num_experts * self._ffn_params_dense(self.d_ff)
            ffn += self.d_model * self.num_experts  # router
            if self.shared_expert_d_ff:
                ffn += self._ffn_params_dense(self.shared_expert_d_ff) + self.d_model
            return attn + ffn + norms
        return attn + self._ffn_params_dense(self.d_ff) + norms

    def _active_layer_params(self, kind: str) -> int:
        if kind == "attn" and self.family == "moe":
            attn = self._attn_params()
            ffn = self.experts_per_tok * self._ffn_params_dense(self.d_ff)
            ffn += self.d_model * self.num_experts
            if self.shared_expert_d_ff:
                ffn += self._ffn_params_dense(self.shared_expert_d_ff) + self.d_model
            return attn + ffn + 2 * self.d_model
        return self._layer_params(kind)

    def count_params(self, active_only: bool = False) -> int:
        """Analytic parameter count (embeddings use the *unpadded* vocab)."""
        emb = self.vocab_size * self.d_model
        total = emb if self.tie_embeddings else 2 * emb
        f = self._active_layer_params if active_only else self._layer_params
        for kind in self.layer_kinds():
            total += f(kind)
        for _ in range(self.encoder_layers):
            total += self._attn_params() * 2 + self._ffn_params_dense(self.d_ff) + 3 * self.d_model
        total += self.d_model  # final norm
        if self.encoder_seq:
            total += self.encoder_seq * self.d_model  # learned positions (stub frontend side)
        return total

    # ------------------------------------------------------------------
    def reduced(self, **overrides: Any) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        kw: dict[str, Any] = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 3 if not self.block_pattern else len(self.block_pattern)),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads else 0,
            d_ff=96 if self.d_ff else 0,
            head_dim=16 if self.head_dim else None,
            vocab_size=503,  # deliberately odd: exercises vocab padding
        )
        if self.num_experts:
            kw.update(num_experts=8, experts_per_tok=min(self.experts_per_tok, 2))
            if self.shared_expert_d_ff:
                kw.update(shared_expert_d_ff=96)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32)
        if self.lru_width:
            kw.update(lru_width=64, local_window=32)
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_seq=24)
        if self.num_patches:
            kw.update(num_patches=8)
        kw.update(overrides)
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int       # sequence length (train/prefill) or KV-cache length (decode)
    global_batch: int

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.global_batch * self.seq_len


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"         # cosine | wsd
    wsd_decay_frac: float = 0.1
    grad_clip: float = 1.0
    zero1: bool = True               # shard optimizer state over data axis
    remat: str = "dots"              # none | dots | full
    microbatches: int = 1            # gradient accumulation
    vocab_parallel: bool = False     # Megatron-style shard_map embed/loss
    seed: int = 0


@dataclass(frozen=True)
class RuntimeConfig:
    """Migration-runtime knobs (the paper's tool)."""
    compression: str = "zlib"        # none | zlib | zstd | quant8+zstd
    delta_migration: bool = True
    reduce_state: bool = True
    block_policy: bool = True        # block-cell (vs single-cell) migration
    knowledge_policy: bool = True
    migration_bandwidth: float = 1e9   # bytes/s (local<->remote link)
    migration_latency: float = 0.5     # seconds fixed per migration


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
