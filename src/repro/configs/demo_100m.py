"""demo-100m — ~100M-parameter llama-like LM for the end-to-end examples.

Not an assigned architecture; used by examples/train_e2e.py and the hybrid
migration examples (the paper's own workloads are notebook pipelines, so this
plays the role of its "model fitting" cell at a size that trains on CPU).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="demo-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=2048,
    vocab_size=32_768,
    tie_embeddings=True,
)

REDUCED = CONFIG.reduced()
