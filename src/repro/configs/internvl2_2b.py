"""internvl2-2b — InternViT + InternLM2 [arXiv:2404.16821].

LM backbone only (24L d_model=2048 16H GQA kv=8 d_ff=8192 vocab=92553); the
vision frontend is a STUB: ``input_specs()`` supplies precomputed patch
embeddings of shape (batch, num_patches=256, d_model) which the model splices
in front of the text-token embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    head_dim=128,
    num_patches=256,
)

REDUCED = CONFIG.reduced()
