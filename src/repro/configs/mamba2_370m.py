"""mamba2-370m — SSD (state-space duality), attention-free [arXiv:2405.21060].

48L d_model=1024, d_ff=0 (no MLP: Mamba-2 blocks only), vocab=50280,
ssm_state=128, expand=2 -> d_inner=2048, headdim=64 -> 32 SSD heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=32,          # SSD heads (= d_inner / ssm_headdim)
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
)

REDUCED = CONFIG.reduced(num_layers=2)
