"""minicpm-2b — llama-like, trained with the WSD schedule [arXiv:2404.06395].

40L d_model=2304 36H (kv=36: MHA) d_ff=5760 vocab=122753 (odd -> exercises
vocab padding).  The WSD (warmup-stable-decay) schedule is wired into
repro.optim and selected by ``schedule="wsd"``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    head_dim=64,
    tie_embeddings=True,
    schedule="wsd",
)

REDUCED = CONFIG.reduced(schedule="wsd")
