"""phi3-medium-14b — RoPE SwiGLU GQA [arXiv:2404.14219].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
kv=10 is not divisible by the 16-way model axis -> KV replicated under TP
(DESIGN.md §3), decode cache sequence-sharded instead.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17_920,
    vocab_size=100_352,
    head_dim=128,
)

REDUCED = CONFIG.reduced(num_kv_heads=2)
