"""qwen2-moe-a2.7b — 4 shared + 60 routed experts, top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) routed-expert d_ff=1408, shared expert 5632,
vocab=151936.  60 % 16 != 0 -> expert-TP sharding mode (DESIGN.md §3).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151_936,
    head_dim=128,
    rope_theta=1_000_000.0,
    num_experts=60,
    experts_per_tok=4,
    shared_expert_d_ff=5632,
    norm_topk_prob=False,
)

REDUCED = CONFIG.reduced()
