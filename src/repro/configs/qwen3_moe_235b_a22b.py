"""qwen3-moe-235b-a22b — 128 routed experts, top-8, q/k-norm [hf:Qwen/Qwen3 family].

94L d_model=4096 64H (GQA kv=4, head_dim=128) expert d_ff=1536, vocab=151936.
128 % 16 == 0 -> expert-parallel sharding over the model axis.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=151_936,
    head_dim=128,
    rope_theta=1_000_000.0,
    qk_norm=True,
    num_experts=128,
    experts_per_tok=8,
    norm_topk_prob=True,
)

REDUCED = CONFIG.reduced(qk_norm=True)
