"""recurrentgemma-9b — RG-LRU + local attention, (R,R,A) pattern [arXiv:2402.19427].

38L d_model=4096; attention blocks are MQA (kv=1, 16 heads, head_dim=256) with
a 2048-token sliding window; recurrent blocks use RG-LRU with lru_width=4096.
Pattern (rec, rec, attn) repeating: 38 = 12x3 + (rec, rec).
Sub-quadratic -> the long_500k decode cell runs (O(1) LRU state + window cache).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12_288,
    vocab_size=256_000,
    head_dim=256,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    local_window=2048,
)

REDUCED = CONFIG.reduced(num_layers=3)
