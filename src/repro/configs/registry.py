"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

# arch id (as assigned) -> module name
_ARCH_MODULES: dict[str, str] = {
    "mamba2-370m": "mamba2_370m",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "yi-6b": "yi_6b",
    "phi3-medium-14b": "phi3_medium_14b",
    "minicpm-2b": "minicpm_2b",
    "stablelm-12b": "stablelm_12b",
    "internvl2-2b": "internvl2_2b",
    "whisper-tiny": "whisper_tiny",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "demo-100m": "demo_100m",
}

ASSIGNED_ARCHS: tuple[str, ...] = tuple(k for k in _ARCH_MODULES if k != "demo-100m")


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    mod = _module(arch)
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(reduced: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ASSIGNED_ARCHS}
