"""The four assigned input-shape suites and (arch x shape) applicability."""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig

SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", kind="train", seq_len=4_096, global_batch=256),
    "prefill_32k": ShapeConfig("prefill_32k", kind="prefill", seq_len=32_768, global_batch=32),
    "decode_32k": ShapeConfig("decode_32k", kind="decode", seq_len=32_768, global_batch=128),
    "long_500k": ShapeConfig("long_500k", kind="decode", seq_len=524_288, global_batch=1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether this (arch x shape) cell runs, and if not, why (DESIGN.md rule)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; %s is full-attention" % cfg.family
    return True, ""
