"""stablelm-12b — GQA with partial rotary [hf:stabilityai/stablelm-2-12b family].

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352, rotary on 25% of the
head dim (stablelm-2 convention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13_824,
    vocab_size=100_352,
    rope_pct=0.25,
)

REDUCED = CONFIG.reduced()
