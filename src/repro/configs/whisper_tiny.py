"""whisper-tiny — encoder-decoder with conv audio frontend (STUB) [arXiv:2212.04356].

Backbone only: 4 decoder layers + 4 encoder layers, d_model=384 6H (kv=6)
d_ff=1536 vocab=51865.  The conv frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (batch, 1500, d_model).  Learned
absolute positions (whisper convention), no RoPE.  Decoder sequence lengths
beyond whisper's native 448 are a stress configuration mandated by the
assigned shape suites (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    pos_embed="learned",
    encoder_layers=4,
    encoder_seq=1500,
)

REDUCED = CONFIG.reduced()
