"""yi-6b — llama-arch GQA [arXiv:2403.04652].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11_008,
    vocab_size=64_000,
    rope_theta=5_000_000.0,
)

REDUCED = CONFIG.reduced(num_kv_heads=2)
