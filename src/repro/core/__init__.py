# The paper's primary contribution: context-aware execution migration.
from repro.core.analyzer import (
    Decision, MigrationAnalyzer, PerfModel, fit_linear, intersection,
    substitute_kwarg,
)
from repro.core.context import ContextDetector, get_sequences, sequence_stats
from repro.core.kb import KnowledgeBase, ParamEstimate, ProvRecord
from repro.core.migration import (
    ExecutionEnvironment, HybridRuntime, MigrationEngine, MigrationResult,
)
from repro.core.notebook import Cell, Notebook
from repro.core.reducer import (
    SerializationFailure, SerializedState, StateReducer,
)
from repro.core.simclock import SimClock, WallClock
from repro.core.simulator import (
    Trace, TRACES, cell_frequency, policy_grid, simulate,
    synthetic_loops_trace, tf_guide_trace,
)
from repro.core.state import ExecutionState

__all__ = [
    "Decision", "MigrationAnalyzer", "PerfModel", "fit_linear", "intersection",
    "substitute_kwarg", "ContextDetector", "get_sequences", "sequence_stats",
    "KnowledgeBase", "ParamEstimate", "ProvRecord", "ExecutionEnvironment",
    "HybridRuntime", "MigrationEngine", "MigrationResult", "Cell", "Notebook",
    "SerializationFailure", "SerializedState", "StateReducer", "SimClock",
    "WallClock", "Trace", "TRACES", "cell_frequency", "policy_grid",
    "simulate", "synthetic_loops_trace", "tf_guide_trace", "ExecutionState",
]
