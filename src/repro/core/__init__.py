# The paper's primary contribution: context-aware execution migration —
# generalized to an N-environment placement fabric.
from repro.core.analyzer import (
    BlockPolicy, CostMatrixPolicy, Decision, HorizonPolicy, KnowledgePolicy,
    MigrationAnalyzer, PerfModel, PlacementPolicy, SingleCellPolicy,
    fit_linear, intersection, substitute_kwarg,
)
from repro.core.chunkstore import (
    CHUNK_BYTES, DiskChunkStore, MemoryChunkStore, array_chunk_digests,
    digest_bytes, split_chunks,
)
from repro.core.context import ContextDetector, get_sequences, sequence_stats
from repro.core.events import Event, EventLoop
from repro.core.fabric import (
    LIFECYCLE, EnvironmentRegistry, ExecutionEnvironment, Link,
)
from repro.core.gateway import (
    GatewayReport, GatewayService, GatewayTenant, WarmPool, WireFrontend,
    poisson_attach_storm,
)
from repro.core.interaction import (
    MODELS, ConfidenceGate, EnsembleModel, FrequencyModel, InteractionModel,
    MarkovModel, RecencyModel, make_model,
)
from repro.core.kb import KnowledgeBase, ParamEstimate, ProvRecord
from repro.core.migration import (
    EnvFailure, HybridRuntime, MigrationEngine, MigrationResult,
    PipelinedMigrationEngine,
)
from repro.core.notebook import Cell, Notebook
from repro.core.reducer import (
    SerializationFailure, SerializedState, StateReducer,
)
from repro.core.replica import RaceTicket, SessionReplicaSet
from repro.core.scheduler import (
    AutoscalePolicy, CapacityArbiter, ScheduleReport, SessionCheckpointer,
    SessionReport, SessionScheduler, WorkloadTrace, gpu_training_notebook,
    remote_sensing_notebook,
)
from repro.core.simclock import SimClock, WallClock
from repro.core.simulator import (
    Trace, TRACES, cell_frequency, policy_grid, simulate,
    synthetic_loops_trace, tf_guide_trace,
)
from repro.core.state import ExecutionState
from repro.core.transport import (
    TRANSPORTS, DigestMirrorStore, LoopbackTransport, MigrationPeer,
    MuxEnvServer, MuxPeer, MuxStream, SocketTransport, SubprocessEnv,
    TokenBucket, Transport, WireReceiver, attach_peer,
)
from repro.core.wire import Frame, FrameDecoder, WireError

__all__ = [
    "BlockPolicy", "CostMatrixPolicy", "Decision", "HorizonPolicy",
    "KnowledgePolicy",
    "MigrationAnalyzer", "PerfModel", "PlacementPolicy", "SingleCellPolicy",
    "fit_linear", "intersection", "substitute_kwarg", "CHUNK_BYTES",
    "DiskChunkStore", "MemoryChunkStore", "array_chunk_digests",
    "digest_bytes", "split_chunks", "ContextDetector",
    "get_sequences", "sequence_stats", "Event", "EventLoop", "LIFECYCLE",
    "EnvironmentRegistry", "ExecutionEnvironment", "Link",
    "MODELS", "ConfidenceGate", "EnsembleModel", "FrequencyModel",
    "InteractionModel", "MarkovModel", "RecencyModel", "make_model",
    "KnowledgeBase", "ParamEstimate",
    "ProvRecord", "EnvFailure", "HybridRuntime", "MigrationEngine",
    "MigrationResult",
    "PipelinedMigrationEngine", "Cell", "Notebook", "SerializationFailure",
    "SerializedState", "StateReducer", "AutoscalePolicy", "CapacityArbiter",
    "ScheduleReport", "SessionCheckpointer",
    "SessionReport", "SessionScheduler", "WorkloadTrace",
    "gpu_training_notebook", "remote_sensing_notebook", "SimClock",
    "WallClock", "Trace",
    "TRACES", "cell_frequency", "policy_grid", "simulate",
    "synthetic_loops_trace", "tf_guide_trace", "ExecutionState",
    "TRANSPORTS", "DigestMirrorStore", "LoopbackTransport", "MigrationPeer",
    "MuxEnvServer", "MuxPeer", "MuxStream",
    "SocketTransport", "SubprocessEnv", "TokenBucket", "Transport",
    "WireReceiver", "attach_peer", "Frame", "FrameDecoder", "WireError",
    "GatewayReport", "GatewayService", "GatewayTenant", "WarmPool",
    "WireFrontend", "poisson_attach_storm",
    "RaceTicket", "SessionReplicaSet",
]
