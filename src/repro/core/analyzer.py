"""Context-aware migration analyzer (paper §II-C).

Two policy families:

* **Performance-aware** — single-cell (migrate iff remote time + 2 migrations
  beats local) and block-cell (use the context detector's predicted block;
  migrate once per block, return on completion or deviation — Fig. 3).
* **Knowledge-aware** — a KB of cell parameters (epochs, num_steps, ...)
  with thresholds; Algorithm 2 probes small parameter values in both
  environments in the background, fits two linear regressors, and updates the
  threshold to their intersection (Fig. 11).

Every decision carries a human-readable reason that is attached to the cell
as an annotation (explainability, Fig. 1).
"""
from __future__ import annotations

import ast
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

from repro.core.astdeps import analyze_cell
from repro.core.context import ContextDetector
from repro.core.kb import KnowledgeBase, ProvRecord
from repro.core.notebook import Cell, Notebook


@dataclass
class Decision:
    env: str
    migrate: bool
    reason: str
    block: tuple[int, ...] = ()
    policy: str = "performance"


class PerfModel:
    """Observed cell durations per (cell, env) — the 'performance logs of
    previous executions in multiple computing environments' (Fig. 1)."""

    def __init__(self):
        self._obs: dict[tuple[str, str], list[float]] = defaultdict(list)

    def observe(self, cell_id: str, env: str, seconds: float) -> None:
        self._obs[(cell_id, env)].append(float(seconds))

    def estimate(self, cell_id: str, env: str) -> float | None:
        xs = self._obs.get((cell_id, env))
        return float(np.median(xs)) if xs else None


# ----------------------------------------------------------------------
# Algorithm 2 helpers
# ----------------------------------------------------------------------

class _KwargSub(ast.NodeTransformer):
    def __init__(self, param: str, value):
        self.param, self.value = param, value

    def visit_Call(self, node: ast.Call):
        self.generic_visit(node)
        for kw in node.keywords:
            if kw.arg == self.param and isinstance(kw.value, ast.Constant):
                kw.value = ast.Constant(self.value)
        return node


def substitute_kwarg(source: str, param: str, value) -> str:
    tree = _KwargSub(param, value).visit(ast.parse(source))
    ast.fix_missing_locations(tree)
    return ast.unparse(tree)


def fit_linear(xs, ys) -> tuple[float, float]:
    """least-squares slope/intercept (the paper's 'simple and unexpensive'
    linear regressors)."""
    a, b = np.polyfit(np.asarray(xs, float), np.asarray(ys, float), 1)
    return float(a), float(b)


def intersection(m_local: tuple[float, float], m_remote: tuple[float, float],
                 migration_time: float = 0.0) -> float:
    """Parameter value where remote (incl. migration offset) beats local."""
    a_l, b_l = m_local
    a_r, b_r = m_remote
    if a_l <= a_r:
        return float("inf")  # remote never catches up
    return (b_r + migration_time - b_l) / (a_l - a_r)


# ----------------------------------------------------------------------
# the analyzer
# ----------------------------------------------------------------------

class MigrationAnalyzer:
    def __init__(self, kb: KnowledgeBase, context: ContextDetector,
                 perf: PerfModel | None = None, *,
                 policy: str = "block",            # single | block
                 use_knowledge: bool = True,
                 migration_latency: float = 0.5,
                 migration_bandwidth: float = 1e9):
        assert policy in ("single", "block")
        self.kb = kb
        self.context = context
        self.perf = perf or PerfModel()
        self.policy = policy
        self.use_knowledge = use_knowledge
        self.migration_latency = migration_latency
        self.migration_bandwidth = migration_bandwidth
        self.state_size_estimate: dict[str, float] = defaultdict(lambda: 1e6)

    # ------------------------------------------------------------------
    def migration_time(self, nbytes: float) -> float:
        return self.migration_latency + nbytes / self.migration_bandwidth

    def observe_state_size(self, notebook: str, nbytes: float) -> None:
        self.state_size_estimate[notebook] = float(nbytes)

    # ------------------------------------------------------------------
    def _knowledge_decision(self, cell: Cell) -> Decision | None:
        info = analyze_cell(cell.source)
        for fn, kwargs in info.call_kwargs.items():
            for p, v in kwargs.items():
                est = self.kb.get(p)
                if est is None or not isinstance(v, (int, float)):
                    continue
                if v > est.threshold:
                    return Decision(
                        "remote", True,
                        f"knowledge: {fn}({p}={v}) > threshold {est.threshold:.2f} "
                        f"({est.source})", policy="knowledge")
                return Decision(
                    "local", False,
                    f"knowledge: {fn}({p}={v}) <= threshold {est.threshold:.2f} "
                    f"({est.source})", policy="knowledge")
        return None

    def _perf_decision(self, nb: Notebook, cell: Cell) -> Decision:
        order = nb.order(cell.cell_id)
        t_mig = self.migration_time(self.state_size_estimate[nb.name])
        t_loc = self.perf.estimate(cell.cell_id, "local")
        t_rem = self.perf.estimate(cell.cell_id, "remote")
        if t_loc is None or t_rem is None:
            return Decision("local", False,
                            "performance: no history for this cell yet")

        if self.policy == "single":
            if t_rem + 2 * t_mig < t_loc:
                return Decision("remote", True,
                                f"performance/single: remote {t_rem:.2f}s + "
                                f"2x{t_mig:.2f}s migration < local {t_loc:.2f}s")
            return Decision("local", False,
                            f"performance/single: local {t_loc:.2f}s <= remote "
                            f"{t_rem:.2f}s + 2x{t_mig:.2f}s migration")

        # block-cell: sum predicted block costs (Fig. 3)
        block, score, ncand = self.context.predict_block_scored(nb.name, order)
        loc_sum = rem_sum = 0.0
        for o in block:
            if o >= len(nb.cells):
                continue
            c = nb.cells[o]
            tl = self.perf.estimate(c.cell_id, "local")
            tr = self.perf.estimate(c.cell_id, "remote")
            if tl is None or tr is None:
                tl = tr = 0.0
            loc_sum += tl
            rem_sum += tr
        conf = 1.0 if len(block) <= 1 else min(score / 100.0 + 0.5, 1.0)
        if len(block) > 1 and ncand < 2:
            # unproven prediction: commit only on the current cell's own value
            if t_rem + 2 * t_mig < t_loc:
                return Decision("remote", True,
                                f"performance/block: unproven block {block}; "
                                f"cell alone justifies migration "
                                f"({t_rem:.2f}s + 2x{t_mig:.2f}s < {t_loc:.2f}s)",
                                block=block)
            return Decision("local", False,
                            f"performance/block: insufficient context evidence "
                            f"for block {block} ({ncand} candidate sequences)",
                            block=block)
        if rem_sum + 2 * t_mig < conf * loc_sum:
            return Decision("remote", True,
                            f"performance/block: block {block} remote "
                            f"{rem_sum:.2f}s + 2x{t_mig:.2f}s < local {loc_sum:.2f}s",
                            block=block)
        return Decision("local", False,
                        f"performance/block: block {block} local {loc_sum:.2f}s "
                        f"<= remote {rem_sum:.2f}s + 2x{t_mig:.2f}s", block=block)

    def decide(self, nb: Notebook, cell: Cell) -> Decision:
        if self.use_knowledge:
            d = self._knowledge_decision(cell)
            if d is not None:
                cell.annotate(d.reason)
                return d
        d = self._perf_decision(nb, cell)
        cell.annotate(d.reason)
        return d

    # ------------------------------------------------------------------
    # Algorithm 2: dynamic migration parameter update
    # ------------------------------------------------------------------
    def update_parameters(self, cell: Cell, runtime, *, probe_values=(1, 2, 3),
                          max_wait: float | None = None) -> dict[str, float]:
        """Probe small parameter values in both environments, fit the two
        regressors, store the intersection in the KB.  ``runtime`` must expose
        ``probe(cell_source, env_name) -> seconds`` (background execution)."""
        info = analyze_cell(cell.source)
        updated: dict[str, float] = {}
        known = set(self.kb.get_known_parameters())
        for fn, kwargs in info.call_kwargs.items():
            for p in (set(kwargs) & known):
                t_loc, t_rem, used = [], [], []
                budget = max_wait
                for v in probe_values:
                    src = substitute_kwarg(cell.source, p, v)
                    tl = runtime.probe(src, "local")
                    tr = runtime.probe(src, "remote")
                    used.append(v)
                    t_loc.append(tl)
                    t_rem.append(tr)
                    if budget is not None:
                        budget -= max(tl, tr)  # probes run in parallel (§II-C)
                        if budget <= 0:
                            break
                if len(used) < 2:
                    continue
                ml = fit_linear(used, t_loc)
                mr = fit_linear(used, t_rem)
                t_mig = self.migration_time(self.state_size_estimate.get(
                    "default", 1e6))
                opt = intersection(ml, mr, t_mig)
                self.kb.update(p, opt)
                self.kb.record(ProvRecord(
                    "kb-update", cell.cell_id, None, 0.0, 0.0,
                    params={"param": p, "local": ml, "remote": mr,
                            "migration_time": t_mig, "threshold": opt}))
                updated[p] = opt
        return updated
