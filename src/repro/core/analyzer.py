"""Context-aware migration analyzer (paper §II-C) over the environment fabric.

Placement is a pluggable :class:`PlacementPolicy`:

* **Performance-aware** — :class:`SingleCellPolicy` (migrate iff the best
  candidate env's time + round-trip migration beats home) and
  :class:`BlockPolicy` (use the context detector's predicted block; migrate
  once per block, return on completion or deviation — Fig. 3).
* **Knowledge-aware** — :class:`KnowledgePolicy`: a KB of cell parameters
  (epochs, num_steps, ...) with thresholds; Algorithm 2 probes small
  parameter values in both environments in the background, fits two linear
  regressors, and updates the threshold to their intersection (Fig. 11).
* **Cost-matrix** — :class:`CostMatrixPolicy` (beyond the paper): scores
  *every* environment in the registry per cell/block using the per-pair
  link costs — inbound state transfer + modeled execution + return-home —
  and places the cell on the argmin.  This is what lets a third env (e.g. a
  TPU mesh) win the heavy cells while a GPU node keeps the medium ones.

With no registry attached the analyzer degrades to the paper's local/remote
dyad and reproduces its decisions exactly.  Every decision carries a
human-readable reason that is attached to the cell as an annotation
(explainability, Fig. 1).
"""
from __future__ import annotations

import ast
from collections import defaultdict
from dataclasses import dataclass

import numpy as np

from repro.core.astdeps import analyze_cell
from repro.core.context import ContextDetector
from repro.core.kb import KnowledgeBase, ProvRecord
from repro.core.notebook import Cell, Notebook


@dataclass
class Decision:
    env: str
    migrate: bool
    reason: str
    block: tuple[int, ...] = ()
    policy: str = "performance"


class PerfModel:
    """Observed cell durations per (cell, env) — the 'performance logs of
    previous executions in multiple computing environments' (Fig. 1)."""

    def __init__(self):
        self._obs: dict[tuple[str, str], list[float]] = defaultdict(list)

    def observe(self, cell_id: str, env: str, seconds: float) -> None:
        self._obs[(cell_id, env)].append(float(seconds))

    def estimate(self, cell_id: str, env: str) -> float | None:
        xs = self._obs.get((cell_id, env))
        return float(np.median(xs)) if xs else None


# ----------------------------------------------------------------------
# Algorithm 2 helpers
# ----------------------------------------------------------------------

class _KwargSub(ast.NodeTransformer):
    def __init__(self, param: str, value):
        self.param, self.value = param, value

    def visit_Call(self, node: ast.Call):
        self.generic_visit(node)
        for kw in node.keywords:
            if kw.arg == self.param and isinstance(kw.value, ast.Constant):
                kw.value = ast.Constant(self.value)
        return node


def substitute_kwarg(source: str, param: str, value) -> str:
    tree = _KwargSub(param, value).visit(ast.parse(source))
    ast.fix_missing_locations(tree)
    return ast.unparse(tree)


def fit_linear(xs, ys) -> tuple[float, float]:
    """least-squares slope/intercept (the paper's 'simple and unexpensive'
    linear regressors)."""
    a, b = np.polyfit(np.asarray(xs, float), np.asarray(ys, float), 1)
    return float(a), float(b)


def intersection(m_local: tuple[float, float], m_remote: tuple[float, float],
                 migration_time: float = 0.0) -> float:
    """Parameter value where remote (incl. migration offset) beats local."""
    a_l, b_l = m_local
    a_r, b_r = m_remote
    if a_l <= a_r:
        return float("inf")  # remote never catches up
    return (b_r + migration_time - b_l) / (a_l - a_r)


# ----------------------------------------------------------------------
# placement policies
# ----------------------------------------------------------------------

class PlacementPolicy:
    """One placement strategy.  ``decide`` returns a Decision, or None to
    pass the cell on to the next policy in the analyzer's chain."""

    name = "policy"

    def decide(self, an: "MigrationAnalyzer", nb: Notebook, cell: Cell,
               current_env: str) -> Decision | None:
        raise NotImplementedError


class KnowledgePolicy(PlacementPolicy):
    """KB parameter thresholds (the paper's knowledge-aware policy)."""

    name = "knowledge"

    def decide(self, an, nb, cell, current_env):
        info = analyze_cell(cell.source)
        target = an.offload_target()
        for fn, kwargs in info.call_kwargs.items():
            for p, v in kwargs.items():
                est = an.kb.get(p)
                if est is None or not isinstance(v, (int, float)):
                    continue
                if v > est.threshold:
                    return Decision(
                        target, True,
                        f"knowledge: {fn}({p}={v}) > threshold {est.threshold:.2f} "
                        f"({est.source})", policy="knowledge")
                return Decision(
                    an.home, False,
                    f"knowledge: {fn}({p}={v}) <= threshold {est.threshold:.2f} "
                    f"({est.source})", policy="knowledge")
        return None


class SingleCellPolicy(PlacementPolicy):
    """Migrate iff the best candidate env's time + 2 migrations beats home."""

    name = "single"

    def decide(self, an, nb, cell, current_env):
        state = an.state_size_estimate[nb.name]
        t_loc = an.perf.estimate(cell.cell_id, an.home)
        best = None
        for cand in an.candidates():
            t_env = an.perf.estimate(cell.cell_id, cand)
            if t_env is None:
                continue
            t_mig = (an.pair_migration_time(state, an.home, cand)
                     + an.pair_migration_time(state, cand, an.home)) / 2.0
            if best is None or t_env + 2 * t_mig < best[1] + 2 * best[2]:
                best = (cand, t_env, t_mig)
        if t_loc is None or best is None:
            return Decision(an.home, False,
                            "performance: no history for this cell yet")
        cand, t_rem, t_mig = best
        if t_rem + 2 * t_mig < t_loc:
            return Decision(cand, True,
                            f"performance/single: {cand} {t_rem:.2f}s + "
                            f"2x{t_mig:.2f}s migration < local {t_loc:.2f}s")
        return Decision(an.home, False,
                        f"performance/single: local {t_loc:.2f}s <= {cand} "
                        f"{t_rem:.2f}s + 2x{t_mig:.2f}s migration")


class BlockPolicy(PlacementPolicy):
    """Sum predicted block costs; migrate once per block (Fig. 3)."""

    name = "block"

    def decide(self, an, nb, cell, current_env):
        order = nb.order(cell.cell_id)
        state = an.state_size_estimate[nb.name]
        t_loc = an.perf.estimate(cell.cell_id, an.home)
        block, score, ncand = an.context.predict_block_scored(nb.name, order)

        block_cells = [nb.cells[o] for o in block if o < len(nb.cells)]
        home_est = {c.cell_id: an.perf.estimate(c.cell_id, an.home)
                    for c in block_cells}
        best = None
        for cand in an.candidates():
            t_rem = an.perf.estimate(cell.cell_id, cand)
            if t_rem is None:
                continue
            loc_sum = rem_sum = 0.0
            for c in block_cells:
                tl = home_est[c.cell_id]
                tr = an.perf.estimate(c.cell_id, cand)
                if tl is None or tr is None:
                    # a cell unmeasured on either side contributes to neither
                    # sum, keeping the home/candidate comparison paired
                    tl = tr = 0.0
                loc_sum += tl
                rem_sum += tr
            t_mig = (an.pair_migration_time(state, an.home, cand)
                     + an.pair_migration_time(state, cand, an.home)) / 2.0
            if best is None or rem_sum + 2 * t_mig < best[3] + 2 * best[4]:
                best = (cand, t_rem, loc_sum, rem_sum, t_mig)
        if t_loc is None or best is None:
            return Decision(an.home, False,
                            "performance: no history for this cell yet")
        cand, t_rem, loc_sum, rem_sum, t_mig = best

        conf = 1.0 if len(block) <= 1 else min(score / 100.0 + 0.5, 1.0)
        if len(block) > 1 and ncand < 2:
            # unproven prediction: commit only on the current cell's own value
            if t_rem + 2 * t_mig < t_loc:
                return Decision(cand, True,
                                f"performance/block: unproven block {block}; "
                                f"cell alone justifies migration "
                                f"({t_rem:.2f}s + 2x{t_mig:.2f}s < {t_loc:.2f}s)",
                                block=block)
            return Decision(an.home, False,
                            f"performance/block: insufficient context evidence "
                            f"for block {block} ({ncand} candidate sequences)",
                            block=block)
        if rem_sum + 2 * t_mig < conf * loc_sum:
            return Decision(cand, True,
                            f"performance/block: block {block} {cand} "
                            f"{rem_sum:.2f}s + 2x{t_mig:.2f}s < local {loc_sum:.2f}s",
                            block=block)
        return Decision(an.home, False,
                        f"performance/block: block {block} local {loc_sum:.2f}s "
                        f"<= {cand} {rem_sum:.2f}s + 2x{t_mig:.2f}s", block=block)


def _modeled_exec_seconds(an, c: Cell, env_name: str) -> float | None:
    """Estimated execution time of a cell on an env: measured history first,
    else home history (or the declared cost) divided by the env speedup."""
    t = an.perf.estimate(c.cell_id, env_name)
    if t is not None:
        return t
    base = an.perf.estimate(c.cell_id, an.home)
    if base is None:
        base = c.cost
    if base is None:
        return None
    return base / an.registry[env_name].speedup


class CostMatrixPolicy(PlacementPolicy):
    """Score all N environments per cell/block with per-pair link costs.

    cost(e) = transfer(current -> e, state) + exec(block | e)
              + transfer(e -> home, state)      [amortized return]

    Requires a registry (per-pair links + env speedups)."""

    name = "cost"

    def decide(self, an, nb, cell, current_env):
        assert an.registry is not None, "cost-matrix policy needs a registry"
        order = nb.order(cell.cell_id)
        state = an.state_size_estimate[nb.name]
        block, score, ncand = an.context.predict_block_scored(nb.name, order)
        if len(block) > 1 and ncand < 2:
            block = (order,)         # unproven prediction: score the cell alone

        def exec_time(c: Cell, env_name: str) -> float | None:
            return _modeled_exec_seconds(an, c, env_name)

        costs: dict[str, float] = {}
        known_any = False
        for env_name in [an.home] + an.candidates():
            total = an.pair_migration_time(state, current_env, env_name)
            # fleet overhead: a still-provisioning env pays its remaining
            # cold start, a saturated one its expected queue wait — so a
            # cold env is not chosen for a short cell (0 without a fleet)
            total += an.env_overhead(env_name)
            if env_name != an.home:
                total += an.pair_migration_time(state, env_name, an.home)
            for o in block:
                if o >= len(nb.cells):
                    continue
                t = exec_time(nb.cells[o], env_name)
                if t is not None:
                    total += t
                    known_any = True
            costs[env_name] = total
        if not known_any:
            return Decision(an.home, False,
                            "cost-matrix: no history or declared costs yet",
                            policy="cost")
        best = min(costs, key=lambda e: (costs[e], e != an.home))
        matrix = ", ".join(f"{e}={t:.2f}s" for e, t in costs.items())
        if best == current_env:
            return Decision(best, False,
                            f"cost-matrix: stay on {best} [{matrix}]",
                            block=block if best != an.home else (),
                            policy="cost")
        return Decision(best, True,
                        f"cost-matrix: {best} wins [{matrix}]",
                        block=block if best != an.home else (),
                        policy="cost")


class HorizonPolicy(PlacementPolicy):
    """Expected-cost placement over the next H cells (beyond the paper).

    Generalizes :class:`BlockPolicy`/:class:`CostMatrixPolicy`: instead of
    committing to the single most probable block, it chains the interaction
    model's next-cell distribution into per-step cell distributions
    ``d_t`` and runs a dynamic program over (step, env) against the
    fabric's cost matrix::

        V[H][e]  = transfer(e -> home, state)          # amortized return
        V[t][e]  = E_{c ~ d_t}[exec(c | e)]
                   + min_e' ( transfer(e -> e', state) + V[t+1][e'] )

    The decision is the env minimizing ``transfer(current -> e) + V[0][e]``
    — i.e. the placement with minimum *expected* cost over the horizon,
    not just the best response to one predicted path.  Requires a registry
    (per-pair links + env speedups).

    Cost plane (``analyzer.objective == "dollars"``): the same DP runs over
    lexicographic ``(expected dollars, expected seconds)`` step costs.
    Execution on env *e* is priced at ``price_per_hour(e)``; migration legs
    additionally pay the link's per-GB egress; a spot env's step cost is
    surcharged by its hazard-weighted expected recovery cost (``hazard *
    exec_seconds`` expected preemptions, each priced from the fleet's
    recovery ladder — replica promotion, checkpoint restore, or rerun).
    A per-cell latency SLO filters the envs the DP may route through:
    an env whose expected per-cell seconds (incl. hazard recovery) exceed
    the SLO is penalized out unless *no* env attains it.  With all prices
    zero and no hazards the dollar component is uniformly 0.0 and the
    lexicographic comparison degrades to the seconds DP — decisions are
    bit-identical to the seconds-only objective."""

    name = "horizon"

    def __init__(self, horizon: int = 4, *, memoize: bool = True):
        assert horizon >= 1
        self.horizon = int(horizon)
        # one decision queries the model's next-cell distribution for the
        # same cell once per chain step AND again in the block-plan walk;
        # a per-decision memo collapses those to one model call per
        # distinct cell.  Read-only sharing (every consumer iterates the
        # dict), so decisions stay bit-identical — memoize=False keeps the
        # recompute path alive for the equivalence test.
        self.memoize = bool(memoize)
        self.model_calls = 0           # distribution() calls actually made

    # -- helpers ---------------------------------------------------------
    def _dist(self, an, nb, c: int, cache: dict | None) -> dict:
        if cache is None:
            self.model_calls += 1
            return an.context.model.distribution(nb.name, c)
        hit = cache.get(c)
        if hit is None:
            self.model_calls += 1
            hit = cache[c] = an.context.model.distribution(nb.name, c)
        return hit

    def _step_distributions(self, an, nb, order: int,
                            cache: dict | None = None
                            ) -> list[dict[int, float]]:
        """d_0 = {current: 1}; d_{t+1} = d_t chained through the model's
        next-cell distribution, truncated to in-notebook cells."""
        dists: list[dict[int, float]] = [{order: 1.0}]
        d = dists[0]
        for _ in range(1, self.horizon):
            nd: dict[int, float] = defaultdict(float)
            for c, p in d.items():
                for c2, p2 in self._dist(an, nb, c, cache).items():
                    if 0 <= c2 < len(nb.cells):
                        nd[c2] += p * p2
            mass = sum(nd.values())
            if mass <= 1e-9:
                break
            d = {c: p / mass for c, p in sorted(nd.items())}
            dists.append(d)
        return dists

    def decide(self, an, nb, cell, current_env):
        assert an.registry is not None, "horizon policy needs a registry"
        order = nb.order(cell.cell_id)
        state = an.state_size_estimate[nb.name]
        cache: dict | None = {} if self.memoize else None
        dists = self._step_distributions(an, nb, order, cache)
        envs = [an.home] + an.candidates()

        # expected exec cost per (step, env); a cell missing an estimate on
        # ANY env contributes to none, keeping the comparison paired like
        # BlockPolicy (else the only env with evidence would be penalized)
        known_any = False
        expected: list[dict[str, float]] = []
        for d in dists:
            row: dict[str, float] = {e: 0.0 for e in envs}
            for c_order, p in d.items():
                ts = {e: _modeled_exec_seconds(an, nb.cells[c_order], e)
                      for e in envs}
                if any(t is None for t in ts.values()):
                    continue
                for e, t in ts.items():
                    row[e] += p * t
                known_any = True
            expected.append(row)
        if not known_any:
            return Decision(an.home, False,
                            "horizon: no history or declared costs yet",
                            policy="horizon")

        if an.objective == "dollars":
            # the price-aware DP lives on its own path so the seconds-only
            # code below stays float-for-float identical to the seed
            return self._decide_dollars(an, nb, current_env, order, state,
                                        dists, envs, cache)

        # backward DP + argmin successor per (step, env); the terminal V is
        # the amortized return-home transfer
        V = {e: an.pair_migration_time(state, e, an.home) for e in envs}
        succ: list[dict[str, str]] = []
        for t in range(len(dists) - 1, -1, -1):
            nv: dict[str, float] = {}
            ns: dict[str, str] = {}
            for e in envs:
                best_e, best_c = None, None
                for e2 in envs:
                    c = an.pair_migration_time(state, e, e2) + V[e2]
                    if best_c is None or c < best_c - 1e-12:
                        best_e, best_c = e2, c
                nv[e] = expected[t][e] + best_c
                ns[e] = best_e
            succ.append(ns)
            V = nv
        succ.reverse()

        costs = {e: an.pair_migration_time(state, current_env, e) + V[e]
                 + an.env_overhead(e)
                 for e in envs}
        best = min(costs, key=lambda e: (costs[e], e != an.home))
        matrix = ", ".join(f"{e}={t:.2f}s" for e, t in costs.items())

        # block plan: the greedy most-likely cell path for as long as the
        # DP keeps the placement on the chosen env
        block = [order]
        if best != an.home:
            e, c = best, order
            for t in range(1, len(dists)):
                e = succ[t - 1][e]
                if e != best:
                    break
                step = {c2: p for c2, p in self._dist(an, nb, c, cache).items()
                        if 0 <= c2 < len(nb.cells)}
                if not step:
                    break
                c = max(step.items(), key=lambda kv: (kv[1], -kv[0]))[0]
                # blocks are non-decreasing runs: a wrap ends the plan
                if c in block or c < block[-1]:
                    break
                block.append(c)

        if best == current_env:
            return Decision(best, False,
                            f"horizon(H={len(dists)}): stay on {best} "
                            f"[{matrix}]",
                            block=tuple(block) if best != an.home else (),
                            policy="horizon")
        return Decision(best, True,
                        f"horizon(H={len(dists)}): {best} minimizes expected "
                        f"cost [{matrix}]",
                        block=tuple(block) if best != an.home else (),
                        policy="horizon")

    # -- price-aware DP (cost plane) -------------------------------------
    # an env whose expected per-cell latency blows the SLO gets this added
    # to its step dollars: any SLO-feasible route beats it, but if *every*
    # env is infeasible the DP still produces a well-defined argmin
    SLO_PENALTY = 1e15

    @staticmethod
    def _lex_better(cand: tuple[float, float],
                    best: tuple[float, float]) -> bool:
        """Lexicographic (dollars, seconds) with the same 1e-12 epsilon the
        seconds DP uses — so an all-prices-zero fleet reproduces the
        seconds DP's successor choices exactly."""
        if cand[0] < best[0] - 1e-12:
            return True
        if cand[0] > best[0] + 1e-12:
            return False
        return cand[1] < best[1] - 1e-12

    def _decide_dollars(self, an, nb, current_env, order, state, dists,
                        envs, cache):
        """Backward DP over (step, env) minimizing lexicographic
        (expected dollars, expected seconds) subject to the per-cell SLO."""
        # per-(step, env) expected dollars + hazard-adjusted seconds; the
        # pairing rule matches the seconds path: a cell missing an estimate
        # on any env contributes to none
        dol: list[dict[str, float]] = []
        sec: list[dict[str, float]] = []
        for d in dists:
            drow = {e: 0.0 for e in envs}
            srow = {e: 0.0 for e in envs}
            for c_order, p in d.items():
                ts = {e: _modeled_exec_seconds(an, nb.cells[c_order], e)
                      for e in envs}
                if any(t is None for t in ts.values()):
                    continue
                for e, t in ts.items():
                    hs, hd = an.hazard_surcharge(e, t, state)
                    drow[e] += p * (an.exec_dollars(t, e) + hd)
                    srow[e] += p * (t + hs)
            dol.append(drow)
            sec.append(srow)

        # SLO feasibility: worst expected per-cell latency over the horizon
        # (exec + hazard-weighted recovery).  Entry migration and fleet
        # overhead are priced in the objective, not the feasibility test —
        # they hit only the first cell of a block.
        feasible = {e: True for e in envs}
        if an.slo is not None:
            for e in envs:
                lat = max((s[e] for s in sec), default=0.0)
                feasible[e] = lat <= an.slo + 1e-12

        V = {e: (an.transfer_dollars(state, e, an.home),
                 an.pair_migration_time(state, e, an.home)) for e in envs}
        succ: list[dict[str, str]] = []
        for t in range(len(dists) - 1, -1, -1):
            nv: dict[str, tuple[float, float]] = {}
            ns: dict[str, str] = {}
            for e in envs:
                best_e, best_c = None, None
                for e2 in envs:
                    c = (an.transfer_dollars(state, e, e2) + V[e2][0],
                         an.pair_migration_time(state, e, e2) + V[e2][1])
                    if best_c is None or self._lex_better(c, best_c):
                        best_e, best_c = e2, c
                pen = 0.0 if feasible[e] else self.SLO_PENALTY
                nv[e] = (dol[t][e] + pen + best_c[0], sec[t][e] + best_c[1])
                ns[e] = best_e
            succ.append(ns)
            V = nv
        succ.reverse()

        costs = {}
        for e in envs:
            over = an.env_overhead(e)
            pen = 0.0 if feasible[e] else self.SLO_PENALTY
            costs[e] = (an.transfer_dollars(state, current_env, e) + V[e][0]
                        + an.exec_dollars(over, e) + pen,
                        an.pair_migration_time(state, current_env, e)
                        + V[e][1] + over)
        best = min(costs, key=lambda e: (costs[e][0], costs[e][1],
                                         e != an.home))
        slo_note = ""
        if costs[best][0] >= self.SLO_PENALTY:
            # every env blows the SLO: fall back to fastest-expected-seconds
            best = min(costs, key=lambda e: (costs[e][1], e != an.home))
            slo_note = f"; SLO {an.slo:.1f}s unattainable, fastest env chosen"
        matrix = ", ".join(
            f"{e}=${costs[e][0] % self.SLO_PENALTY:.4f}/{costs[e][1]:.2f}s"
            + ("" if feasible[e] else "!slo") for e in envs)

        block = [order]
        if best != an.home:
            e, c = best, order
            for t in range(1, len(dists)):
                e = succ[t - 1][e]
                if e != best:
                    break
                step = {c2: p for c2, p in self._dist(an, nb, c, cache).items()
                        if 0 <= c2 < len(nb.cells)}
                if not step:
                    break
                c = max(step.items(), key=lambda kv: (kv[1], -kv[0]))[0]
                if c in block or c < block[-1]:
                    break
                block.append(c)

        if best == current_env:
            return Decision(best, False,
                            f"horizon-$(H={len(dists)}): stay on {best} "
                            f"[{matrix}]{slo_note}",
                            block=tuple(block) if best != an.home else (),
                            policy="horizon")
        return Decision(best, True,
                        f"horizon-$(H={len(dists)}): {best} minimizes "
                        f"expected dollars [{matrix}]{slo_note}",
                        block=tuple(block) if best != an.home else (),
                        policy="horizon")


POLICIES = {"single": SingleCellPolicy, "block": BlockPolicy,
            "cost": CostMatrixPolicy, "horizon": HorizonPolicy}


# ----------------------------------------------------------------------
# the analyzer
# ----------------------------------------------------------------------

class MigrationAnalyzer:
    def __init__(self, kb: KnowledgeBase, context: ContextDetector,
                 perf: PerfModel | None = None, *,
                 policy: str = "block",    # single | block | cost | horizon
                 use_knowledge: bool = True,
                 migration_latency: float = 0.5,
                 migration_bandwidth: float = 1e9,
                 registry=None,
                 horizon: int = 4,
                 objective: str = "seconds",   # seconds | dollars
                 slo: float | None = None):
        assert policy in POLICIES, policy
        if policy in ("cost", "horizon") and registry is None:
            raise ValueError(f"{policy} policy requires a registry")
        if objective not in ("seconds", "dollars"):
            raise ValueError(f"unknown objective {objective!r} "
                             "(expected 'seconds' or 'dollars')")
        if objective == "dollars" and registry is None:
            raise ValueError("objective='dollars' requires a registry "
                             "(prices live on envs and links)")
        if slo is not None and slo <= 0:
            raise ValueError(f"slo must be > 0 seconds, got {slo}")
        self.kb = kb
        self.context = context
        self.perf = perf or PerfModel()
        self.policy = policy
        self.use_knowledge = use_knowledge
        self.migration_latency = migration_latency
        self.migration_bandwidth = migration_bandwidth
        self.registry = registry
        self.horizon = int(horizon)
        # fleet plane attaches an object with overhead_seconds(env) here so
        # cost/horizon placement prices provisioning delay + queue depth;
        # None (the default) keeps the paper's decisions bit-identical
        self.fleet_view = None
        # live replication attaches an object with residual_bytes(nbytes,
        # src, dst) here so placement prices only the bytes NOT already
        # trickled to the target; None (the default) keeps decisions
        # bit-identical to the unreplicated run
        self.replication_view = None
        # cost plane: "seconds" (the paper's objective) or "dollars"
        # (expected dollars subject to the per-cell latency SLO below)
        self.objective = objective
        self.slo = slo
        # the fleet scheduler attaches an object with
        # expected_recovery(env) -> (seconds, dollars) here, pricing one
        # preemption from its configured recovery ladder (replica
        # promotion / checkpoint restore / rerun); None falls back to a
        # conservative re-ship-and-rerun model
        self.recovery_view = None
        self.state_size_estimate: dict[str, float] = defaultdict(lambda: 1e6)
        self._chain: list[PlacementPolicy] = []
        if use_knowledge:
            self._chain.append(KnowledgePolicy())
        if policy == "horizon":
            self._chain.append(HorizonPolicy(self.horizon))
        else:
            self._chain.append(POLICIES[policy]())

    # -- fabric views ----------------------------------------------------
    @property
    def home(self) -> str:
        return self.registry.home if self.registry is not None else "local"

    def candidates(self) -> list[str]:
        """Placement candidates other than home."""
        if self.registry is not None:
            return self.registry.candidates()
        return ["remote"]

    def offload_target(self) -> str:
        """Default offload env (fastest candidate): the paper's 'remote'."""
        cands = self.candidates()
        if not cands:
            return self.home    # every candidate is down: stay put
        if self.registry is not None and len(cands) > 1:
            return max(cands, key=lambda n: self.registry[n].speedup)
        return cands[0]

    # -- migration cost --------------------------------------------------
    def migration_time(self, nbytes: float) -> float:
        """Home <-> default offload target cost (the paper's scalar model)."""
        if self.registry is not None:
            return self.registry.transfer_seconds(
                self.home, self.offload_target(), nbytes)
        return self.migration_latency + nbytes / self.migration_bandwidth

    def pair_migration_time(self, nbytes: float, src: str, dst: str) -> float:
        if src == dst:
            return 0.0
        if self.replication_view is not None:
            nbytes = self.replication_view.residual_bytes(nbytes, src, dst)
        if self.registry is not None:
            return self.registry.transfer_seconds(src, dst, nbytes)
        return self.migration_latency + nbytes / self.migration_bandwidth

    def observe_state_size(self, notebook: str, nbytes: float) -> None:
        self.state_size_estimate[notebook] = float(nbytes)

    def env_overhead(self, env_name: str) -> float:
        """Fleet-plane surcharge for targeting ``env_name`` right now:
        remaining provisioning cold-start + expected queue wait.  Zero
        without an attached fleet view (the paper's always-on dyad)."""
        if self.fleet_view is None:
            return 0.0
        return float(self.fleet_view.overhead_seconds(env_name))

    # -- cost plane ------------------------------------------------------
    def env_price(self, env_name: str) -> float:
        """Dollars per hour of occupying ``env_name`` (0 without a registry
        — the paper's dyad is free)."""
        if self.registry is None or env_name not in self.registry:
            return 0.0
        return self.registry[env_name].price_per_hour

    def exec_dollars(self, seconds: float, env_name: str) -> float:
        return self.env_price(env_name) * seconds / 3600.0

    def env_hazard(self, env_name: str) -> float:
        """Preemption hazard (events/second) of ``env_name``; 0 = on-demand."""
        if self.registry is None or env_name not in self.registry:
            return 0.0
        return self.registry[env_name].hazard_rate

    def transfer_dollars(self, nbytes: float, src: str, dst: str) -> float:
        """Egress dollars src→dst for the *residual* bytes — the same
        replication discount :meth:`pair_migration_time` applies."""
        if src == dst or self.registry is None:
            return 0.0
        if self.replication_view is not None:
            nbytes = self.replication_view.residual_bytes(nbytes, src, dst)
        return self.registry.transfer_dollars(src, dst, nbytes)

    def hazard_surcharge(self, env_name: str, exec_seconds: float,
                         state_bytes: float) -> tuple[float, float]:
        """Expected (seconds, dollars) a preemption hazard adds to running
        one cell of ``exec_seconds`` on ``env_name``: ``hazard *
        exec_seconds`` expected preemptions, each costing one recovery.
        The recovery is priced from the fleet's ladder when a
        ``recovery_view`` is attached; the fallback models the worst rung —
        re-ship the state from home and rerun the cell."""
        h = self.env_hazard(env_name)
        if h <= 0.0 or exec_seconds <= 0.0:
            return 0.0, 0.0
        if self.recovery_view is not None:
            r_sec, r_dol = self.recovery_view.expected_recovery(env_name)
            r_sec += exec_seconds / 2.0        # expected lost partial work
            r_dol += self.exec_dollars(exec_seconds / 2.0, env_name)
        else:
            r_sec = (self.pair_migration_time(state_bytes, self.home, env_name)
                     + exec_seconds)
            r_dol = (self.transfer_dollars(state_bytes, self.home, env_name)
                     + self.exec_dollars(exec_seconds, env_name))
        n = h * exec_seconds                   # expected preemptions mid-cell
        return n * r_sec, n * r_dol

    # ------------------------------------------------------------------
    def decide(self, nb: Notebook, cell: Cell, *,
               current_env: str | None = None, peek: bool = False) -> Decision:
        """Run the policy chain.  ``peek=True`` skips annotations (used by
        the pipelined engine to predict the next hop without side effects)."""
        current_env = current_env or self.home
        for pol in self._chain:
            d = pol.decide(self, nb, cell, current_env)
            if d is not None:
                if not peek:
                    cell.annotate(d.reason)
                return d
        return Decision(self.home, False, "no policy fired")  # pragma: no cover

    # ------------------------------------------------------------------
    # Algorithm 2: dynamic migration parameter update
    # ------------------------------------------------------------------
    def update_parameters(self, cell: Cell, runtime, *, probe_values=(1, 2, 3),
                          max_wait: float | None = None) -> dict[str, float]:
        """Probe small parameter values in both environments, fit the two
        regressors, store the intersection in the KB.  ``runtime`` must expose
        ``probe(cell_source, env_name) -> seconds`` (background execution)."""
        info = analyze_cell(cell.source)
        updated: dict[str, float] = {}
        known = set(self.kb.get_known_parameters())
        probe_env = self.offload_target()
        for fn, kwargs in info.call_kwargs.items():
            for p in (set(kwargs) & known):
                t_loc, t_rem, used = [], [], []
                budget = max_wait
                for v in probe_values:
                    src = substitute_kwarg(cell.source, p, v)
                    tl = runtime.probe(src, self.home)
                    tr = runtime.probe(src, probe_env)
                    used.append(v)
                    t_loc.append(tl)
                    t_rem.append(tr)
                    if budget is not None:
                        budget -= max(tl, tr)  # probes run in parallel (§II-C)
                        if budget <= 0:
                            break
                if len(used) < 2:
                    continue
                ml = fit_linear(used, t_loc)
                mr = fit_linear(used, t_rem)
                t_mig = self.migration_time(self.state_size_estimate.get(
                    "default", 1e6))
                opt = intersection(ml, mr, t_mig)
                self.kb.update(p, opt)
                self.kb.record(ProvRecord(
                    "kb-update", cell.cell_id, None, 0.0, 0.0,
                    params={"param": p, "local": ml, "remote": mr,
                            "migration_time": t_mig, "threshold": opt}))
                updated[p] = opt
        return updated
