"""AST dependency analysis (paper §II-D) and cell-parameter extraction (§II-C).

``Load`` nodes name the objects a cell reads; resolving them against the
*live* namespace and recursively walking function globals/closures/defaults
builds the dependency closure — run-time analysis, so untaken branches cost
nothing and dynamically-built containers are captured by construction (their
contents serialize with the named object).

``Call`` keyword arguments with constant values (e.g. ``model.fit(epochs=10)``)
feed the Knowledge Base ("Notebook to Knowledge Base" service, PROV-lite).
"""
from __future__ import annotations

import ast
import builtins
import inspect
import types
from dataclasses import dataclass, field
from typing import Any


@dataclass
class CellAnalysis:
    loads: set[str] = field(default_factory=set)
    stores: set[str] = field(default_factory=set)
    call_kwargs: dict[str, dict[str, Any]] = field(default_factory=dict)
    imports: set[str] = field(default_factory=set)


def _dotted(node: ast.AST) -> str | None:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self):
        self.out = CellAnalysis()

    def visit_Name(self, node: ast.Name):
        if isinstance(node.ctx, ast.Load):
            self.out.loads.add(node.id)
        elif isinstance(node.ctx, (ast.Store, ast.Del)):
            self.out.stores.add(node.id)
        self.generic_visit(node)

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self.out.imports.add(a.name.split(".")[0])
            self.out.stores.add((a.asname or a.name).split(".")[0])

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module:
            self.out.imports.add(node.module.split(".")[0])
        for a in node.names:
            self.out.stores.add(a.asname or a.name)

    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func)
        if name:
            kwargs = {}
            for kw in node.keywords:
                if kw.arg is not None and isinstance(kw.value, ast.Constant):
                    kwargs[kw.arg] = kw.value.value
            if kwargs:
                self.out.call_kwargs.setdefault(name, {}).update(kwargs)
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self.out.stores.add(node.name)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node: ast.ClassDef):
        self.out.stores.add(node.name)
        self.generic_visit(node)


def analyze_cell(source: str) -> CellAnalysis:
    tree = ast.parse(source)
    v = _Visitor()
    v.visit(tree)
    # names assigned before use inside this cell are not external deps,
    # but a name can be both (x = x + 1) — keep it as a load then.
    return v.out


_BUILTIN_NAMES = set(dir(builtins))


def _function_refs(fn) -> set[str]:
    """Global names a function (or its nested code objects) references."""
    names: set[str] = set()
    codes = [fn.__code__]
    while codes:
        code = codes.pop()
        names.update(code.co_names)
        for const in code.co_consts:
            if isinstance(const, types.CodeType):
                codes.append(const)
    return names


def dependency_closure(roots: set[str], ns: dict[str, Any]) -> tuple[set[str], set[str]]:
    """Expand root Load-names into the full set of namespace names (and module
    names) the execution depends on (paper: recursive inspection of variable
    definitions, functions, and loaded modules)."""
    needed: set[str] = set()
    modules: set[str] = set()
    work = [r for r in roots if r in ns]
    seen_objs: set[int] = set()

    while work:
        name = work.pop()
        if name in needed:
            continue
        needed.add(name)
        obj = ns[name]
        if id(obj) in seen_objs:
            continue
        seen_objs.add(id(obj))

        if isinstance(obj, types.ModuleType):
            # modules are re-imported on the remote side, never serialized
            modules.add(obj.__name__.split(".")[0])
            continue

        fns = []
        if isinstance(obj, types.FunctionType):
            fns.append(obj)
        elif inspect.isclass(obj):
            fns.extend(f for f in vars(obj).values()
                       if isinstance(f, types.FunctionType))
        elif not isinstance(obj, (int, float, str, bytes, bool, type(None))):
            # instances: walk methods defined on their class
            fns.extend(f for f in vars(type(obj)).values()
                       if isinstance(f, types.FunctionType)
                       and type(obj).__module__ == "__main__")

        for fn in fns:
            for ref in _function_refs(fn):
                if ref in _BUILTIN_NAMES:
                    continue
                if ref in ns and ref not in needed:
                    work.append(ref)
            # closure cells
            if fn.__closure__:
                for cell in fn.__closure__:
                    try:
                        val = cell.cell_contents
                    except ValueError:
                        continue
                    for k, v in ns.items():
                        if v is val and k not in needed:
                            work.append(k)
            # referenced modules
            g = fn.__globals__
            for ref in _function_refs(fn):
                v = g.get(ref)
                if isinstance(v, types.ModuleType):
                    modules.add(v.__name__.split(".")[0])
    return needed, modules


def cell_dependencies(source: str, ns: dict[str, Any]) -> tuple[set[str], set[str], CellAnalysis]:
    """Names (and modules) this cell's execution needs from the namespace."""
    info = analyze_cell(source)
    roots = {n for n in info.loads if n in ns and n not in _BUILTIN_NAMES}
    needed, modules = dependency_closure(roots, ns)
    modules |= info.imports
    needed = {n for n in needed
              if not isinstance(ns.get(n), types.ModuleType)}
    return needed, modules, info
