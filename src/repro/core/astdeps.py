"""AST dependency analysis (paper §II-D) and cell-parameter extraction (§II-C).

``Load`` nodes name the objects a cell reads; resolving them against the
*live* namespace and recursively walking function globals/closures/defaults
builds the dependency closure — run-time analysis, so untaken branches cost
nothing and dynamically-built containers are captured by construction (their
contents serialize with the named object).

``Call`` keyword arguments with constant values (e.g. ``model.fit(epochs=10)``)
feed the Knowledge Base ("Notebook to Knowledge Base" service, PROV-lite).
"""
from __future__ import annotations

import ast
import builtins
import inspect
import types
from dataclasses import dataclass, field
from typing import Any


@dataclass
class CellAnalysis:
    loads: set[str] = field(default_factory=set)
    stores: set[str] = field(default_factory=set)
    call_kwargs: dict[str, dict[str, Any]] = field(default_factory=dict)
    imports: set[str] = field(default_factory=set)


def _dotted(node: ast.AST) -> str | None:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self):
        self.out = CellAnalysis()

    def visit_Name(self, node: ast.Name):
        if isinstance(node.ctx, ast.Load):
            self.out.loads.add(node.id)
        elif isinstance(node.ctx, (ast.Store, ast.Del)):
            self.out.stores.add(node.id)
        self.generic_visit(node)

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self.out.imports.add(a.name.split(".")[0])
            self.out.stores.add((a.asname or a.name).split(".")[0])

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module:
            self.out.imports.add(node.module.split(".")[0])
        for a in node.names:
            self.out.stores.add(a.asname or a.name)

    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func)
        if name:
            kwargs = {}
            for kw in node.keywords:
                if kw.arg is not None and isinstance(kw.value, ast.Constant):
                    kwargs[kw.arg] = kw.value.value
            if kwargs:
                self.out.call_kwargs.setdefault(name, {}).update(kwargs)
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self.out.stores.add(node.name)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node: ast.ClassDef):
        self.out.stores.add(node.name)
        self.generic_visit(node)


def analyze_cell(source: str) -> CellAnalysis:
    tree = ast.parse(source)
    v = _Visitor()
    v.visit(tree)
    # names assigned before use inside this cell are not external deps,
    # but a name can be both (x = x + 1) — keep it as a load then.
    return v.out


_BUILTIN_NAMES = set(dir(builtins))


def _function_refs(fn) -> set[str]:
    """Global names a function (or its nested code objects) references."""
    names: set[str] = set()
    codes = [fn.__code__]
    while codes:
        code = codes.pop()
        names.update(code.co_names)
        for const in code.co_consts:
            if isinstance(const, types.CodeType):
                codes.append(const)
    return names


def dependency_closure(roots: set[str], ns: dict[str, Any]) -> tuple[set[str], set[str]]:
    """Expand root Load-names into the full set of namespace names (and module
    names) the execution depends on (paper: recursive inspection of variable
    definitions, functions, and loaded modules)."""
    needed: set[str] = set()
    modules: set[str] = set()
    work = [r for r in roots if r in ns]
    seen_objs: set[int] = set()

    while work:
        name = work.pop()
        if name in needed:
            continue
        needed.add(name)
        obj = ns[name]
        if id(obj) in seen_objs:
            continue
        seen_objs.add(id(obj))

        if isinstance(obj, types.ModuleType):
            # modules are re-imported on the remote side, never serialized
            modules.add(obj.__name__.split(".")[0])
            continue

        fns = []
        if isinstance(obj, types.FunctionType):
            fns.append(obj)
        elif inspect.isclass(obj):
            fns.extend(f for f in vars(obj).values()
                       if isinstance(f, types.FunctionType))
        elif not isinstance(obj, (int, float, str, bytes, bool, type(None))):
            # instances: walk methods defined on their class
            fns.extend(f for f in vars(type(obj)).values()
                       if isinstance(f, types.FunctionType)
                       and type(obj).__module__ == "__main__")

        for fn in fns:
            for ref in _function_refs(fn):
                if ref in _BUILTIN_NAMES:
                    continue
                if ref in ns and ref not in needed:
                    work.append(ref)
            # closure cells
            if fn.__closure__:
                for cell in fn.__closure__:
                    try:
                        val = cell.cell_contents
                    except ValueError:
                        continue
                    for k, v in ns.items():
                        if v is val and k not in needed:
                            work.append(k)
            # referenced modules
            g = fn.__globals__
            for ref in _function_refs(fn):
                v = g.get(ref)
                if isinstance(v, types.ModuleType):
                    modules.add(v.__name__.split(".")[0])
    return needed, modules


def cell_dependencies(source: str, ns: dict[str, Any]) -> tuple[set[str], set[str], CellAnalysis]:
    """Names (and modules) this cell's execution needs from the namespace."""
    info = analyze_cell(source)
    roots = {n for n in info.loads if n in ns and n not in _BUILTIN_NAMES}
    needed, modules = dependency_closure(roots, ns)
    modules |= info.imports
    needed = {n for n in needed
              if not isinstance(ns.get(n), types.ModuleType)}
    return needed, modules, info


# ----------------------------------------------------------------------
# live-variable analysis over the remaining notebook cells
# ----------------------------------------------------------------------
#
# Classic backward dataflow at cell granularity: live_in = uses ∪
# (live_out − kills).  A name live at the migration point must travel;
# anything else is *provably dead* — no remaining cell can read it before
# (re)defining it — and may be pruned from trickle and migration without
# changing what the remaining cells compute.
#
# Safety is one-sided: ``uses`` over-approximates (every Load anywhere in
# the cell, plus augmented-assignment and ``del`` targets, which need the
# name bound), ``kills`` under-approximates (only *unconditional top-level
# simple-name* bindings end liveness — an assignment inside an ``if`` or a
# loop may never run).  Dynamic constructs that can read arbitrary names
# (``exec``/``eval``, ``globals()``/``locals()``/``vars()``, star-imports)
# or an unparseable cell force the conservative answer: everything lives.

_DYNAMIC_NAMES = frozenset({"exec", "eval", "globals", "locals", "vars",
                            "__import__"})


@dataclass
class LivenessResult:
    """Outcome of :func:`live_roots` over the remaining cells."""
    live: set[str]          # root names live at entry (uses before kills)
    conservative: bool      # True: analysis gave up — treat everything live
    reason: str = ""


class _DefUseVisitor(ast.NodeVisitor):
    """Per-cell gen/kill sets with scope-aware uses.

    ``uses``: names read from the enclosing namespace.  Comprehension
    targets, lambda/function parameters and function-local bindings are
    tracked per scope so a comprehension-local ``i`` does not keep an outer
    ``i`` alive; names declared ``global``/``nonlocal`` stay visible as
    uses/outer bindings.
    """

    def __init__(self):
        self.uses: set[str] = set()
        self.kills: set[str] = set()
        self.dynamic: str | None = None       # reason, when analysis gave up
        self._scopes: list[set[str]] = []     # per-inner-scope local names
        self._declared: list[set[str]] = []   # global/nonlocal per scope

    # -- helpers --------------------------------------------------------
    def _bound_locally(self, name: str) -> bool:
        return any(name in s for s in self._scopes)

    def _use(self, name: str) -> None:
        if not self._bound_locally(name):
            self.uses.add(name)

    def _target_names(self, node: ast.AST) -> list[str]:
        """Simple Name targets of an assignment target tree."""
        if isinstance(node, ast.Name):
            return [node.id]
        if isinstance(node, (ast.Tuple, ast.List)):
            out = []
            for elt in node.elts:
                out.extend(self._target_names(elt))
            return out
        if isinstance(node, ast.Starred):
            return self._target_names(node.value)
        return []

    # -- uses -----------------------------------------------------------
    def visit_Name(self, node: ast.Name):
        if isinstance(node.ctx, ast.Load):
            if node.id in _DYNAMIC_NAMES:
                self.dynamic = f"dynamic construct {node.id!r}"
            self._use(node.id)
        elif isinstance(node.ctx, ast.Del):
            # ``del x`` needs x bound, then unbinds it: a use AND a kill
            # (the kill lands only for top-level Delete statements, below)
            self._use(node.id)
        elif isinstance(node.ctx, ast.Store) and self._scopes:
            if node.id not in self._declared[-1]:
                self._scopes[-1].add(node.id)

    def visit_AugAssign(self, node: ast.AugAssign):
        # ``x += 1``: the target's ctx is Store, but the old value is read
        for name in self._target_names(node.target):
            self._use(name)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if any(a.name == "*" for a in node.names):
            self.dynamic = f"star-import from {node.module!r}"

    def visit_Global(self, node: ast.Global):
        # declared names resolve in the enclosing namespace even inside a
        # function scope: later Stores must not shadow them as locals
        if self._declared:
            self._declared[-1].update(node.names)
        for scope in self._scopes:
            scope.difference_update(node.names)

    visit_Nonlocal = visit_Global

    # -- inner scopes ----------------------------------------------------
    def _visit_scoped(self, bound: set[str], children) -> None:
        self._scopes.append(set(bound))
        self._declared.append(set())
        for child in children:
            self.visit(child)
        self._scopes.pop()
        self._declared.pop()

    def _visit_function(self, node) -> None:
        args = node.args
        params = {a.arg for a in (args.posonlyargs + args.args
                                  + args.kwonlyargs)}
        if args.vararg:
            params.add(args.vararg.arg)
        if args.kwarg:
            params.add(args.kwarg.arg)
        # defaults/annotations/decorators evaluate in the enclosing scope
        for d in list(args.defaults) + [d for d in args.kw_defaults if d]:
            self.visit(d)
        for dec in getattr(node, "decorator_list", []):
            self.visit(dec)
        body = getattr(node, "body", [])
        self._visit_scoped(params, body if isinstance(body, list) else [body])

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self._visit_function(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda):
        self._visit_function(node)

    def _visit_comprehension(self, node) -> None:
        # iterables are visited in the ENCLOSING scope: the first one
        # genuinely evaluates there (``[x for x in x]`` reads the outer
        # ``x``), and treating the nested ones the same way only grows
        # ``uses`` — the safe direction.  Only the element expressions and
        # the filter conditions see the comprehension-local targets.
        for gen in node.generators:
            self.visit(gen.iter)
        bound: set[str] = set()
        for gen in node.generators:
            bound.update(self._target_names(gen.target))
        if isinstance(node, ast.DictComp):
            elts = [node.key, node.value]
        else:
            elts = [node.elt]
        self._visit_scoped(
            bound, elts + [c for g in node.generators for c in g.ifs])

    visit_ListComp = _visit_comprehension
    visit_SetComp = _visit_comprehension
    visit_GeneratorExp = _visit_comprehension
    visit_DictComp = _visit_comprehension


def cell_def_use(source: str) -> tuple[set[str], set[str], str | None]:
    """Per-cell (uses, kills, dynamic_reason).  ``kills`` holds only the
    *certain* top-level bindings; ``dynamic_reason`` is non-None when the
    cell defeats static analysis and everything must be treated live."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return set(), set(), f"unparseable cell: {e.msg}"
    v = _DefUseVisitor()
    v.visit(tree)
    kills: set[str] = set()
    for stmt in tree.body:                 # top-level, unconditional only
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                kills.update(v._target_names(t) if not isinstance(
                    t, (ast.Attribute, ast.Subscript)) else ())
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None \
                and isinstance(stmt.target, ast.Name):
            kills.add(stmt.target.id)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            kills.add(stmt.name)
        elif isinstance(stmt, ast.Import):
            kills.update((a.asname or a.name).split(".")[0]
                         for a in stmt.names)
        elif isinstance(stmt, ast.ImportFrom):
            if not any(a.name == "*" for a in stmt.names):
                kills.update(a.asname or a.name for a in stmt.names)
        elif isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    kills.add(t.id)
    return v.uses, kills, v.dynamic


def live_roots(remaining_sources) -> LivenessResult:
    """Backward dataflow over the remaining cells (in execution order):
    the returned ``live`` set is every name some remaining cell may read
    before rebinding it.  Any dynamic construct in any remaining cell
    forces the conservative result (``conservative=True``)."""
    live: set[str] = set()
    for src in reversed(list(remaining_sources)):
        uses, kills, dynamic = cell_def_use(src)
        if dynamic is not None:
            return LivenessResult(set(), True, dynamic)
        live = uses | (live - kills)
    return LivenessResult(live, False)


def live_names(remaining_sources, ns: dict[str, Any]) -> set[str] | None:
    """Namespace names the remaining cells can reach: the live roots plus
    their dependency closure (a live function pins the globals it reads).
    Returns ``None`` when the analysis is conservative — callers must then
    treat every name as live."""
    result = live_roots(remaining_sources)
    if result.conservative:
        return None
    roots = {n for n in result.live if n in ns and n not in _BUILTIN_NAMES}
    needed, _modules = dependency_closure(roots, ns)
    return needed
