"""Content-addressed chunk store (CAS): the single substrate for state
movement (§II-D generalized to chunk granularity).

Every serialized payload — an array's raw buffer, a quantized buffer, a
pickle stream — is split into fixed-size chunks, each identified by a 64-bit
content digest of its *raw* bytes.  The digest is the address: migration
ships only chunks the receiver's store does not hold, checkpointing is
migration into an on-disk store, and concurrent sessions share one store per
physical environment so a dataset's chunks cross the wire once.

Array buffers (the bulk of notebook state) are what gets chunked: their
digests come from the Pallas ``hash_delta`` per-block digest vector
(:func:`array_chunk_digests`) — per 1024-unit block, two uint32 lanes, so
only digests cross from the device, never the tensor.  Pickle streams are
typically small and travel whole alongside the chunk manifest.
:func:`digest_bytes` is the host-side blake2b utility for content-
addressing arbitrary byte blobs in the same 64-bit keyspace.

Stored values are *encoded* chunks: a 1-byte codec tag + the compressed
bytes, so a chunk written under one codec stays readable when a later
serialization uses another.  :class:`DiskChunkStore` adds an 8-byte blake2b
footer per file (atomic tmp->rename writes) so torn or corrupted chunks are
detected on read.
"""
from __future__ import annotations

import hashlib
import os
import threading
import zlib

import numpy as np

try:
    import zstandard as _zstd
except ImportError:  # pragma: no cover
    _zstd = None

# zstd contexts are reusable but not safe for simultaneous use; one per
# thread keeps the per-chunk hot loop allocation-free (AsyncCheckpointer
# serializes on a background thread while the engine may be migrating)
_TLS = threading.local()


def _zstd_compressor():
    c = getattr(_TLS, "compressor", None)
    if c is None:
        c = _TLS.compressor = _zstd.ZstdCompressor(level=6)
    return c


def _zstd_decompressor():
    d = getattr(_TLS, "decompressor", None)
    if d is None:
        d = _TLS.decompressor = _zstd.ZstdDecompressor()
    return d

CHUNK_BYTES = 1 << 18      # default chunk size: 256 KiB
_BLOCK_BYTES = 1024        # device hash block (== hash_delta.ops.BLOCK bytes)

_CODEC_IDS = {"none": 0, "zlib": 1, "zstd": 2}
_CODEC_NAMES = {v: k for k, v in _CODEC_IDS.items()}


# ----------------------------------------------------------------------
# digests + chunking
# ----------------------------------------------------------------------

def digest_bytes(data: bytes) -> int:
    """64-bit blake2b content digest of a raw byte chunk."""
    return int.from_bytes(hashlib.blake2b(data, digest_size=8).digest(),
                          "little")


def effective_chunk_bytes(n: int, chunk_bytes: int) -> int:
    """The one chunk-boundary rule, shared by splitting and digesting.

    ``chunk_bytes <= 0`` or a payload that fits in one chunk => whole
    payload; otherwise the size is aligned down to the device hash block so
    chunk boundaries coincide with block-digest boundaries."""
    if chunk_bytes <= 0 or chunk_bytes >= n:
        return max(n, 1)
    return max(_BLOCK_BYTES, chunk_bytes - chunk_bytes % _BLOCK_BYTES)


def split_chunks(data: bytes, chunk_bytes: int = CHUNK_BYTES) -> list[bytes]:
    """Fixed-size split; the final chunk may be short."""
    n = len(data)
    if n == 0:
        return []
    eff = effective_chunk_bytes(n, chunk_bytes)
    return [data[i:i + eff] for i in range(0, n, eff)]


def array_chunk_digests(raw: bytes, chunk_bytes: int = CHUNK_BYTES, *,
                        interpret: bool = False,
                        impl: str = "xla") -> list[int]:
    """Per-chunk 64-bit digests of an array's raw buffer via the device
    block-digest vector (aligned 1:1 with :func:`split_chunks`).

    The buffer is hashed once on device (1024-byte blocks, two uint32 lanes
    each); only the (nb, 2) digest vector crosses to the host, where each
    chunk's span of block digests is folded into one digest (chunk length
    mixed in, so zero-padding of the final block cannot alias a shorter
    chunk)."""
    import jax.numpy as jnp

    from repro.kernels.hash_delta.ops import BLOCK, block_digests

    assert BLOCK == _BLOCK_BYTES
    n = len(raw)
    if n == 0:
        return []
    eff = effective_chunk_bytes(n, chunk_bytes)
    buf = np.frombuffer(raw, dtype=np.uint8)
    h2 = np.asarray(block_digests(jnp.asarray(buf), interpret=interpret,
                                  impl=impl), dtype=np.uint64)   # (nb, 2)
    h64 = (h2[:, 1] << np.uint64(32)) | h2[:, 0]
    out = []
    for start in range(0, n, eff):
        clen = min(eff, n - start)
        seg = h64[start // BLOCK:(start + clen + BLOCK - 1) // BLOCK]
        h = hashlib.blake2b(seg.tobytes(), digest_size=8)
        h.update(clen.to_bytes(8, "little"))
        out.append(int.from_bytes(h.digest(), "little"))
    return out


def array_chunk_digests_many(payloads, chunk_bytes: int = CHUNK_BYTES, *,
                             interpret: bool = False, impl: str = "xla",
                             priors=None):
    """Per-chunk digests for *many* raw buffers in one device launch and
    one host sync — bit-identical to calling :func:`array_chunk_digests`
    on each payload.

    Each payload is zero-padded to the device block boundary before
    packing, so its rows of the shared block grid equal the standalone
    rows.  ``priors`` (optional, aligned with ``payloads``) carries
    ``(block_h64, chunk_digests, payload_len)`` tuples from a previous
    digesting of the same logical payload: when the length still matches,
    the fused compare kernel flags unchanged blocks **on device** and any
    chunk whose block span is unchanged reuses its prior digest without a
    host blake2b fold — only flags and lanes ever cross to the host.

    Returns ``(chunk_digest_lists, block_h64_list)``: per payload, its
    chunk digests plus the per-block uint64 digest vector (cacheable as
    the next call's prior)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.hash_delta.ops import (
        note_host_sync, packed_block_digests,
        packed_block_digests_compare, staging_buffer, to_device,
    )

    n = len(payloads)
    if n == 0:
        return [], []
    lens = [len(p) for p in payloads]
    nbs = [(ln + _BLOCK_BYTES - 1) // _BLOCK_BYTES for ln in lens]
    total_nb = sum(nbs)
    if total_nb == 0:                       # every payload empty
        return [[] for _ in payloads], [np.zeros(0, np.uint64)] * n
    # single copy pass: payloads land block-padded in one aligned buffer
    # the device then aliases zero-copy
    host = staging_buffer(total_nb * _BLOCK_BYTES, np.uint8)
    off = 0
    for p, nb in zip(payloads, nbs):
        end = off + len(p)
        host[off:end] = np.frombuffer(p, dtype=np.uint8)
        off += nb * _BLOCK_BYTES
        if off != end:
            host[end:off] = 0
    packed = to_device(host)

    use_cmp = priors is not None and any(pr is not None for pr in priors)
    if use_cmp:
        prior_lanes = np.zeros((total_nb, 2), np.uint32)
        has = np.zeros((total_nb, 1), np.uint32)
        off = 0
        for i, pr in enumerate(priors):
            nb = nbs[i]
            if pr is not None and pr[2] == lens[i] and len(pr[0]) == nb:
                h64 = np.asarray(pr[0], np.uint64)
                prior_lanes[off:off + nb, 0] = (
                    h64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
                prior_lanes[off:off + nb, 1] = (
                    h64 >> np.uint64(32)).astype(np.uint32)
                has[off:off + nb, 0] = 1
            off += nb
        h2, ch = jax.device_get(packed_block_digests_compare(
            packed, jnp.asarray(prior_lanes), jnp.asarray(has),
            interpret=interpret, impl=impl))
        changed = ch[:, 0].astype(bool)
    else:
        h2 = np.asarray(packed_block_digests(
            packed, interpret=interpret, impl=impl))
        changed = np.ones(total_nb, bool)
    note_host_sync()
    h2 = h2.astype(np.uint64)
    h64_all = (h2[:, 1] << np.uint64(32)) | h2[:, 0]

    out_chunks, out_h64 = [], []
    off = 0
    for i in range(n):
        nb, nlen = nbs[i], lens[i]
        h64 = h64_all[off:off + nb]
        ch_i = changed[off:off + nb]
        off += nb
        out_h64.append(h64)
        if nlen == 0:
            out_chunks.append([])
            continue
        eff = effective_chunk_bytes(nlen, chunk_bytes)
        pr = priors[i] if use_cmp else None
        reuse = (pr is not None and pr[2] == nlen and len(pr[0]) == nb)
        digs = []
        for ci, start in enumerate(range(0, nlen, eff)):
            clen = min(eff, nlen - start)
            b0 = start // _BLOCK_BYTES
            b1 = (start + clen + _BLOCK_BYTES - 1) // _BLOCK_BYTES
            if reuse and ci < len(pr[1]) and not ch_i[b0:b1].any():
                digs.append(pr[1][ci])      # exact: lanes matched on device
            else:
                h = hashlib.blake2b(h64[b0:b1].tobytes(), digest_size=8)
                h.update(clen.to_bytes(8, "little"))
                digs.append(int.from_bytes(h.digest(), "little"))
        out_chunks.append(digs)
    return out_chunks, out_h64


# ----------------------------------------------------------------------
# chunk encoding (codec-tagged, self-describing)
# ----------------------------------------------------------------------

def encode_chunk(raw: bytes, codec: str) -> bytes:
    """Raw chunk -> 1-byte codec tag + compressed bytes.  The tag records
    what was *actually* used (zstd falls back to zlib when unavailable), so
    decoding never depends on the requesting serialization's codec."""
    if codec == "none":
        return bytes([_CODEC_IDS["none"]]) + raw
    if codec in ("zstd", "quant8+zstd") and _zstd is not None:
        return bytes([_CODEC_IDS["zstd"]]) + _zstd_compressor().compress(raw)
    return bytes([_CODEC_IDS["zlib"]]) + zlib.compress(raw, level=6)


def decode_chunk(data: bytes) -> bytes:
    codec = _CODEC_NAMES[data[0]]
    body = data[1:]
    if codec == "none":
        return body
    if codec == "zstd":
        if _zstd is None:
            raise IOError("chunk was written with zstd but zstandard "
                          "is not installed")
        return _zstd_decompressor().decompress(body)
    return zlib.decompress(body)


# ----------------------------------------------------------------------
# stores
# ----------------------------------------------------------------------

class MemoryChunkStore:
    """In-memory CAS: digest -> encoded chunk.  Chunks are immutable, so one
    store may safely back any number of sessions/environments.

    Bounded: superseded chunk generations (every version of a mutating
    array ever migrated) would otherwise accumulate for the session's
    lifetime, so the store evicts least-recently-touched chunks past
    ``max_bytes``.  Eviction is always safe — a missing chunk is simply
    re-shipped by the next migration that references it."""

    def __init__(self, max_bytes: int = 1 << 30):
        self._chunks: dict[int, bytes] = {}     # insertion = recency order
        self.max_bytes = int(max_bytes)
        self._nbytes = 0

    def _touch(self, d: int) -> None:
        self._chunks[d] = self._chunks.pop(d)   # move to most-recent end

    def has(self, d: int) -> bool:
        if d in self._chunks:
            self._touch(d)
            return True
        return False

    def get(self, d: int) -> bytes:
        data = self._chunks[d]
        self._touch(d)
        return data

    def put(self, d: int, data: bytes) -> None:
        if d in self._chunks:
            self._touch(d)
            return
        if not isinstance(data, bytes):
            # zero-copy wire payloads arrive as memoryviews into transient
            # recv buffers; the store must own its bytes — this is the one
            # place the copy is required, so it happens here and only here
            data = bytes(data)
        self._chunks[d] = data
        self._nbytes += len(data)
        while self._nbytes > self.max_bytes and len(self._chunks) > 1:
            old = next(iter(self._chunks))
            if old == d:                        # never evict the newcomer
                break
            self._nbytes -= len(self._chunks.pop(old))

    def put_many(self, chunks: dict[int, bytes]) -> None:
        for d, c in chunks.items():
            self.put(d, c)

    # -- wire ingestion --------------------------------------------------
    def ingest_frame(self, frame) -> int:
        """Ingest one CHUNK wire frame incrementally (transport plane):
        the frame's store-encoded body lands under its digest.  A payload
        whose codec tag is unknown is rejected as a WireError before it can
        poison the store."""
        from repro.core.wire import WireError, parse_chunk
        d, encoded = parse_chunk(frame)
        if not encoded or encoded[0] not in _CODEC_NAMES:
            raise WireError(
                f"chunk {d:016x}: unknown codec tag "
                f"{encoded[0] if encoded else None!r}")
        self.put(d, encoded)
        return d

    def ingest_frames(self, frames) -> tuple[int, int]:
        """Ingest a CHUNK-frame iterable; returns (chunks, encoded bytes)."""
        count = nbytes = 0
        for f in frames:
            d = self.ingest_frame(f)
            count += 1
            nbytes += f.payload_len - 8       # minus the digest prefix
        return count, nbytes

    def digests(self) -> set[int]:
        return set(self._chunks)

    def __len__(self) -> int:
        return len(self._chunks)

    @property
    def nbytes(self) -> int:
        return self._nbytes


class DiskChunkStore(MemoryChunkStore):
    """On-disk CAS directory: one ``chunk-<16 hex>.bin`` file per chunk.

    Writes are atomic (tmp -> rename) and append an 8-byte blake2b footer
    over the stored bytes; :meth:`get` verifies it, so torn writes and
    bit-flips surface as ``IOError`` instead of corrupt restores."""

    def __init__(self, directory: str):
        super().__init__()
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, d: int) -> str:
        return os.path.join(self.dir, f"chunk-{d:016x}.bin")

    def has(self, d: int) -> bool:
        return os.path.exists(self._path(d))

    def get(self, d: int) -> bytes:
        with open(self._path(d), "rb") as f:
            data = f.read()
        body, footer = data[:-8], data[-8:]
        if hashlib.blake2b(body, digest_size=8).digest() != footer:
            raise IOError(f"chunk {d:016x} failed its integrity check")
        return body

    def put(self, d: int, data: bytes) -> None:
        path = self._path(d)
        if os.path.exists(path):
            return                       # content-addressed: already correct
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
            f.write(hashlib.blake2b(data, digest_size=8).digest())
        os.replace(tmp, path)

    def remove(self, d: int) -> None:
        try:
            os.remove(self._path(d))
        except FileNotFoundError:
            pass

    def digests(self) -> set[int]:
        out = set()
        for fn in os.listdir(self.dir):
            if fn.startswith("chunk-") and fn.endswith(".bin"):
                out.add(int(fn[len("chunk-"):-len(".bin")], 16))
        return out

    def __len__(self) -> int:
        return len(self.digests())

    @property
    def nbytes(self) -> int:
        return sum(os.path.getsize(self._path(d)) for d in self.digests())
