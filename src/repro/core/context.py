"""Context detector (paper §II-B, Algorithm 1).

Mines the history of cell-order interactions for non-decreasing sequences,
scores them by subset-counted frequency, and predicts the block of cells the
user is about to execute (consumed by the block-cell migration policy)."""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field

from repro.core import telemetry as T


def get_sequences(history_order: list[int]) -> list[tuple[int, ...]]:
    """Split a cell-order interaction history into non-decreasing runs.

    Paper example: 1,2,3,2,3 -> (1,2,3), (2,3): a new sequence starts
    whenever the ongoing one is broken (next order < current)."""
    seqs: list[tuple[int, ...]] = []
    cur: list[int] = []
    for o in history_order:
        if cur and o < cur[-1]:
            seqs.append(tuple(cur))
            cur = []
        cur.append(o)
    if cur:
        seqs.append(tuple(cur))
    return seqs


def _contiguous_subseq(a: tuple, b: tuple) -> bool:
    """a is a contiguous subsequence of b."""
    n, m = len(a), len(b)
    if n > m:
        return False
    return any(b[i:i + n] == a for i in range(m - n + 1))


def sequence_stats(history_order: list[int],
                   current_order: int | None = None) -> dict[tuple[int, ...], float]:
    """Algorithm 1: score sequences by frequency (%), optionally restricted to
    sequences containing the current active cell."""
    sequences = get_sequences(history_order)
    if current_order is not None:
        sequences = [s for s in sequences if current_order in s]
    if not sequences:
        return {}

    counts: dict[tuple[int, ...], int] = defaultdict(int)
    for s in sequences:
        counts[s] += 1  # duplicates removed but counted (lines 7-11)

    stats: dict[tuple[int, ...], int] = {}
    total = 0
    for s in sorted(counts, key=len):  # increasing length (line 4)
        subtotal = counts[s]
        for o in counts:
            if o != s and _contiguous_subseq(s, o):
                subtotal += counts[o]
        stats[s] = subtotal
        total += subtotal

    return {s: v / total * 100.0 for s, v in stats.items()}  # lines 14-15


@dataclass
class ContextDetector:
    """Subscribes to the MQ bus; tracks per-notebook interaction history."""
    history: dict[str, list[int]] = field(default_factory=lambda: defaultdict(list))
    _cell_order: dict[str, dict[str, int]] = field(default_factory=dict)

    def attach(self, bus: T.MQBus, topic: str = "telemetry") -> None:
        bus.subscribe(topic, self.on_message)

    def on_message(self, msg: T.TelemetryMessage) -> None:
        if msg.type != T.CELL_EXECUTION_COMPLETED or msg.cell_id is None:
            return
        order = msg.payload.get("order")
        if order is None:
            order = list(msg.cell_ids).index(msg.cell_id)
        self.history[msg.notebook].append(int(order))

    # ------------------------------------------------------------------
    def record(self, notebook: str, order: int) -> None:
        self.history[notebook].append(order)

    def stats(self, notebook: str, current_order: int | None = None):
        return sequence_stats(self.history[notebook], current_order)

    def predict_block(self, notebook: str, current_order: int) -> tuple[int, ...]:
        """Most probable previously-seen sequence containing the current cell;
        returns the cells from the current one onward (the upcoming block)."""
        return self.predict_block_scored(notebook, current_order)[0]

    def predict_block_scored(
            self, notebook: str, current_order: int,
    ) -> tuple[tuple[int, ...], float, int]:
        """(block, score%, n_candidates) — score is the Algorithm-1 frequency
        of the chosen sequence; n_candidates (distinct sequences containing
        the cell) gauges how much evidence the prediction rests on."""
        stats = self.stats(notebook, current_order)
        if not stats:
            return (current_order,), 0.0, 0
        best, score = max(stats.items(), key=lambda kv: (kv[1], len(kv[0])))
        i = best.index(current_order)
        return best[i:], score, len(stats)

    def predict_next(self, notebook: str, current_order: int) -> int | None:
        """The cell most likely to run *after* the current one (the element
        following it in the most probable sequence) — used by the pipelined
        engine to prefetch the next hop's state during execution."""
        block = self.predict_block(notebook, current_order)
        if len(block) > 1:
            return block[1]
        return None
