"""Context detector (paper §II-B, Algorithm 1).

The detector is now a thin telemetry-bus adapter over a pluggable
:class:`~repro.core.interaction.InteractionModel` (default:
:class:`~repro.core.interaction.FrequencyModel`, the incremental Algorithm 1
— bit-identical decisions to the original per-query rescan).  The module
keeps the reference implementation of Algorithm 1 (:func:`get_sequences` /
:func:`sequence_stats`) as pure functions over a history list: they are the
specification the incremental model is property-tested against.
"""
from __future__ import annotations

from collections import defaultdict

from repro.core import telemetry as T
from repro.core.interaction import (
    FrequencyModel, InteractionModel, _contiguous_subseq, make_model,
)


def get_sequences(history_order: list[int]) -> list[tuple[int, ...]]:
    """Split a cell-order interaction history into non-decreasing runs.

    Paper example: 1,2,3,2,3 -> (1,2,3), (2,3): a new sequence starts
    whenever the ongoing one is broken (next order < current)."""
    seqs: list[tuple[int, ...]] = []
    cur: list[int] = []
    for o in history_order:
        if cur and o < cur[-1]:
            seqs.append(tuple(cur))
            cur = []
        cur.append(o)
    if cur:
        seqs.append(tuple(cur))
    return seqs


def sequence_stats(history_order: list[int],
                   current_order: int | None = None) -> dict[tuple[int, ...], float]:
    """Algorithm 1: score sequences by frequency (%), optionally restricted to
    sequences containing the current active cell."""
    sequences = get_sequences(history_order)
    if current_order is not None:
        sequences = [s for s in sequences if current_order in s]
    if not sequences:
        return {}

    counts: dict[tuple[int, ...], int] = defaultdict(int)
    for s in sequences:
        counts[s] += 1  # duplicates removed but counted (lines 7-11)

    stats: dict[tuple[int, ...], float] = {}
    total = 0
    for s in sorted(counts, key=len):  # increasing length (line 4)
        subtotal = counts[s]
        for o in counts:
            if o != s and _contiguous_subseq(s, o):
                subtotal += counts[o]
        stats[s] = subtotal
        total += subtotal

    return {s: v / total * 100.0 for s, v in stats.items()}  # lines 14-15


class ContextDetector:
    """Subscribes to the MQ bus; feeds per-notebook interaction history into
    the pluggable interaction model and answers prediction queries from it.

    ``model`` accepts an :class:`InteractionModel` instance, a registered
    model name (``frequency`` | ``markov`` | ``recency`` | ``ensemble``) or
    None for the paper's default."""

    def __init__(self, model: InteractionModel | str | None = None):
        self.model = make_model(model)
        self.history: dict[str, list[int]] = defaultdict(list)
        self._attached: list[tuple[T.MQBus, str]] = []

    def attach(self, bus: T.MQBus, topic: str = "telemetry") -> None:
        bus.subscribe(topic, self.on_message)
        self._attached.append((bus, topic))

    def detach(self) -> None:
        """Unsubscribe from every bus this detector attached to (sessions
        must not leak their subscribers into later sessions)."""
        for bus, topic in self._attached:
            bus.unsubscribe(topic, self.on_message)
        self._attached.clear()

    def on_message(self, msg: T.TelemetryMessage) -> None:
        if msg.type != T.CELL_EXECUTION_COMPLETED or msg.cell_id is None:
            return
        order = msg.payload.get("order")
        if order is None:
            try:
                order = list(msg.cell_ids).index(msg.cell_id)
            except ValueError:
                # the cell was deleted/renamed mid-session: the event no
                # longer maps onto an order — drop it rather than crash the
                # whole bus dispatch
                return
        self.record(msg.notebook, int(order))

    # ------------------------------------------------------------------
    def record(self, notebook: str, order: int) -> None:
        self.history[notebook].append(int(order))
        self.model.observe(notebook, int(order))

    def stats(self, notebook: str, current_order: int | None = None):
        """Algorithm-1 sequence scores.  Served incrementally by the
        frequency model; other models fall back to the reference rescan
        over the recorded history."""
        if isinstance(self.model, FrequencyModel):
            return self.model.stats(notebook, current_order)
        return sequence_stats(self.history[notebook], current_order)

    def distribution(self, notebook: str, current_order: int) -> dict[int, float]:
        """P(next cell | history, current) from the interaction model."""
        return self.model.distribution(notebook, current_order)

    def predict_block(self, notebook: str, current_order: int) -> tuple[int, ...]:
        """Most probable upcoming block from the current cell onward."""
        return self.model.predict_block(notebook, current_order)

    def predict_block_scored(
            self, notebook: str, current_order: int,
    ) -> tuple[tuple[int, ...], float, int]:
        """(block, score%, n_candidates) — score is the model's confidence
        in the chosen block; n_candidates gauges how much evidence the
        prediction rests on."""
        return self.model.predict_block_scored(notebook, current_order)

    def predict_next(self, notebook: str, current_order: int) -> int | None:
        """The cell most likely to run *after* the current one — used by the
        pipelined engine to prefetch the next hop's state during execution."""
        return self.model.predict_next(notebook, current_order)
