"""Discrete-event simulation core: heap-ordered event loop, timers, processes.

The fleet plane (scheduler, env lifecycle, failure injection, autoscaling,
background checkpoints) all run as events on one :class:`EventLoop`.  Time
comes from a pluggable clock — :class:`repro.core.simclock.SimClock` for
deterministic simulation (the loop *advances* it to each event's due time)
or :class:`repro.core.simclock.WallClock` for real deployments (the loop
*sleeps* until each event is due; ``advance`` on a real clock is a no-op,
which is how the loop tells the two apart).

Ordering is total and deterministic: events fire in ``(time, priority,
seq)`` order, where ``seq`` is the scheduling sequence number — so two
events due at the same instant with the same priority fire in the order
they were scheduled, and a lower ``priority`` wins ties at one instant
(the session scheduler uses the session index as priority to reproduce
its historical lowest-session-first tie-break exactly).

Processes are plain generators: ``yield <seconds>`` suspends the process
for that long, ``yield None`` (or ``yield 0``) reschedules it at the same
instant behind already-queued same-time events.  A process that returns
(or raises StopIteration) simply ends.
"""
from __future__ import annotations

import heapq
import itertools
import time as _time
from typing import Callable, Generator, Iterator


class Event:
    """A scheduled callback; ``cancel()`` makes the loop skip it."""

    __slots__ = ("time", "priority", "seq", "fn", "args", "cancelled")

    def __init__(self, time: float, priority: int, seq: int,
                 fn: Callable, args: tuple):
        self.time = float(time)
        self.priority = int(priority)
        self.seq = int(seq)
        self.fn = fn
        self.args = args
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True

    def __lt__(self, other: "Event") -> bool:
        return ((self.time, self.priority, self.seq)
                < (other.time, other.priority, other.seq))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = " cancelled" if self.cancelled else ""
        return f"Event(t={self.time:.3f}, prio={self.priority}{state})"


class EventLoop:
    """Heap-ordered event loop over a SimClock or WallClock time source."""

    def __init__(self, clock=None):
        if clock is None:
            from repro.core.simclock import SimClock
            clock = SimClock()
        self.clock = clock
        self._heap: list[Event] = []
        self._seq = itertools.count()
        self.events_fired = 0

    # -- time ------------------------------------------------------------
    def now(self) -> float:
        return self.clock.now()

    def _wait_until(self, t: float) -> None:
        """Advance a simulated clock to ``t``; sleep a real one."""
        now = self.clock.now()
        if t <= now:
            return
        moved = self.clock.advance(t - now)
        if moved < t:                      # real clock: advance is a no-op
            remaining = t - self.clock.now()
            if remaining > 0:
                _time.sleep(remaining)

    # -- scheduling ------------------------------------------------------
    def call_at(self, t: float, fn: Callable, *args,
                priority: int = 0) -> Event:
        """Schedule ``fn(*args)`` at absolute time ``t`` (clamped to now)."""
        ev = Event(max(t, self.clock.now()), priority, next(self._seq),
                   fn, args)
        heapq.heappush(self._heap, ev)
        return ev

    def call_later(self, delay: float, fn: Callable, *args,
                   priority: int = 0) -> Event:
        assert delay >= 0, delay
        return self.call_at(self.clock.now() + delay, fn, *args,
                            priority=priority)

    def every(self, interval: float, fn: Callable, *args,
              priority: int = 0, start_after: float | None = None) -> Event:
        """Recurring timer: ``fn(*args)`` every ``interval`` seconds until
        ``fn`` returns False or the returned (first) event is cancelled.
        Cancellation is checked at each tick, so cancelling the handle stops
        the whole series."""
        assert interval > 0, interval
        handle = Event(0.0, priority, -1, fn, args)  # series handle only

        def tick():
            if handle.cancelled:
                return
            if fn(*args) is False:
                handle.cancel()
                return
            self.call_later(interval, tick, priority=priority)

        self.call_later(interval if start_after is None else start_after,
                        tick, priority=priority)
        return handle

    def process(self, gen: Generator | Iterator, *,
                priority: int = 0, delay: float = 0.0) -> Event:
        """Drive a generator as a process: each ``yield dt`` suspends it for
        ``dt`` seconds (``None``/0 = same instant, behind queued peers).
        The returned handle covers the process's whole lifetime: cancelling
        it stops the process at its next wakeup (and closes the generator),
        not just the first step — same contract as :meth:`every`."""
        handle = Event(self.clock.now() + delay, priority, -1, None, ())

        def step():
            if handle.cancelled:
                if hasattr(gen, "close"):
                    gen.close()
                return
            try:
                dt = next(gen)
            except StopIteration:
                handle.cancelled = True     # finished: mark for observers
                return
            self.call_later(float(dt or 0.0), step, priority=priority)

        self.call_later(delay, step, priority=priority)
        return handle

    # -- running ---------------------------------------------------------
    def run(self, until: float | None = None) -> float:
        """Fire events in order until the heap drains (or past ``until``);
        returns the final clock time."""
        while self._heap:
            ev = self._heap[0]
            if ev.cancelled:
                heapq.heappop(self._heap)
                continue
            if until is not None and ev.time > until:
                break
            heapq.heappop(self._heap)
            self._wait_until(ev.time)
            self.events_fired += 1
            ev.fn(*ev.args)
        if until is not None:
            self._wait_until(until)
        return self.clock.now()

    def pending(self) -> int:
        return sum(1 for ev in self._heap if not ev.cancelled)
