"""Environment fabric: N heterogeneous execution environments + links.

The paper's runtime moves notebook state between exactly two places (the
user's machine and one cloud node).  This module generalizes that dyad into
an *environment fabric*: an :class:`EnvironmentRegistry` holds any number of
heterogeneous :class:`ExecutionEnvironment`s (cpu-local, gpu-cloud, a TPU
mesh via ``DistContext``, a disk/checkpoint target) with per-pair
bandwidth/latency :class:`Link`s.  Placement policies, the migration engine
and the session scheduler all resolve environments and transfer costs
through the registry instead of hardcoded ``"local"``/``"remote"`` strings.

The paper's two-env setup is the smallest instance:
``EnvironmentRegistry.two_env()`` builds it, and ``from_envs()`` adapts the
legacy ``{"local": ..., "remote": ...}`` dict API.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Iterator

from repro.core.chunkstore import DiskChunkStore, MemoryChunkStore
from repro.core.state import ExecutionState


# lifecycle state machine: which transitions an environment may take.
# ``up`` is the default (and the paper's implicit state — its two envs are
# always on); everything else is fleet-plane machinery.
LIFECYCLE = {
    "provisioning": {"up", "failed", "down"},
    "up": {"draining", "failed", "down"},
    "draining": {"down", "failed", "up"},   # draining can be cancelled
    "down": {"provisioning"},
    "failed": {"provisioning"},
}


class ExecutionEnvironment:
    """A place code can run with its own namespace (§II): the user's machine,
    a cloud node, a JAX mesh (``DistContext``) — or a non-compute target such
    as disk, which the engine migrates to for checkpointing.

    Every environment fronts a content-addressed chunk store — the state
    plane's substrate: migration ships only chunks the target store lacks.
    ``kind="storage"`` environments back theirs with an on-disk CAS
    directory (``storage_dir``), which is how checkpointing *is* migration.

    Fleet lifecycle: ``status`` walks the :data:`LIFECYCLE` state machine
    (``provisioning → up → draining → down/failed``).  ``cold_start`` is the
    modeled seconds a provision takes before the env is usable; the fleet
    scheduler records ``ready_at`` when it starts one.  ``idle_timeout``
    (None = never) is how long the env may sit idle before the autoscaler
    culls it.  The default status is ``up``, so a registry that never
    touches the lifecycle behaves exactly as before.

    Cost plane: ``price_per_hour`` is what occupying this env costs in
    dollars per wall-clock hour (0 = free, the paper's implicit price).
    ``hazard_rate`` is the preemption hazard in events per *second* of
    uptime; a non-zero rate marks the env as spot/preemptible capacity —
    the scheduler draws seeded preemption times from it and the price-aware
    placement DP weighs it against the cheaper price tag."""

    def __init__(self, name: str, *, speedup: float = 1.0,
                 mesh_ctx=None, globals_seed: dict | None = None,
                 kind: str = "compute", chunk_store=None,
                 storage_dir: str | None = None, status: str = "up",
                 cold_start: float = 0.0, idle_timeout: float | None = None,
                 transport: str = "loopback", price_per_hour: float = 0.0,
                 hazard_rate: float = 0.0):
        assert status in LIFECYCLE, status
        self.name = name
        self.speedup = float(speedup)
        self.mesh_ctx = mesh_ctx
        self.kind = kind                 # compute | storage
        self.storage_dir = storage_dir
        self.status = status
        self.cold_start = float(cold_start)
        self.idle_timeout = idle_timeout
        self.ready_at = 0.0              # when a provisioning env comes up
        self.price_per_hour = float(price_per_hour)
        self.hazard_rate = float(hazard_rate)
        if self.price_per_hour < 0 or self.hazard_rate < 0:
            raise ValueError(
                f"env {name!r}: price_per_hour and hazard_rate must be >= 0")
        # transport plane: how migration traffic reaches this env.
        # "loopback" (default) = in-process, zero-copy, simulated timing —
        # the paper's setup.  "socket"/"subprocess" envs additionally carry
        # a ``peer`` (a transport.MigrationPeer) once one is attached; the
        # engine streams real wire frames through it.
        self.transport = transport
        self.peer = None
        if chunk_store is None:
            chunk_store = (DiskChunkStore(storage_dir) if storage_dir
                           else MemoryChunkStore())
        self.chunk_store = chunk_store
        self.state = ExecutionState(dict(globals_seed or {}))

    # -- lifecycle -------------------------------------------------------
    def set_status(self, status: str, *, now: float = 0.0) -> str:
        """Transition the lifecycle state machine; returns the old status.
        Illegal transitions raise (e.g. ``down`` cannot jump to ``up``
        without provisioning)."""
        if status == self.status:
            return status
        allowed = LIFECYCLE[self.status]
        if status not in allowed:
            raise ValueError(
                f"env {self.name!r}: illegal lifecycle transition "
                f"{self.status!r} -> {status!r} (allowed: {sorted(allowed)})")
        old, self.status = self.status, status
        if status == "provisioning":
            self.ready_at = now + self.cold_start
        return old

    def placeable_now(self) -> bool:
        """Whether new work may target this env: up, or provisioning (the
        cold-start wait is then priced into placement)."""
        return self.status in ("up", "provisioning")

    @property
    def spot(self) -> bool:
        """Preemptible capacity: a non-zero preemption hazard was declared."""
        return self.hazard_rate > 0.0

    def execute(self, source: str, cost: float | None = None) -> float:
        """Run real code against this env's namespace; return modeled seconds."""
        t0 = time.perf_counter()
        exec(compile(source, f"<{self.name}>", "exec"), self.state.ns)  # noqa: S102
        wall = time.perf_counter() - t0
        base = cost if cost is not None else wall
        return base / self.speedup

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"ExecutionEnvironment({self.name!r}, speedup={self.speedup})"


@dataclass(frozen=True)
class Link:
    """Directed transfer cost between two environments.  ``transport``
    names which transport binding the pair's migration traffic rides
    (loopback = in-process simulated movement; socket = real framed TCP,
    optionally shaped).  The *cost model* is the same either way — real
    transports record measured wall time alongside the modeled seconds.

    Links are directed, so a pair may be asymmetric: cloud downlinks are
    commonly faster than uplinks, and providers bill *egress* — dollars per
    GB leaving the source — in one direction only.  ``egress_per_gb`` prices
    that; the default 0.0 keeps every pre-cost-plane topology free."""
    bandwidth: float = 1e9          # bytes/second
    latency: float = 0.5            # seconds per transfer
    transport: str = "loopback"
    egress_per_gb: float = 0.0      # dollars per 1e9 bytes crossing the link

    def transfer_seconds(self, nbytes: int | float) -> float:
        return self.latency + nbytes / self.bandwidth

    def transfer_dollars(self, nbytes: int | float) -> float:
        return self.egress_per_gb * nbytes / 1e9


class EnvironmentRegistry:
    """N environments + per-pair links + per-env capacity.

    One environment is the *home* (the paper's "local"): where the user
    sits, where sessions start, and where state returns after a block
    completes.  Links default to (``default_bandwidth``, ``default_latency``)
    so a registry behaves exactly like the legacy scalar-cost engine until
    pairs are given their own costs via :meth:`connect`.
    """

    def __init__(self, *, default_bandwidth: float = 1e9,
                 default_latency: float = 0.5):
        self._envs: dict[str, ExecutionEnvironment] = {}
        self._links: dict[tuple[str, str], Link] = {}
        self._capacity: dict[str, int] = {}
        self._placeable: dict[str, bool] = {}
        self.default_link = Link(default_bandwidth, default_latency)
        self.home: str | None = None
        # fleet-plane audit trail: (time, env, old_status, new_status)
        self.lifecycle_log: list[tuple[float, str, str, str]] = []

    # -- membership ----------------------------------------------------
    def register(self, env: ExecutionEnvironment, *, home: bool = False,
                 capacity: int = 1,
                 placeable: bool | None = None) -> ExecutionEnvironment:
        if env.name in self._envs:
            raise ValueError(f"environment {env.name!r} already registered")
        self._envs[env.name] = env
        self._capacity[env.name] = int(capacity)
        if placeable is None:
            placeable = env.kind == "compute"
        self._placeable[env.name] = bool(placeable)
        if home or self.home is None:
            self.home = env.name
        return env

    def retire(self, name: str) -> ExecutionEnvironment:
        """Remove an environment from the live registry (dynamic fleet
        membership): its links, capacity and placement eligibility go with
        it.  The home env cannot be retired — sessions start and return
        there."""
        if name == self.home:
            raise ValueError(f"cannot retire the home environment {name!r}")
        env = self._envs.pop(name)
        self._capacity.pop(name, None)
        self._placeable.pop(name, None)
        self._links = {pair: link for pair, link in self._links.items()
                       if name not in pair}
        return env

    def set_status(self, name: str, status: str, *,
                   now: float = 0.0) -> None:
        """Lifecycle transition with an audit-log entry (fleet plane)."""
        old = self._envs[name].set_status(status, now=now)
        if old != status:
            self.lifecycle_log.append((now, name, old, status))

    def set_transport(self, name: str, kind: str, *,
                      now: float = 0.0) -> None:
        """Mark which transport carries migration traffic to ``name``
        (fleet plane); audit-logged like a lifecycle transition."""
        from repro.core.transport import TRANSPORTS
        if kind not in TRANSPORTS:
            raise ValueError(f"unknown transport {kind!r} "
                             f"(expected one of {TRANSPORTS})")
        env = self._envs[name]
        old = getattr(env, "transport", "loopback")
        env.transport = kind
        if old != kind:
            self.lifecycle_log.append(
                (now, name, f"transport:{old}", f"transport:{kind}"))

    def __getitem__(self, name: str) -> ExecutionEnvironment:
        return self._envs[name]

    def __contains__(self, name: str) -> bool:
        return name in self._envs

    def __len__(self) -> int:
        return len(self._envs)

    def __iter__(self) -> Iterator[str]:
        return iter(self._envs)

    def names(self) -> list[str]:
        return list(self._envs)

    def envs(self) -> dict[str, ExecutionEnvironment]:
        return dict(self._envs)

    def compute_envs(self) -> dict[str, ExecutionEnvironment]:
        """Environments cells may be *placed* on: excludes storage targets
        and envs whose lifecycle state is not placeable (down / failed /
        draining)."""
        return {n: e for n, e in self._envs.items()
                if self._placeable[n] and e.placeable_now()}

    def candidates(self) -> list[str]:
        """Placement candidates other than home, registration order."""
        return [n for n in self.compute_envs() if n != self.home]

    def capacity(self, name: str) -> int:
        return self._capacity[name]

    # -- links ----------------------------------------------------------
    def connect(self, a: str, b: str, *, bandwidth: float | None = None,
                latency: float | None = None, symmetric: bool = True,
                transport: str | None = None,
                egress_per_gb: float | None = None,
                reverse_bandwidth: float | None = None,
                reverse_latency: float | None = None,
                reverse_egress_per_gb: float | None = None) -> Link:
        """Set the a→b link.  ``symmetric=True`` (default) also sets b→a;
        pass any ``reverse_*`` override to make the pair asymmetric — the
        reverse direction then gets its own Link falling back to the
        forward values for anything not overridden."""
        link = Link(bandwidth if bandwidth is not None
                    else self.default_link.bandwidth,
                    latency if latency is not None
                    else self.default_link.latency,
                    transport if transport is not None
                    else self.default_link.transport,
                    egress_per_gb if egress_per_gb is not None
                    else self.default_link.egress_per_gb)
        self._links[(a, b)] = link
        asymmetric = (reverse_bandwidth is not None
                      or reverse_latency is not None
                      or reverse_egress_per_gb is not None)
        if symmetric:
            if asymmetric:
                self._links[(b, a)] = Link(
                    reverse_bandwidth if reverse_bandwidth is not None
                    else link.bandwidth,
                    reverse_latency if reverse_latency is not None
                    else link.latency,
                    link.transport,
                    reverse_egress_per_gb if reverse_egress_per_gb is not None
                    else link.egress_per_gb)
            else:
                self._links[(b, a)] = link
        return link

    def set_egress(self, a: str, b: str, per_gb: float, *,
                   symmetric: bool = False) -> None:
        """Price egress on an existing (or default) link without touching
        its bandwidth/latency.  Egress is directional by default — billing
        usually charges data *leaving* a provider, not entering it."""
        self._links[(a, b)] = replace(
            self.link(a, b), egress_per_gb=float(per_gb))
        if symmetric:
            self._links[(b, a)] = replace(
                self.link(b, a), egress_per_gb=float(per_gb))

    def link(self, src: str, dst: str) -> Link:
        if src == dst:
            return Link(float("inf"), 0.0)
        return self._links.get((src, dst), self.default_link)

    def transfer_seconds(self, src: str, dst: str, nbytes: int | float) -> float:
        if src == dst:
            return 0.0
        return self.link(src, dst).transfer_seconds(nbytes)

    def transfer_dollars(self, src: str, dst: str, nbytes: int | float) -> float:
        """Egress dollars for shipping ``nbytes`` src→dst (0 on self-pairs)."""
        if src == dst:
            return 0.0
        return self.link(src, dst).transfer_dollars(nbytes)

    def pairs(self) -> list[tuple[str, str]]:
        ns = self.names()
        return [(a, b) for a in ns for b in ns if a != b]

    def clone_topology(self, *,
                       share_chunk_stores: bool = True) -> "EnvironmentRegistry":
        """Same env names/speedups/links/capacities with *fresh namespaces*.

        The session scheduler gives each session a private clone (its own
        kernel namespaces) while a shared CapacityArbiter models the actual
        hardware the clones stand for.  By default the clones also share the
        original envs' chunk stores — content-addressed chunks are immutable,
        so N sessions loading the same dataset transfer its chunks once;
        pass ``share_chunk_stores=False`` to isolate the in-memory stores.
        Storage-backed envs keep pointing at their on-disk directory either
        way: the disk *is* the physical medium the clones stand for."""
        reg = EnvironmentRegistry(
            default_bandwidth=self.default_link.bandwidth,
            default_latency=self.default_link.latency)
        for name, env in self._envs.items():
            clone = ExecutionEnvironment(
                name, speedup=env.speedup, mesh_ctx=env.mesh_ctx,
                kind=env.kind, storage_dir=env.storage_dir,
                cold_start=env.cold_start, idle_timeout=env.idle_timeout,
                transport=getattr(env, "transport", "loopback"),
                price_per_hour=env.price_per_hour,
                hazard_rate=env.hazard_rate,
                chunk_store=env.chunk_store if share_chunk_stores
                else None)
            # lifecycle state carries over verbatim (the clone stands for
            # the same physical env); bypass the transition checker
            clone.status = env.status
            clone.ready_at = env.ready_at
            reg.register(
                clone,
                home=(name == self.home), capacity=self._capacity[name],
                placeable=self._placeable[name])
        reg._links = dict(self._links)
        return reg

    # -- constructors ----------------------------------------------------
    @classmethod
    def two_env(cls, *, remote_speedup: float = 10.0, bandwidth: float = 1e9,
                latency: float = 0.5) -> "EnvironmentRegistry":
        """The paper's local/remote dyad as the smallest fabric."""
        reg = cls(default_bandwidth=bandwidth, default_latency=latency)
        reg.register(ExecutionEnvironment("local"), home=True)
        reg.register(ExecutionEnvironment("remote", speedup=remote_speedup))
        return reg

    @classmethod
    def from_envs(cls, envs: dict[str, ExecutionEnvironment], *,
                  bandwidth: float = 1e9,
                  latency: float = 0.5) -> "EnvironmentRegistry":
        """Adapt the legacy ``{"local": ..., "remote": ...}`` dict API."""
        reg = cls(default_bandwidth=bandwidth, default_latency=latency)
        for name, env in envs.items():
            reg.register(env, home=(name == "local"))
        return reg
