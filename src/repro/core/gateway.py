"""Persistent multi-tenant gateway: warm-pool scheduling on the event loop.

:class:`~repro.core.scheduler.SessionScheduler` is a batch loop — it
consumes a :class:`~repro.core.scheduler.WorkloadTrace` and exits.  A
production gateway (Jupyter Enterprise Gateway, Noteburst) is a *service*:
sessions attach and detach at will, a warm pool of pre-provisioned workers
absorbs cold starts, and per-tenant admission keeps one noisy tenant from
starving the rest.  :class:`GatewayService` is that shape on the existing
:class:`~repro.core.events.EventLoop` (SimClock for deterministic
benchmarks, WallClock for a real deployment):

* **attach/detach at any time** — programmatic (:meth:`GatewayService
  .attach`) or over the wire protocol (:class:`WireFrontend` speaks the
  ``ATTACH``/``DETACH`` frames of :mod:`repro.core.wire`, and rides a
  plain transport or one stream of a
  :class:`~repro.core.transport.MuxPeer`);
* **warm pool** — :class:`WarmPool` keeps K pre-provisioned workers (each
  a ``registry.clone_topology()`` with fresh kernel namespaces) ready, so
  a pool hit attaches with zero provisioning wait; a miss walks the
  worker's compute envs through the fabric lifecycle state machine
  (``up → down → provisioning → up``, audit-logged) and pays the cold
  start.  Every acquire schedules a background refill, so the pool
  sustains ``K / cold_start`` attaches per second invisibly;
* **fair-share admission** — per-tenant quotas (max concurrent sessions)
  plus deficit-round-robin arbitration of the gateway-wide
  ``max_sessions`` budget: each backlogged tenant earns
  ``quantum x weight`` deficit per round and admits sessions while its
  deficit and quota allow, so admission bandwidth divides by weight
  instead of by who floods the queue hardest;
* **indexed hot paths** — admission and placement go through the
  interval-indexed :class:`~repro.core.scheduler.CapacityArbiter`
  (bisect probes, not scans), and the fleet-minimum-clock watermark the
  arbiter prunes against comes from a lazy min-heap of session wake
  times, so no per-event work is O(sessions).

The degenerate instance — one tenant, no quota, everyone attached before
``run()`` — reproduces the batch scheduler's semantics: sessions still
gate through the same arbiter and the same placement policies, so paper
decision traces stay bit-identical.
"""
from __future__ import annotations

import heapq
import itertools
import math
import time
from collections import deque
from dataclasses import dataclass, field

from repro.core import wire
from repro.core.events import EventLoop
from repro.core.fabric import EnvironmentRegistry
from repro.core.migration import HybridRuntime
from repro.core.notebook import Notebook
from repro.core.scheduler import CapacityArbiter
from repro.core.wire import WireError


def percentile(xs, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    rank = max(1, math.ceil(q / 100.0 * len(xs)))
    return float(xs[min(rank, len(xs)) - 1])


# ----------------------------------------------------------------------
# warm pool
# ----------------------------------------------------------------------

@dataclass
class WarmWorker:
    """A pre-provisioned kernel slot: a private clone of the fabric
    topology (fresh namespaces, shared physical chunk stores)."""
    registry: EnvironmentRegistry
    warm: bool = True


class WarmPool:
    """K pre-provisioned workers; ``acquire`` pops one instantly on a hit.

    A hit costs zero provisioning wait and schedules a background refill
    ``cold_start`` seconds out — the replacement provisions while nobody
    is waiting on it, which is the entire point of a warm pool.  A miss
    builds a worker on the spot and charges the caller the cold start
    (its envs walk the lifecycle machine like any provisioning env).
    Used workers are never re-pooled: their namespaces are dirty, and
    their replacement was already scheduled at acquire time.  ``size=0``
    disables the pool (every attach pays the cold start) — the
    cold-provision baseline the gateway bench compares against."""

    def __init__(self, size: int, *, cold_start: float, factory):
        assert size >= 0 and cold_start >= 0.0
        self.size = int(size)
        self.cold_start = float(cold_start)
        self._factory = factory           # () -> EnvironmentRegistry clone
        self._ready: deque[WarmWorker] = deque()
        self._filling = 0
        self._loop: EventLoop | None = None
        self.hits = 0
        self.misses = 0
        self.refills = 0

    def bind(self, loop: EventLoop, *, prewarm: bool = True) -> None:
        self._loop = loop
        if prewarm:
            for _ in range(self.size):
                self._ready.append(WarmWorker(self._factory()))

    @property
    def level(self) -> int:
        return len(self._ready)

    def acquire(self, now: float) -> tuple[WarmWorker, float]:
        """Returns (worker, provisioning delay): 0.0 on a pool hit, the
        cold start on a miss."""
        self._refill_later()
        if self._ready:
            self.hits += 1
            return self._ready.popleft(), 0.0
        self.misses += 1
        worker = WarmWorker(self._factory(), warm=False)
        self._provision(worker, now)
        return worker, self.cold_start

    def release(self, worker: WarmWorker) -> None:
        """A detached session's worker is discarded, not re-pooled: its
        namespaces are dirty and its replacement is already provisioning
        (scheduled when it was acquired)."""

    def _refill_later(self) -> None:
        if self._loop is None or self.size == 0:
            return
        if self.level + self._filling < self.size:
            self._filling += 1
            self._loop.call_later(self.cold_start, self._refill, priority=-20)

    def _refill(self) -> None:
        self._filling -= 1
        if self.level < self.size:
            self._ready.append(WarmWorker(self._factory()))
            self.refills += 1

    def _provision(self, worker: WarmWorker, now: float) -> None:
        """Walk a cold worker's compute envs through the fabric lifecycle
        machine: ``up → down → provisioning`` now, ``→ up`` at readiness
        (audit-logged on the worker's registry)."""
        reg = worker.registry
        ready = now + self.cold_start
        for name, env in reg.envs().items():
            if env.kind != "compute" or env.status != "up":
                continue
            env.cold_start = max(env.cold_start, self.cold_start)
            reg.set_status(name, "down", now=now)
            reg.set_status(name, "provisioning", now=now)
            env.ready_at = ready
            if self._loop is not None:
                self._loop.call_at(ready, self._mark_up, reg, name, ready,
                                   priority=-20)

    @staticmethod
    def _mark_up(reg: EnvironmentRegistry, name: str, now: float) -> None:
        if name in reg and reg[name].status == "provisioning":
            reg.set_status(name, "up", now=now)


# ----------------------------------------------------------------------
# tenants + fair-share admission
# ----------------------------------------------------------------------

@dataclass
class GatewayTenant:
    """Admission state for one tenant: a FIFO of waiting attach requests,
    a deficit-round-robin account, and a concurrency quota."""
    name: str
    quota: int | None = None          # max concurrent sessions (None = ∞)
    weight: float = 1.0               # DRR share of admission bandwidth
    deficit: float = 0.0
    queue: deque = field(default_factory=deque)
    admitted: int = 0                 # currently-running sessions
    attached_total: int = 0
    admission_wait: float = 0.0       # summed seconds spent queued

    def can_admit(self) -> bool:
        return bool(self.queue) and (self.quota is None
                                     or self.admitted < self.quota)


@dataclass
class _AttachRequest:
    session_id: str
    tenant: str
    notebook: Notebook
    plan: list
    think: list
    requested_at: float = 0.0
    frontend: "WireFrontend | None" = None
    runtime_kw: dict = field(default_factory=dict)


@dataclass
class _GwSession:
    id: str
    idx: int                          # attach order: event-priority tie-break
    tenant: str
    runtime: HybridRuntime
    worker: WarmWorker
    plan: list
    think: list
    frontend: "WireFrontend | None" = None
    cursor: int = 0
    think_used: int = 0
    think_total: float = 0.0
    attached_at: float = 0.0
    attach_wait: float = 0.0
    next_wake: float = 0.0
    detached: bool = False
    step_event = None

    def next_think(self) -> float:
        if self.think_used < len(self.think):
            t = self.think[self.think_used]
            self.think_used += 1
            return float(t)
        return 0.0


@dataclass
class GatewaySessionReport:
    session: str
    tenant: str
    notebook: str
    cells_run: int
    attach_wait: float                # admission wait + provisioning wait
    warm: bool
    queue_wait: float                 # capacity waits during the session
    makespan: float                   # session clock at detach
    migrations: int
    reason: str                       # complete | client | error:...
    # replica plane (zero with replicas=0): convergence lag at detach,
    # promotions taken, races run and their win/waste tallies
    replica_lag: int = 0
    promotions: int = 0
    races: int = 0
    race_wins: dict = field(default_factory=dict)
    race_waste_seconds: float = 0.0


@dataclass
class GatewayReport:
    sessions: int
    completed: int
    client_detached: int
    errors: int
    peak_concurrent: int
    makespan: float
    attach_wait_p50: float
    attach_wait_p99: float
    warm_attach_p99: float
    cold_attach_p99: float
    queue_wait_p50: float
    queue_wait_p99: float
    decision_ms_p50: float
    decision_ms_p99: float
    decisions: int
    pool_hits: int
    pool_misses: int
    pool_refills: int
    pruned_intervals: int
    env_utilization: dict
    tenants: dict
    # replica plane aggregates (zero with replicas=0)
    promotions: int = 0
    races: int = 0
    race_waste_seconds: float = 0.0
    session_reports: list = field(default_factory=list)


# ----------------------------------------------------------------------
# the service
# ----------------------------------------------------------------------

class GatewayService:
    """A long-running gateway process over one shared fabric registry.

    Sessions gate through one interval-indexed
    :class:`~repro.core.scheduler.CapacityArbiter` (the physical pool)
    while each runs on a private worker clone from the :class:`WarmPool`.
    ``attach()`` may be called before or during :meth:`run` — including
    from event callbacks, which is how :class:`WireFrontend` injects
    wire-borne attach storms."""

    def __init__(self, registry: EnvironmentRegistry, *,
                 warm_pool: int = 4, cold_start: float = 5.0,
                 max_sessions: int | None = None,
                 quantum: float = 1.0, share_chunks: bool = True,
                 clock=None, poll_interval: float = 0.05,
                 prune_interval: float = 10.0, prewarm: bool = True,
                 replicas: int = 0, race: bool = False,
                 **runtime_defaults):
        if replicas < 0:
            raise ValueError(f"replicas must be >= 0, got {replicas}")
        self.replicas_k = int(replicas)
        self.race = bool(race)
        self.registry = registry
        self.share_chunks = bool(share_chunks)
        self.loop = EventLoop(clock)
        self.arbiter = CapacityArbiter(registry)
        self.pool = WarmPool(warm_pool, cold_start=cold_start,
                             factory=self._clone)
        self.pool.bind(self.loop, prewarm=prewarm)
        self.max_sessions = max_sessions
        self.quantum = float(quantum)
        self.poll_interval = float(poll_interval)
        self.prune_interval = float(prune_interval)
        self.runtime_defaults = dict(runtime_defaults)
        self.tenants: dict[str, GatewayTenant] = {}
        self.stop_when_idle = False
        self._pending_storm = 0        # scheduled-but-not-yet-admitted
        self._sessions: dict[str, _GwSession] = {}
        self._active = 0
        self._queued = 0
        self._seq = itertools.count()
        self._drr_ring: deque[str] = deque()
        self._wake_heap: list[tuple[float, int, _GwSession]] = []
        self._last_prune = float("-inf")
        self._frontends: list[WireFrontend] = []
        # telemetry
        self.peak_concurrent = 0
        self.warm_waits: list[float] = []
        self.cold_waits: list[float] = []
        self.decision_seconds: list[float] = []
        self.reports: list[GatewaySessionReport] = []

    def _clone(self) -> EnvironmentRegistry:
        return self.registry.clone_topology(
            share_chunk_stores=self.share_chunks)

    # -- tenants ---------------------------------------------------------
    def add_tenant(self, name: str, *, quota: int | None = None,
                   weight: float = 1.0) -> GatewayTenant:
        if weight <= 0:
            raise ValueError(f"tenant {name!r}: weight must be positive")
        if quota is not None and quota < 1:
            raise ValueError(f"tenant {name!r}: quota must be >= 1")
        t = self.tenants[name] = GatewayTenant(name, quota=quota,
                                               weight=float(weight))
        self._drr_ring.append(name)
        return t

    def _tenant(self, name: str) -> GatewayTenant:
        if name not in self.tenants:
            self.add_tenant(name)
        return self.tenants[name]

    # -- attach / detach -------------------------------------------------
    def attach(self, notebook: Notebook, plan=None, *,
               tenant: str = "default", think=None, at: float | None = None,
               session: str | None = None, frontend=None,
               **runtime_kw) -> str:
        """Queue a session attach (admission happens on the loop, under
        fair-share).  ``at`` schedules the request for a future sim time;
        default is now.  Returns the session id immediately."""
        sid = session or f"g{next(self._seq):05d}-{notebook.name}"
        kw = dict(self.runtime_defaults)
        kw.update(runtime_kw)
        req = _AttachRequest(
            session_id=sid, tenant=tenant, notebook=notebook,
            plan=list(plan) if plan is not None
            else list(range(len(notebook.cells))),
            think=list(think or []), frontend=frontend, runtime_kw=kw)
        when = self.loop.now() if at is None else at
        self.loop.call_at(when, self._admit_request, req, priority=-2)
        return sid

    def detach(self, session_id: str, reason: str = "client") -> None:
        """Client-initiated detach: stops the session wherever it is (its
        pending step event is cancelled) and frees its worker + quota."""
        sess = self._sessions.get(session_id)
        if sess is None:
            raise KeyError(f"no attached session {session_id!r}")
        if sess.step_event is not None:
            sess.step_event.cancel()
        self._finish(sess, reason)

    def _admit_request(self, req: _AttachRequest) -> None:
        req.requested_at = self.loop.now()
        if self._pending_storm > 0:
            self._pending_storm -= 1
        self._tenant(req.tenant).queue.append(req)
        self._queued += 1
        self._pump_admission()

    def _free_slots(self) -> float:
        if self.max_sessions is None:
            return float("inf")
        return self.max_sessions - self._active

    def _pump_admission(self) -> None:
        """Deficit round robin over backlogged tenants: one visit earns
        ``quantum x weight``; a session costs 1 deficit.  Tenants at
        quota are skipped without earning (deficit must not hoard while
        the tenant cannot spend it)."""
        while self._queued and self._free_slots() > 0:
            admitted_this_round = False
            for _ in range(len(self._drr_ring)):
                name = self._drr_ring[0]
                self._drr_ring.rotate(-1)
                t = self.tenants[name]
                if not t.can_admit():
                    if not t.queue:
                        t.deficit = 0.0
                    continue
                t.deficit += self.quantum * t.weight
                while t.can_admit() and t.deficit >= 1.0 \
                        and self._free_slots() > 0:
                    t.deficit -= 1.0
                    self._start_session(t, t.queue.popleft())
                    admitted_this_round = True
                if not t.queue:
                    t.deficit = 0.0
            if not admitted_this_round:
                return                 # everyone blocked on quota or slots

    def _start_session(self, tenant: GatewayTenant,
                       req: _AttachRequest) -> None:
        now = self.loop.now()
        self._queued -= 1
        if req.session_id in self._sessions:   # client reused a live id
            req.session_id = f"{req.session_id}#{next(self._seq)}"
        worker, delay = self.pool.acquire(now)
        rt = HybridRuntime(req.notebook, registry=worker.registry,
                           arbiter=self.arbiter, session_id=req.session_id,
                           **req.runtime_kw)
        self._time_decisions(rt)
        if self.replicas_k > 0:
            followers = sorted(
                n for n, e in worker.registry.envs().items()
                if e.kind == "compute" and n != rt.home)[:self.replicas_k]
            if followers:
                rt.attach_replicas(followers, race=self.race)
        admission_wait = now - req.requested_at
        attach_wait = admission_wait + delay
        (self.warm_waits if worker.warm else self.cold_waits).append(
            attach_wait)
        tenant.admitted += 1
        tenant.attached_total += 1
        tenant.admission_wait += admission_wait
        sess = _GwSession(
            id=req.session_id, idx=next(self._seq), tenant=tenant.name,
            runtime=rt, worker=worker, plan=req.plan, think=req.think,
            frontend=req.frontend, attached_at=now, attach_wait=attach_wait)
        self._sessions[sess.id] = sess
        self._active += 1
        self.peak_concurrent = max(self.peak_concurrent, self._active)
        ready = now + delay
        sess.next_wake = ready
        heapq.heappush(self._wake_heap, (ready, sess.idx, sess))
        sess.step_event = self.loop.call_at(ready, self._step, sess,
                                            priority=sess.idx)
        if req.frontend is not None:
            req.frontend.notify_attached(sess, admission_wait, ready)

    def _time_decisions(self, rt: HybridRuntime) -> None:
        """Wall-clock every placement decision this runtime makes (the
        bench's decision-latency distribution)."""
        orig = rt.analyzer.decide
        sink = self.decision_seconds

        def timed(nb, cell, **kw):
            t0 = time.perf_counter()
            d = orig(nb, cell, **kw)
            sink.append(time.perf_counter() - t0)
            return d

        rt.analyzer.decide = timed

    # -- the per-session step --------------------------------------------
    def _step(self, sess: _GwSession) -> None:
        if sess.detached:
            return
        sess.step_event = None
        rt = sess.runtime
        now = self.loop.now()
        gap = now - rt.clock.now()
        if gap > 0:
            rt.clock.advance_to(now)
            if sess.cursor > 0:
                sess.think_total += gap
        if rt.replicas is not None:
            # think time just ended: converge the followers on whatever the
            # last cell committed before the next one runs
            rt.replicas.sync(now)
        self._prune_tick()
        try:
            rt.run_cell(sess.plan[sess.cursor])
        except Exception as e:  # noqa: BLE001 — a dying cell detaches, not crashes
            self._finish(sess, f"error:{type(e).__name__}")
            return
        sess.cursor += 1
        if sess.cursor >= len(sess.plan):
            self._finish(sess, "complete")
            return
        t_next = rt.clock.now() + sess.next_think()
        sess.next_wake = t_next
        heapq.heappush(self._wake_heap, (t_next, sess.idx, sess))
        sess.step_event = self.loop.call_at(t_next, self._step, sess,
                                            priority=sess.idx)

    def _prune_tick(self) -> None:
        """Arbiter pruning without an O(sessions) scan: the fleet-minimum
        clock watermark is the min of the lazy wake-time heap (stale
        entries — detached sessions, superseded wake times — pop on
        contact), and the actual prune runs at most once per
        ``prune_interval`` of watermark progress."""
        heap = self._wake_heap
        while heap and (heap[0][2].detached
                        or heap[0][0] != heap[0][2].next_wake):
            heapq.heappop(heap)
        if not heap:
            return
        watermark = heap[0][0]
        if watermark - self._last_prune >= self.prune_interval:
            self._last_prune = watermark
            self.arbiter.prune(watermark)

    def _finish(self, sess: _GwSession, reason: str) -> None:
        sess.detached = True
        rt = sess.runtime
        rs = rt.replicas
        self.reports.append(GatewaySessionReport(
            session=sess.id, tenant=sess.tenant, notebook=rt.nb.name,
            cells_run=sess.cursor, attach_wait=sess.attach_wait,
            warm=sess.worker.warm, queue_wait=rt.queue_wait,
            makespan=rt.clock.now(), migrations=rt.migrations,
            reason=reason,
            replica_lag=rs.lag() if rs else 0,
            promotions=rs.promotions if rs else 0,
            races=rs.races if rs else 0,
            race_wins=dict(rs.race_wins) if rs else {},
            race_waste_seconds=rs.race_waste_seconds if rs else 0.0))
        rt.close()
        self.pool.release(sess.worker)
        self.tenants[sess.tenant].admitted -= 1
        self._active -= 1
        del self._sessions[sess.id]
        if sess.frontend is not None:
            sess.frontend.notify_detached(sess, reason)
        self._pump_admission()

    # -- wire frontends --------------------------------------------------
    def add_frontend(self, transport) -> "WireFrontend":
        """Serve gateway control frames (ATTACH/DETACH) arriving on
        ``transport`` — a plain transport or one
        :class:`~repro.core.transport.MuxStream` of a shared socket.  The
        frontend is polled from the event loop (no blocked thread per
        connection)."""
        fe = WireFrontend(self, transport)
        self._frontends.append(fe)
        self.loop.every(self.poll_interval, fe._tick, priority=-3)
        return fe

    def expect_storm(self, n: int) -> None:
        """Declare ``n`` future attach requests so ``stop_when_idle``
        drains only after they all arrived (wire storms reach the
        gateway with polling latency; an idle instant in between must
        not stop the service)."""
        self._pending_storm += int(n)
        self.stop_when_idle = True

    def _idle(self) -> bool:
        return (self._pending_storm == 0 and self._active == 0
                and self._queued == 0)

    # -- driving ---------------------------------------------------------
    def run(self, until: float | None = None) -> GatewayReport:
        """Drive the loop until drained (or ``until``); returns the
        aggregate report.  With ``stop_when_idle`` set (storm benches),
        frontend pollers stand down once the declared storm has fully
        drained, letting the loop empty."""
        self.loop.run(until)
        return self.report()

    def report(self) -> GatewayReport:
        reasons = [r.reason for r in self.reports]
        queue_waits = [r.queue_wait for r in self.reports]
        attach_waits = self.warm_waits + self.cold_waits
        dec_ms = [s * 1e3 for s in self.decision_seconds]
        return GatewayReport(
            sessions=len(self.reports),
            completed=sum(1 for r in reasons if r == "complete"),
            client_detached=sum(1 for r in reasons if r == "client"),
            errors=sum(1 for r in reasons if r.startswith("error")),
            peak_concurrent=self.peak_concurrent,
            makespan=self.loop.now(),
            attach_wait_p50=percentile(attach_waits, 50),
            attach_wait_p99=percentile(attach_waits, 99),
            warm_attach_p99=percentile(self.warm_waits, 99),
            cold_attach_p99=percentile(self.cold_waits, 99),
            queue_wait_p50=percentile(queue_waits, 50),
            queue_wait_p99=percentile(queue_waits, 99),
            decision_ms_p50=percentile(dec_ms, 50),
            decision_ms_p99=percentile(dec_ms, 99),
            decisions=len(dec_ms),
            pool_hits=self.pool.hits, pool_misses=self.pool.misses,
            pool_refills=self.pool.refills,
            pruned_intervals=self.arbiter.pruned_intervals,
            env_utilization={n: self.arbiter.utilization(n)
                             for n in self.registry.names()},
            tenants={
                name: {"attached": t.attached_total, "quota": t.quota,
                       "weight": t.weight,
                       "admission_wait": t.admission_wait}
                for name, t in self.tenants.items()},
            promotions=sum(r.promotions for r in self.reports),
            races=sum(r.races for r in self.reports),
            race_waste_seconds=sum(r.race_waste_seconds
                                   for r in self.reports),
            session_reports=list(self.reports))


# ----------------------------------------------------------------------
# the wire frontend
# ----------------------------------------------------------------------

class WireFrontend:
    """Gateway control plane over one transport: handles inbound ATTACH
    (builds the Notebook from the payload, queues admission, acks with
    the session id) and DETACH; notifies the client with a DETACH frame
    when a session completes.  Driven by a loop timer calling
    ``transport.poll()`` — many frontends share the one gateway thread."""

    def __init__(self, gw: GatewayService, transport):
        self.gw = gw
        self.transport = transport
        self.closed = False
        self.attaches = 0
        self.detaches = 0

    # -- gateway-side notifications --------------------------------------
    def notify_attached(self, sess: _GwSession, admission_wait: float,
                        ready_at: float) -> None:
        self._send(wire.json_frame(wire.ACK, {
            "session": sess.id, "admission_wait": admission_wait,
            "ready_at": ready_at, "warm": sess.worker.warm}))

    def notify_detached(self, sess: _GwSession, reason: str) -> None:
        self._send(wire.detach_frame(sess.id, reason))

    def _send(self, frame) -> None:
        if self.closed:
            return
        try:
            self.transport.send(frame)
        except WireError:
            self.closed = True

    # -- the poll tick ----------------------------------------------------
    def _tick(self):
        if self.closed:
            return False
        while True:
            try:
                frame = self.transport.poll()
            except WireError:
                self.closed = True         # connection died: stand down
                return False
            if frame is None:
                break
            self._handle(frame)
        if self.gw.stop_when_idle and self.gw._idle():
            return False                   # storm drained: let the loop empty
        return None

    def _handle(self, frame) -> None:
        t = frame.ftype
        if t == wire.ATTACH:
            doc = wire.parse_attach(frame)
            nb = Notebook(doc["notebook"])
            for c in doc["cells"]:
                nb.add_cell(c["source"], cost=c["cost"])
            sid = self.gw.attach(nb, tenant=doc["tenant"],
                                 think=doc["think"],
                                 session=doc["session"], frontend=self)
            self.attaches += 1
            self._send(wire.json_frame(wire.ACK, {"queued": sid}))
        elif t == wire.DETACH:
            sid, reason = wire.parse_detach(frame)
            try:
                self.gw.detach(sid, reason)
                self.detaches += 1
            except KeyError:
                self._send(wire.json_frame(wire.ERROR, {
                    "error": f"no attached session {sid!r}",
                    "kind": "gateway"}))
        elif t == wire.HELLO:
            wire.parse_hello(frame)
            self._send(wire.hello_frame())
        elif t == wire.BYE:
            self.closed = True
        else:
            self._send(wire.json_frame(wire.ERROR, {
                "error": f"unexpected {wire.TYPE_NAMES.get(t, t)} frame "
                         f"on the gateway control plane",
                "kind": "gateway"}))


# ----------------------------------------------------------------------
# attach storms
# ----------------------------------------------------------------------

def poisson_attach_storm(gw: GatewayService, *, n_sessions: int,
                         rate: float, think_mean: float,
                         make_notebook, tenants=("default",),
                         seed: int = 0, client=None,
                         **runtime_kw) -> list[str]:
    """Schedule a seeded Poisson attach storm against ``gw`` and arm it to
    stop when drained.  ``make_notebook(i) -> Notebook`` builds the i-th
    session's notebook; tenants are assigned round-robin.  Direct mode
    queues :meth:`GatewayService.attach` calls on the loop; pass
    ``client`` (the client end of a frontend's transport) to instead send
    real ``ATTACH`` frames across the wire at each arrival, exercising
    the full decode → admit → ack path.  Returns the session ids (direct
    mode) or the ids encoded in the frames (wire mode)."""
    from repro.core.scheduler import WorkloadTrace

    trace = WorkloadTrace.poisson(
        n_sessions, rate=rate, think_mean=think_mean,
        cells_per_session=len(make_notebook(0).cells), seed=seed)
    gw.expect_storm(n_sessions)
    sids = []
    for i, arrival in enumerate(trace.arrivals):
        nb = make_notebook(i)
        tenant = tenants[i % len(tenants)]
        sid = f"storm{seed}x{i:05d}-{nb.name}"
        sids.append(sid)
        if client is None:
            gw.attach(nb, tenant=tenant, think=trace.think[i], at=arrival,
                      session=sid, **runtime_kw)
        else:
            frame = wire.attach_frame(
                tenant, nb.name,
                [{"source": c.source, "cost": c.cost} for c in nb.cells],
                think=trace.think[i], session=sid)
            gw.loop.call_at(arrival, client.send, frame, priority=-2)
    return sids
