"""Predictive interaction models (the decision plane's prediction subsystem).

The paper's context detector (§II-B, Algorithm 1) mines the history of
cell-order interactions for non-decreasing sequences and predicts the block
the user is about to execute.  This module extracts that prediction into a
pluggable :class:`InteractionModel` interface so the placement policies, the
pipelined engine's speculative prefetch, and the scheduler's telemetry all
consume one abstraction:

* :class:`FrequencyModel` — Algorithm 1, made *incremental*: per-event O(1)
  amortized suffix-count updates instead of the O(n²) per-query history
  rescans of the original detector.  Scores (and tie-breaks) are
  bit-identical to :func:`repro.core.context.sequence_stats`.
* :class:`MarkovModel` — k-th-order transition counts with Laplace
  smoothing; yields a *full* next-cell probability distribution and backs
  off to shorter contexts when the current one is unseen.
* :class:`RecencyModel` — exponentially decayed first-order transitions, so
  drifting interactivity (the user moves to a new part of the notebook)
  doesn't fossilize the predictor.
* :class:`EnsembleModel` — a multiplicative-weights mixture of the above:
  each realized next cell reweights the members by the probability they
  assigned to it.

:class:`ConfidenceGate` gates speculative prefetch on predicted probability
mass and self-calibrates its threshold online from realized hit/miss
outcomes (fed from KB prediction provenance by the runtime).
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field


def _argmax(dist: dict[int, float]) -> tuple[int, float] | None:
    """Deterministic argmax: highest probability, smallest cell id on ties."""
    if not dist:
        return None
    best = max(dist.items(), key=lambda kv: (kv[1], -kv[0]))
    return best


def top_candidates(dist: dict[int, float], k: int) -> list[tuple[int, float]]:
    """The ``k`` most likely next cells, deterministically ordered (highest
    probability first, smallest cell id on ties) — the shared target-
    selection rule for speculative prefetch and background trickling."""
    if k <= 0:
        return []
    return sorted(dist.items(), key=lambda kv: (-kv[1], kv[0]))[:k]


# ----------------------------------------------------------------------
# interface
# ----------------------------------------------------------------------

class InteractionModel:
    """One next-cell predictor.  ``observe`` feeds realized executions;
    ``distribution`` returns P(next cell | history, current); block
    prediction drives the block policies and the prefetch planner."""

    name = "model"

    def observe(self, notebook: str, order: int) -> None:
        raise NotImplementedError

    def distribution(self, notebook: str, current: int) -> dict[int, float]:
        """P(next | current). May be empty when there is no evidence."""
        raise NotImplementedError

    def predict_block_scored(
            self, notebook: str, current: int,
    ) -> tuple[tuple[int, ...], float, int]:
        """(block, score%, n_candidates): the cells expected to run from the
        current one onward, the confidence score of that block (percent),
        and how many distinct candidates the evidence offered."""
        raise NotImplementedError

    def predict_block(self, notebook: str, current: int) -> tuple[int, ...]:
        return self.predict_block_scored(notebook, current)[0]

    def predict_next(self, notebook: str, current: int) -> int | None:
        """The most likely cell after ``current`` (None without evidence)."""
        best = _argmax(self.distribution(notebook, current))
        return best[0] if best is not None else None

    def reset(self, notebook: str | None = None) -> None:
        raise NotImplementedError


# ----------------------------------------------------------------------
# Algorithm 1, incremental
# ----------------------------------------------------------------------

def _contiguous_subseq(a: tuple, b: tuple) -> bool:
    """a is a contiguous subsequence of b (shared with context.py's
    reference implementation — one definition, one semantics)."""
    n, m = len(a), len(b)
    if n > m:
        return False
    return any(b[i:i + n] == a for i in range(m - n + 1))


class _FreqState:
    """Per-notebook incremental Algorithm-1 bookkeeping.

    The key identity: after filtering to sequences containing the current
    cell, a sequence's Algorithm-1 subtotal equals the number of run
    *occurrences* that contain it as a contiguous subsequence (each run
    containing s also contains the current cell, because s does).  So we
    maintain, per distinct contiguous subsequence ever produced by a closed
    run, the count of closed-run occurrences containing it — updated once
    when a run closes (O(L³) in the run length L, which is bounded by the
    notebook's cell count: O(1) amortized in the history length)."""

    __slots__ = ("counts", "sub_occ", "containing", "first_seen", "seq_no",
                 "open_run", "last")

    def __init__(self):
        self.counts: dict[tuple[int, ...], int] = {}
        self.sub_occ: dict[tuple[int, ...], int] = defaultdict(int)
        self.containing: dict[int, set[tuple[int, ...]]] = defaultdict(set)
        self.first_seen: dict[tuple[int, ...], int] = {}
        self.seq_no = 0
        self.open_run: list[int] = []
        self.last: int | None = None

    def push(self, order: int) -> None:
        if self.open_run and order < self.open_run[-1]:
            self._close()
        self.open_run.append(order)

    def _close(self) -> None:
        run = tuple(self.open_run)
        self.open_run = []
        if run not in self.counts:
            self.counts[run] = 0
            self.first_seen[run] = self.seq_no
            for o in set(run):
                self.containing[o].add(run)
        self.counts[run] += 1
        self.seq_no += 1
        # every distinct contiguous subsequence of this occurrence is
        # contained one more time
        n = len(run)
        subs = {run[i:j] for i in range(n) for j in range(i + 1, n + 1)}
        for s in subs:
            self.sub_occ[s] += 1


class FrequencyModel(InteractionModel):
    """Algorithm 1 (paper §II-B) with incremental per-event updates.

    ``stats``/``predict_block_scored`` are bit-identical to the original
    per-query :func:`repro.core.context.sequence_stats` rescan, including
    dict ordering (increasing length, then first appearance) — which the
    legacy ``max`` tie-breaking depends on."""

    name = "frequency"

    def __init__(self):
        self._nb: dict[str, _FreqState] = defaultdict(_FreqState)

    def observe(self, notebook: str, order: int) -> None:
        self._nb[notebook].push(int(order))

    def reset(self, notebook: str | None = None) -> None:
        if notebook is None:
            self._nb.clear()
        else:
            self._nb.pop(notebook, None)

    # -- Algorithm 1 ----------------------------------------------------
    def stats(self, notebook: str,
              current: int | None = None) -> dict[tuple[int, ...], float]:
        st = self._nb[notebook]
        cur = tuple(st.open_run)
        if current is None:
            cands = set(st.counts)
            if cur:
                cands.add(cur)
        else:
            cands = set(st.containing.get(current, ()))
            if cur and current in cur:
                cands.add(cur)
        if not cands:
            return {}
        raw: dict[tuple[int, ...], int] = {}
        for s in cands:
            v = st.sub_occ.get(s, 0)
            if cur and _contiguous_subseq(s, cur):
                v += 1
            raw[s] = v
        total = sum(raw.values())
        # legacy ordering: increasing length, ties by first appearance (the
        # open run, when unseen as a closed run, appears last)
        nxt = st.seq_no
        ordered = sorted(raw, key=lambda s: (len(s),
                                             st.first_seen.get(s, nxt)))
        return {s: raw[s] / total * 100.0 for s in ordered}

    def distribution(self, notebook: str, current: int) -> dict[int, float]:
        """Next-hop distribution implied by Algorithm 1: each candidate
        sequence votes its score for its successor of the current cell."""
        stats = self.stats(notebook, current)
        votes: dict[int, float] = defaultdict(float)
        for s, score in stats.items():
            i = s.index(current)
            if i + 1 < len(s):
                votes[s[i + 1]] += score
        total = sum(votes.values())
        if total <= 0:
            return {}
        return {c: v / total for c, v in sorted(votes.items())}

    def predict_block_scored(
            self, notebook: str, current: int,
    ) -> tuple[tuple[int, ...], float, int]:
        stats = self.stats(notebook, current)
        if not stats:
            return (current,), 0.0, 0
        best, score = max(stats.items(), key=lambda kv: (kv[1], len(kv[0])))
        i = best.index(current)
        return best[i:], score, len(stats)

    def predict_next(self, notebook: str, current: int) -> int | None:
        # legacy rule: the element following the current cell in the most
        # probable sequence (not the vote-pooled argmax)
        block = self.predict_block(notebook, current)
        if len(block) > 1:
            return block[1]
        return None


# ----------------------------------------------------------------------
# Markov / recency / ensemble
# ----------------------------------------------------------------------

class MarkovModel(InteractionModel):
    """k-th-order transition counts with Laplace smoothing and backoff.

    Maintains counts for every context length 1..k so an unseen long
    context backs off to shorter ones; the distribution is smoothed over
    the notebook's observed vocabulary (plus the queried cell), so it
    always sums to 1 whenever there is any evidence."""

    name = "markov"

    def __init__(self, order: int = 2, alpha: float = 0.5,
                 horizon: int = 8, block_threshold: float = 0.4):
        assert order >= 1
        self.order = order
        self.alpha = float(alpha)
        self.horizon = int(horizon)
        self.block_threshold = float(block_threshold)
        self._trans: dict[str, dict[tuple[int, ...], dict[int, int]]] = \
            defaultdict(dict)
        self._tail: dict[str, list[int]] = defaultdict(list)
        self._vocab: dict[str, set[int]] = defaultdict(set)

    def observe(self, notebook: str, order: int) -> None:
        order = int(order)
        tail = self._tail[notebook]
        table = self._trans[notebook]
        for k in range(1, self.order + 1):
            if len(tail) >= k:
                ctx = tuple(tail[-k:])
                nxt = table.setdefault(ctx, {})
                nxt[order] = nxt.get(order, 0) + 1
        tail.append(order)
        del tail[:-self.order]
        self._vocab[notebook].add(order)

    def reset(self, notebook: str | None = None) -> None:
        for d in (self._trans, self._tail, self._vocab):
            if notebook is None:
                d.clear()
            else:
                d.pop(notebook, None)

    # ------------------------------------------------------------------
    def _context_for(self, notebook: str, current: int) -> list[int]:
        tail = self._tail[notebook]
        if tail and tail[-1] == current:
            return list(tail)
        return (list(tail) + [current])[-self.order:]

    def _dist_from_context(self, notebook: str,
                           ctx: list[int]) -> dict[int, float]:
        seen = self._vocab[notebook]
        if not seen:
            return {}          # no evidence at all: no distribution
        vocab = sorted(seen | set(ctx))
        table = self._trans[notebook]
        for k in range(min(self.order, len(ctx)), 0, -1):
            counts = table.get(tuple(ctx[-k:]))
            if counts:
                total = sum(counts.values())
                denom = total + self.alpha * len(vocab)
                return {v: (counts.get(v, 0) + self.alpha) / denom
                        for v in vocab}
        return {v: 1.0 / len(vocab) for v in vocab}

    def _raw_candidates(self, notebook: str, ctx: list[int]) -> int:
        table = self._trans[notebook]
        for k in range(min(self.order, len(ctx)), 0, -1):
            counts = table.get(tuple(ctx[-k:]))
            if counts:
                return len(counts)
        return 0

    def distribution(self, notebook: str, current: int) -> dict[int, float]:
        return self._dist_from_context(
            notebook, self._context_for(notebook, current))

    def predict_block_scored(
            self, notebook: str, current: int,
    ) -> tuple[tuple[int, ...], float, int]:
        ctx = self._context_for(notebook, current)
        ncand = self._raw_candidates(notebook, ctx)
        if ncand == 0:
            return (current,), 0.0, 0
        block = [current]
        score = 0.0
        roll = list(ctx)
        for step in range(self.horizon):
            best = _argmax(self._dist_from_context(notebook, roll))
            if best is None:
                break
            nxt, p = best
            if step == 0:
                score = p * 100.0
            # a block is a non-decreasing run (paper §II-B): a predicted
            # wrap-around (loop restart) ends the block rather than
            # promising cells the runtime's plan bookkeeping would drop
            if p < self.block_threshold or nxt in block or nxt < block[-1]:
                break
            block.append(nxt)
            roll = (roll + [nxt])[-self.order:]
        return tuple(block), score, ncand


class RecencyModel(InteractionModel):
    """Exponentially decayed first-order transitions.

    Each observed transition adds weight 1; every prior weight decays by
    ``decay`` per event, applied lazily (stored as (weight, stamp) pairs),
    so observe is O(1) and queries touch only the current cell's
    successors.  Drift therefore overtakes fossils in O(log) events."""

    name = "recency"

    def __init__(self, decay: float = 0.9, horizon: int = 8,
                 block_threshold: float = 0.4):
        assert 0.0 < decay <= 1.0
        self.decay = float(decay)
        self.horizon = int(horizon)
        self.block_threshold = float(block_threshold)
        # nb -> prev -> {next: (weight, stamp)}
        self._w: dict[str, dict[int, dict[int, tuple[float, int]]]] = \
            defaultdict(dict)
        self._t: dict[str, int] = defaultdict(int)
        self._last: dict[str, int] = {}

    def observe(self, notebook: str, order: int) -> None:
        order = int(order)
        t = self._t[notebook]
        last = self._last.get(notebook)
        if last is not None:
            succ = self._w[notebook].setdefault(last, {})
            w, stamp = succ.get(order, (0.0, t))
            succ[order] = (w * self.decay ** (t - stamp) + 1.0, t)
        self._t[notebook] = t + 1
        self._last[notebook] = order

    def reset(self, notebook: str | None = None) -> None:
        for d in (self._w, self._t, self._last):
            if notebook is None:
                d.clear()
            else:
                d.pop(notebook, None)

    def _weights(self, notebook: str, current: int) -> dict[int, float]:
        t = self._t[notebook]
        succ = self._w[notebook].get(current)
        if not succ:
            return {}
        return {v: w * self.decay ** (t - stamp)
                for v, (w, stamp) in sorted(succ.items())}

    def distribution(self, notebook: str, current: int) -> dict[int, float]:
        w = self._weights(notebook, current)
        total = sum(w.values())
        if total <= 0:
            return {}
        return {v: x / total for v, x in w.items()}

    def predict_block_scored(
            self, notebook: str, current: int,
    ) -> tuple[tuple[int, ...], float, int]:
        dist = self.distribution(notebook, current)
        if not dist:
            return (current,), 0.0, 0
        block = [current]
        score = 0.0
        cur = current
        for step in range(self.horizon):
            best = _argmax(self.distribution(notebook, cur))
            if best is None:
                break
            nxt, p = best
            if step == 0:
                score = p * 100.0
            # blocks are non-decreasing runs: a wrap-around ends the block
            if p < self.block_threshold or nxt in block or nxt < block[-1]:
                break
            block.append(nxt)
            cur = nxt
        return tuple(block), score, len(dist)


class EnsembleModel(InteractionModel):
    """Multiplicative-weights mixture of interaction models.

    Before each observation reaches the members, every member is scored by
    the probability it assigned to the realized next cell; weights multiply
    by ``floor + p`` and renormalize, so persistently wrong members decay
    and the mixture tracks whichever member fits the current interactivity
    regime (frequency for stable loops, recency under drift)."""

    name = "ensemble"

    def __init__(self, models: list[InteractionModel] | None = None,
                 floor: float = 0.1, min_weight: float = 0.02):
        self.models = models if models is not None else [
            FrequencyModel(), MarkovModel(), RecencyModel()]
        assert self.models
        self.floor = float(floor)
        self.min_weight = float(min_weight)
        self.weights = [1.0 / len(self.models)] * len(self.models)
        self._last: dict[str, int] = {}

    def observe(self, notebook: str, order: int) -> None:
        order = int(order)
        last = self._last.get(notebook)
        if last is not None:
            scores = []
            for m in self.models:
                p = m.distribution(notebook, last).get(order, 0.0)
                scores.append(self.floor + p)
            new = [w * s for w, s in zip(self.weights, scores)]
            total = sum(new)
            if total > 0:
                new = [max(w / total, self.min_weight) for w in new]
                norm = sum(new)
                self.weights = [w / norm for w in new]
        for m in self.models:
            m.observe(notebook, order)
        self._last[notebook] = order

    def reset(self, notebook: str | None = None) -> None:
        for m in self.models:
            m.reset(notebook)
        if notebook is None:
            self._last.clear()
            self.weights = [1.0 / len(self.models)] * len(self.models)
        else:
            self._last.pop(notebook, None)

    def distribution(self, notebook: str, current: int) -> dict[int, float]:
        mix: dict[int, float] = defaultdict(float)
        for w, m in zip(self.weights, self.models):
            for c, p in m.distribution(notebook, current).items():
                mix[c] += w * p
        total = sum(mix.values())
        if total <= 0:
            return {}
        return {c: p / total for c, p in sorted(mix.items())}

    def predict_block_scored(
            self, notebook: str, current: int,
    ) -> tuple[tuple[int, ...], float, int]:
        i = max(range(len(self.models)), key=lambda j: self.weights[j])
        block, score, ncand = self.models[i].predict_block_scored(
            notebook, current)
        mix = self.distribution(notebook, current)
        if len(block) > 1 and mix:
            score = mix.get(block[1], 0.0) * 100.0
        return block, score, max(ncand, len(mix))


# ----------------------------------------------------------------------
# confidence gate (speculative-prefetch admission)
# ----------------------------------------------------------------------

@dataclass
class ConfidenceGate:
    """Admits a speculative prefetch only when the predicted next hop's
    probability mass clears ``threshold`` — and moves the threshold online:
    each realized outcome of an *issued* prefetch updates an EWMA hit-rate
    estimate, and the threshold steps toward keeping that estimate at
    ``target_hit_rate`` (more misses -> stricter gate, clamped to bounds).
    The runtime feeds outcomes from KB prediction-provenance records, so
    the gate self-calibrates to the user's actual interactivity."""

    threshold: float = 0.35
    target_hit_rate: float = 0.6
    lr: float = 0.1
    relax: float = 0.05
    bounds: tuple[float, float] = (0.05, 0.95)
    hit_rate: float = field(default=0.5, init=False)
    issued: int = field(default=0, init=False)
    hits: int = field(default=0, init=False)
    rejections: int = field(default=0, init=False)
    _initial: float = field(default=0.0, init=False, repr=False)

    def __post_init__(self):
        self._initial = self.threshold

    def allow(self, prob: float) -> bool:
        return prob >= self.threshold

    def observe(self, hit: bool) -> None:
        """Record the realized outcome of one issued prefetch."""
        self.issued += 1
        self.hits += int(hit)
        self.hit_rate = (1 - self.lr) * self.hit_rate + self.lr * float(hit)
        lo, hi = self.bounds
        self.threshold = min(hi, max(
            lo, self.threshold + self.lr * (self.target_hit_rate
                                            - self.hit_rate)))

    def rejected(self) -> None:
        """A candidate was gated out.  The threshold only *rises* on issued
        outcomes, so without this it could latch above the model's maximum
        attainable probability and kill speculation forever; each rejection
        decays a latched-high threshold back toward its initial value, so
        the gate re-opens once the miss storm that raised it has passed."""
        self.rejections += 1
        if self.threshold > self._initial:
            self.threshold = self._initial + (
                self.threshold - self._initial) * (1.0 - self.relax)


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------

MODELS = {"frequency": FrequencyModel, "markov": MarkovModel,
          "recency": RecencyModel, "ensemble": EnsembleModel}


def make_model(spec: "InteractionModel | str | None") -> InteractionModel:
    """Resolve a model spec: an instance passes through, a name constructs
    the registered class, None means the paper's default (FrequencyModel)."""
    if spec is None:
        return FrequencyModel()
    if isinstance(spec, InteractionModel):
        return spec
    if isinstance(spec, str):
        if spec not in MODELS:
            raise ValueError(f"unknown interaction model {spec!r}; "
                             f"choose from {sorted(MODELS)}")
        return MODELS[spec]()
    raise TypeError(f"model spec must be InteractionModel | str | None, "
                    f"got {type(spec).__name__}")
