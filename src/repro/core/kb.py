"""Knowledge Base + provenance (paper §II-C).

The KB stores, per (parameter, context), the estimated threshold above which
migrating a cell pays off (seeded by an expert, updated by Algorithm 2), plus
PROV-ML-lite provenance records of every cell execution and migration
decision ("Notebook to Knowledge Base" service / ProvLake stand-in).
"""
from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass, field
from typing import Any


@dataclass
class ParamEstimate:
    param: str
    threshold: float
    valid_range: tuple[float, float] = (0.0, float("inf"))
    source: str = "expert"           # expert | learned
    history: list[float] = field(default_factory=list)
    # EWMA coefficient for updates: None keeps the paper's behaviour (the
    # last observation overwrites the threshold); 0 < smoothing <= 1 blends
    # each new observation into the running estimate so one noisy probe run
    # can't swing the migration threshold wholesale.
    smoothing: float | None = None

    def update(self, value: float) -> None:
        lo, hi = self.valid_range
        v = float(min(max(value, lo), hi))
        if self.smoothing is not None and self.source == "learned":
            v = self.smoothing * v + (1.0 - self.smoothing) * self.threshold
            v = float(min(max(v, lo), hi))
        self.threshold = v
        self.source = "learned"
        self.history.append(self.threshold)


@dataclass
class ProvRecord:
    """PROV-ML-lite: Activity (cell run) + Agent (env) + used/generated."""
    kind: str                         # cell-run | migration | kb-update
    cell_id: str | None
    env: str | None
    started: float
    ended: float
    params: dict[str, Any] = field(default_factory=dict)
    used: tuple[str, ...] = ()
    generated: tuple[str, ...] = ()
    decision: str | None = None
    reason: str | None = None


class KnowledgeBase:
    def __init__(self):
        self._params: dict[str, ParamEstimate] = {}
        self.provenance: list[ProvRecord] = []

    # --- parameter estimates (knowledge-aware policy) ------------------
    def seed(self, param: str, threshold: float,
             valid_range: tuple[float, float] = (0.0, float("inf")),
             smoothing: float | None = None) -> None:
        self._params[param] = ParamEstimate(param, threshold, valid_range,
                                            smoothing=smoothing)

    def get_known_parameters(self) -> list[str]:
        return list(self._params)

    def get(self, param: str) -> ParamEstimate | None:
        return self._params.get(param)

    def update(self, param: str, value: float) -> None:
        if param not in self._params:
            self._params[param] = ParamEstimate(param, value, source="learned")
        else:
            self._params[param].update(value)
        self.record(ProvRecord("kb-update", None, None, time.time(), time.time(),
                               params={param: value}))

    # --- provenance -----------------------------------------------------
    def record(self, rec: ProvRecord) -> None:
        self.provenance.append(rec)

    def records(self, kind: str | None = None) -> list[ProvRecord]:
        return [r for r in self.provenance if kind is None or r.kind == kind]

    def record_prediction(self, cell_id: str | None, notebook: str,
                          predicted: dict[int, float], realized: int,
                          when: float = 0.0) -> ProvRecord:
        """Record predicted next-cell distribution vs the realized next cell
        (the calibration signal the prefetch confidence gate learns from).
        Only the top few candidates are kept so provenance stays bounded."""
        top = sorted(predicted.items(), key=lambda kv: (-kv[1], kv[0]))[:5]
        rec = ProvRecord(
            "prediction", cell_id, None, when, when,
            params={"notebook": notebook,
                    "predicted": [[int(c), float(p)] for c, p in top],
                    "realized": int(realized),
                    "hit": bool(top and top[0][0] == realized),
                    "prob_realized": float(predicted.get(realized, 0.0))})
        self.record(rec)
        return rec

    def export_json(self, max_records: int = 1000, *,
                    kind: str | None = None, indent: int | None = None) -> str:
        """Bounded JSON export of provenance: the ``max_records`` most
        recent records (optionally one kind), with non-JSON-native values
        coerced via ``str`` so arbitrary params can't break the export."""
        max_records = max(0, int(max_records))
        recs = self.records(kind)[-max_records:] if max_records else []
        payload = {
            "params": {p: {"threshold": e.threshold, "source": e.source,
                           "smoothing": e.smoothing,
                           "history": list(e.history)}
                       for p, e in sorted(self._params.items())},
            "records": [dataclasses.asdict(r) for r in recs],
            "total_records": len(self.provenance),
            "exported_records": len(recs),
        }
        return json.dumps(payload, default=str, indent=indent)
