"""Knowledge Base + provenance (paper §II-C).

The KB stores, per (parameter, context), the estimated threshold above which
migrating a cell pays off (seeded by an expert, updated by Algorithm 2), plus
PROV-ML-lite provenance records of every cell execution and migration
decision ("Notebook to Knowledge Base" service / ProvLake stand-in).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any


@dataclass
class ParamEstimate:
    param: str
    threshold: float
    valid_range: tuple[float, float] = (0.0, float("inf"))
    source: str = "expert"           # expert | learned
    history: list[float] = field(default_factory=list)

    def update(self, value: float) -> None:
        lo, hi = self.valid_range
        self.threshold = float(min(max(value, lo), hi))
        self.source = "learned"
        self.history.append(self.threshold)


@dataclass
class ProvRecord:
    """PROV-ML-lite: Activity (cell run) + Agent (env) + used/generated."""
    kind: str                         # cell-run | migration | kb-update
    cell_id: str | None
    env: str | None
    started: float
    ended: float
    params: dict[str, Any] = field(default_factory=dict)
    used: tuple[str, ...] = ()
    generated: tuple[str, ...] = ()
    decision: str | None = None
    reason: str | None = None


class KnowledgeBase:
    def __init__(self):
        self._params: dict[str, ParamEstimate] = {}
        self.provenance: list[ProvRecord] = []

    # --- parameter estimates (knowledge-aware policy) ------------------
    def seed(self, param: str, threshold: float,
             valid_range: tuple[float, float] = (0.0, float("inf"))) -> None:
        self._params[param] = ParamEstimate(param, threshold, valid_range)

    def get_known_parameters(self) -> list[str]:
        return list(self._params)

    def get(self, param: str) -> ParamEstimate | None:
        return self._params.get(param)

    def update(self, param: str, value: float) -> None:
        if param not in self._params:
            self._params[param] = ParamEstimate(param, value, source="learned")
        else:
            self._params[param].update(value)
        self.record(ProvRecord("kb-update", None, None, time.time(), time.time(),
                               params={param: value}))

    # --- provenance -----------------------------------------------------
    def record(self, rec: ProvRecord) -> None:
        self.provenance.append(rec)

    def records(self, kind: str | None = None) -> list[ProvRecord]:
        return [r for r in self.provenance if kind is None or r.kind == kind]
