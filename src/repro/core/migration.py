"""The migration engine and the hybrid runtime over the environment fabric.

This is the paper's server-side machinery assembled: sessions emit Table-I
telemetry on the MQ bus; the context detector listens; the analyzer decides
placement; the engine moves *reduced, delta, compressed* state between
environments; everything is recorded as provenance.

Environments live in :mod:`repro.core.fabric`: an ExecutionEnvironment is
"a place code can run with its own namespace" — the user's machine, a cloud
node, or, in the TPU adaptation, a JAX mesh (``DistContext``), which is how
the same engine implements checkpointing (migration to a storage env) and
elastic rescaling (migration between meshes).  The runtime works over any
:class:`EnvironmentRegistry` (N environments, per-pair link costs); the
paper's local/remote dyad is the two-env instance.  Timing follows the
paper's §III protocol: declared cell costs (or measured wall time) divided
by the environment speedup, on a simulated clock.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core import telemetry as T
from repro.core.analyzer import Decision, MigrationAnalyzer, PerfModel
from repro.core.context import ContextDetector
from repro.core.fabric import EnvironmentRegistry, ExecutionEnvironment
from repro.core.interaction import (ConfidenceGate, InteractionModel,
                                    top_candidates)
from repro.core.kb import KnowledgeBase, ProvRecord
from repro.core.notebook import Cell, Notebook
from repro.core.reducer import (DIGEST_BYTES, SerializationFailure,
                                SerializedState, StateReducer)
from repro.core.simclock import SimClock
from repro.core.state import ExecutionState

__all__ = [
    "EnvFailure", "ExecutionEnvironment", "MigrationResult",
    "MigrationEngine", "PipelinedMigrationEngine", "DeltaReplicator",
    "HybridRuntime",
]


class EnvFailure(Exception):
    """An environment died while a cell (or a migration into it) was in
    flight.  The clock has been advanced to the failure instant — the work
    up to then is charged and lost; the fleet scheduler owns recovery
    (checkpoint restore or rerun-from-home)."""

    def __init__(self, env: str, at: float, order: int | None = None, *,
                 during: str = "execute", wasted: float = 0.0):
        super().__init__(f"environment {env!r} failed at t={at:.3f} "
                         f"during {during} (cell order={order}, "
                         f"{wasted:.3f}s of work lost)")
        self.env = env
        self.at = at
        self.order = order
        self.during = during
        self.wasted = wasted


@dataclass
class MigrationResult:
    src: str
    dst: str
    names: tuple[str, ...]
    deleted: tuple[str, ...]
    nbytes: int
    seconds: float
    full_bytes: int = 0      # what a full-state migration would have cost
    noop: bool = False       # empty delta: nothing travelled, nothing charged
    prefetched: tuple[str, ...] = ()   # names applied from a pipelined prefetch
    wasted_prefetch_bytes: int = 0     # speculative bytes streamed but unused
    claimed: tuple[str, ...] = ()      # names claimed from trickled replication
    claim_bytes: int = 0               # manifest-only cost of that claim
    # transport plane: what the migration actually cost on a real transport.
    # ``seconds`` above stays the *modeled* charge (placement decisions and
    # the sim clock run on it); these record reality when frames moved.
    transport: str = "loopback"
    wire_frames: int = 0               # frames that crossed the transport
    wall_seconds: float = 0.0          # measured transfer wall time


@dataclass
class _PendingPrefetch:
    """An in-flight background transfer started by the pipelined engine."""
    src: str
    dst: str
    ser: SerializedState
    started_at: float
    ready_at: float
    nbytes: int
    held: frozenset = frozenset()   # chunks dst already had at begin time
    predicted_order: int | None = None   # cell this speculation bets on
    prob: float | None = None            # predicted probability (None=planned)
    dst_store: object = None             # receiver's chunk store (for banking)
    peer: object = None                  # transport peer when dst is remote


class MigrationEngine:
    """Reduced/delta/compressed state transfer between environments.

    Transfer cost resolves through the registry's per-pair links when a
    registry is attached; otherwise the scalar ``bandwidth``/``latency``
    model applies to every pair (the paper's uniform setup).  Optional
    ``serialize_bandwidth``/``compress_bandwidth`` model the capture and
    codec stages; this synchronous engine charges the three stages
    *serially* — :class:`PipelinedMigrationEngine` overlaps them.
    """

    def __init__(self, reducer: StateReducer, *, bandwidth: float = 1e9,
                 latency: float = 0.5, delta: bool = True,
                 registry: EnvironmentRegistry | None = None,
                 serialize_bandwidth: float = math.inf,
                 compress_bandwidth: float = math.inf):
        self.reducer = reducer
        self.bandwidth = bandwidth
        self.latency = latency
        self.delta = delta
        self.registry = registry
        self.serialize_bandwidth = serialize_bandwidth
        self.compress_bandwidth = compress_bandwidth
        # receiver's content view: env name -> {state name -> digest}
        self.synced: dict[str, dict[str, int]] = {}
        self.log: list[MigrationResult] = []
        # background delta replicator (attached by the runtime when live
        # replication is on); decision-time migrations claim its banked state
        self.replicator: "DeltaReplicator | None" = None
        # ONE waste ledger for every speculative byte that streamed but was
        # never claimed — pipelined prefetch and trickled replication both
        # charge here, so reports surface a single number
        self.prefetch_wasted_bytes = 0
        # chunk manifests of the most recent migrate() — consumed by the
        # Checkpointer; deliberately NOT kept per-log-entry, which would pin
        # every byte ever migrated in memory for the session's lifetime
        self.last_ser: SerializedState | None = None

    # -- cost model ------------------------------------------------------
    def _link_seconds(self, nbytes: int, src: str | None, dst: str | None) -> float:
        if self.registry is not None and src is not None and dst is not None:
            return self.registry.transfer_seconds(src, dst, nbytes)
        return self.latency + nbytes / self.bandwidth

    def _stage_seconds(self, nbytes: int) -> float:
        return nbytes / self.serialize_bandwidth + nbytes / self.compress_bandwidth

    def transfer_seconds(self, nbytes: int, src: str | None = None,
                         dst: str | None = None) -> float:
        """Serialize + compress + network, charged end to end (synchronous)."""
        return self._stage_seconds(nbytes) + self._link_seconds(nbytes, src, dst)

    # ------------------------------------------------------------------
    def migrate(self, src: ExecutionEnvironment, dst: ExecutionEnvironment,
                cell_source: str | None = None,
                names: set[str] | None = None,
                strict: bool = True, now: float | None = None) -> MigrationResult:
        """Move the state ``cell_source`` needs (or explicit ``names``) from
        src to dst; only new/changed names are serialized when delta is on.

        When either end carries a transport ``peer`` (socket / subprocess),
        the migration genuinely streams wire frames — chunk-manifest
        exchange, chunk payloads, tombstones — instead of moving objects in
        process; the modeled ``seconds`` are unchanged, and the real frame
        count and wall time land on the result."""
        if getattr(src, "peer", None) is not None:
            return self._migrate_pull(src, dst, cell_source, names, strict)
        import types as _types
        modules: set[str] = set()
        full_state = names is None and cell_source is None
        if names is None:
            if cell_source is not None:
                names, modules, _ = self.reducer.reduce(src.state, cell_source)
            else:
                names = set(src.state.names())
        rep = self.replicator
        if rep is not None and full_state and dst.kind != "storage":
            # liveness pruning: a full-state move (return home / block exit)
            # skips names no remaining cell can observe.  Checkpoints
            # (storage destinations) always carry everything — recovery may
            # replay from an older plan position than liveness assumed.
            names = rep.prune_dead(names, src.state)
        # re-import module aliases on the destination (paper: preamble/deps);
        # for a transport-bound destination the alias specs ride the
        # manifest instead and the receiver imports them itself
        dst_peer = getattr(dst, "peer", None)
        mod_aliases: list[str] = []
        for alias, val in list(src.state.ns.items()):
            if isinstance(val, _types.ModuleType) and (
                    alias in names or val.__name__.split(".")[0] in modules):
                mod_aliases.append(f"{alias}={val.__name__}")
                if dst_peer is not None:
                    continue
                try:
                    dst.state.ns[alias] = __import__(val.__name__)
                    if "." in val.__name__:  # alias points at a submodule
                        import importlib
                        dst.state.ns[alias] = importlib.import_module(val.__name__)
                except ImportError:
                    pass
        # module aliases are re-imported on the destination, never serialized
        names = {n for n in names
                 if not isinstance(src.state.get(n), _types.ModuleType)}
        known = self.synced.setdefault(dst.name, {})
        # claim trickled state: names the replicator already banked at dst
        # (content re-validated by digest) need only a manifest, not bytes
        claim_sub: SerializedState | None = None
        if rep is not None and self.delta and dst.kind != "storage":
            claim_sub = rep.peek_claim(src, dst, names, known)
        if self.delta:
            eff_known = (known if claim_sub is None
                         else {**known, **claim_sub.digests})
            send, dead, here = self.reducer.delta_names(src.state, names,
                                                        eff_known)
            send &= set(names)
        else:
            send, dead = set(names), set()
            here = self.reducer.digests(src.state, names)

        ser = self.reducer.serialize_names(
            src.state, send, on_error="raise" if strict else "skip",
            digests=here)      # delta already digested this capture
        # chunk-manifest exchange: the receiver advertises the chunk digests
        # its store already holds; only missing chunks cross the wire, so a
        # small in-place update to a large array moves one chunk, not the
        # array, and a dataset shared across sessions moves once.
        wire_frames, wall_seconds = 0, 0.0
        if dst_peer is not None and (send or dead or mod_aliases):
            # transport-bound destination: the manifest exchange happens
            # over real frames — the receiver's need-ack IS the held set.
            # Module aliases ride the manifest, so they must stream even
            # when the state delta is empty (the loopback path re-imports
            # them unconditionally; an alias-only stream keeps parity)
            stats = dst_peer.send_state(ser, deleted=dead,
                                        modules=mod_aliases)
            held = {d for d in ser.chunks if d in stats.held}
            wire_bytes = ser.wire_nbytes(held)
            # the mirror records what the remote store now holds
            dst.chunk_store.put_many(ser.chunks)
            src.chunk_store.put_many(ser.chunks)
            wire_frames, wall_seconds = stats.frames, stats.wall_seconds
        else:
            dst_store = dst.chunk_store
            held = {d for d in ser.chunks if dst_store.has(d)}
            wire_bytes = ser.wire_nbytes(held)
            dst_store.put_many(ser.missing_chunks(held))
            src.chunk_store.put_many(ser.chunks)  # sender holds its own content
            if dst.kind != "storage" and dst_peer is None:
                # storage envs are manifest+CAS only: restore reads the
                # store, so materializing leaves into the namespace would
                # just pin a second in-RAM copy of every checkpoint
                objs = self.reducer.deserialize(ser, target_ns=dst.state.ns,
                                                chunk_store=dst_store)
                dst.state.update(objs)
        dst.state.drop(dead)

        known.update(ser.digests)
        for n in dead:
            known.pop(n, None)
        # the sender's own content view is now also known
        self.synced.setdefault(src.name, {}).update(here)
        # a deletion on the source is a deletion on *every* synced receiver
        if dead:
            self._propagate_tombstones(dead, exclude=(dst.name,))

        # apply the replication claim: the bytes are already banked at dst,
        # so only the manifest (digest refs + pickle streams) travels — a
        # converged trickle turns the migration into this claim alone
        claim_names: tuple[str, ...] = ()
        claim_bytes = 0
        if claim_sub is not None:
            held_claim = {d for b in claim_sub.blobs.values()
                          for d in b.chunk_digests()}
            claim_bytes = claim_sub.wire_nbytes(held_claim)
            if dst_peer is not None:
                cstats = dst_peer.send_state(claim_sub)
                wire_frames += cstats.frames
                wall_seconds += cstats.wall_seconds
            else:
                objs = self.reducer.deserialize(claim_sub,
                                                target_ns=dst.state.ns,
                                                chunk_store=dst.chunk_store)
                dst.state.update(objs)
            known.update(claim_sub.digests)
            rep.commit_claim(dst.name, claim_sub)
            claim_names = tuple(sorted(claim_sub.blobs))
            wire_bytes += claim_bytes

        # an empty delta is a no-op: nothing crosses the wire, nothing charged
        noop = not send and not dead and not claim_names
        seconds = 0.0 if noop else self.transfer_seconds(
            wire_bytes, src.name, dst.name)
        res = MigrationResult(src.name, dst.name,
                              tuple(sorted(set(send) | set(claim_names))),
                              tuple(sorted(dead)), 0 if noop else wire_bytes,
                              seconds, noop=noop,
                              claimed=claim_names, claim_bytes=claim_bytes,
                              transport=(getattr(dst, "transport", "socket")
                                         if dst_peer is not None
                                         else "loopback"),
                              wire_frames=wire_frames,
                              wall_seconds=wall_seconds)
        self.last_ser = ser
        self.log.append(res)
        return res

    def _migrate_pull(self, src: ExecutionEnvironment,
                      dst: ExecutionEnvironment,
                      cell_source: str | None, names: set[str] | None,
                      strict: bool) -> MigrationResult:
        """``src``'s namespace lives behind a transport peer (a subprocess
        or socket-served env): the remote side reduces, computes the delta
        against our content view, serializes, and streams the state home.
        Chunks ``dst``'s store already holds are not re-requested."""
        from repro.core.transport import import_alias_specs
        known = self.synced.setdefault(dst.name, {})
        ser, dead, modules, stats = src.peer.fetch_state(
            names=set(names) if names is not None else None,
            cell_source=cell_source,
            known=known if self.delta else {},
            strict=strict, delta=self.delta, store=dst.chunk_store)
        # module aliases re-import on the destination (paper: preamble/deps)
        import_alias_specs(dst.state.ns, modules)
        wire_bytes = ser.wire_nbytes(set(stats.held))
        dst.chunk_store.put_many(ser.chunks)
        if dst.kind != "storage":
            objs = self.reducer.deserialize(ser, target_ns=dst.state.ns,
                                            chunk_store=dst.chunk_store)
            dst.state.update(objs)
        dst.state.drop(dead)
        known.update(ser.digests)
        for n in dead:
            known.pop(n, None)
        self.synced.setdefault(src.name, {}).update(ser.digests)
        if dead:
            self._propagate_tombstones(dead, exclude=(dst.name,))
        send = set(ser.blobs)
        noop = not send and not dead
        seconds = 0.0 if noop else self.transfer_seconds(
            wire_bytes, src.name, dst.name)
        res = MigrationResult(src.name, dst.name, tuple(sorted(send)),
                              tuple(sorted(dead)), 0 if noop else wire_bytes,
                              seconds, noop=noop,
                              transport=getattr(src, "transport", "socket"),
                              wire_frames=stats.frames,
                              wall_seconds=stats.wall_seconds)
        self.last_ser = ser
        self.log.append(res)
        return res

    def _propagate_tombstones(self, dead, exclude=()) -> None:
        """Names deleted on the source are dropped on every env whose synced
        view records them, and their digests evicted from all views."""
        for env_name, view in self.synced.items():
            if env_name in exclude:
                continue
            held = [n for n in dead if n in view]
            if not held:
                continue
            for n in held:
                view.pop(n, None)
            if self.registry is not None and env_name in self.registry:
                self.registry[env_name].state.drop(held)

    def invalidate(self, env_name: str, names) -> None:
        """``env_name`` (re)defined these names: every peer's copy — and every
        recorded digest — is stale; force a re-send on the next migration."""
        for view in self.synced.values():
            for n in names:
                view.pop(n, None)


class PipelinedMigrationEngine(MigrationEngine):
    """Chunked serialize → compress → transfer pipeline on the sim clock.

    Two wins over the synchronous engine:

    * within one migration, the three stages overlap chunk-by-chunk, so the
      charge is dominated by the slowest stage instead of their sum;
    * :meth:`begin_prefetch` starts the predicted next hop's transfer in the
      background while the current cell executes — the eventual ``migrate``
      only charges whatever transfer time execution did not already cover.

    Prefetch is *confidence-gated speculation*: callers pass the predicted
    probability of the hop and the :class:`ConfidenceGate` admits only
    predictions whose mass clears its (self-calibrating) threshold
    (``prob=None`` marks a planned, non-speculative transfer — e.g. the
    next cell of a committed block — which always proceeds).  Stale claims
    can be cancelled, and every speculative byte that streamed without
    being applied is accounted in ``prefetch_wasted_bytes`` and on the
    claiming :class:`MigrationResult`.
    """

    def __init__(self, reducer: StateReducer, *,
                 chunk_bytes: int | None = None,
                 gate: ConfidenceGate | None = None,
                 prefetch_top_k: int = 2, **kw):
        super().__init__(reducer, **kw)
        # stage-overlap granularity defaults to the reducer's CAS chunk size
        # so the pipeline and the store chunk the same way
        self.chunk_bytes = int(chunk_bytes if chunk_bytes is not None
                               else max(reducer.chunk_bytes, 1))
        self._pending: dict[str, _PendingPrefetch] = {}
        self.gate = gate if gate is not None else ConfidenceGate()
        self.prefetch_top_k = int(prefetch_top_k)
        self.prefetch_hits = 0
        self.prefetch_issued = 0
        self.prefetch_gated = 0          # speculations the gate rejected
        self.prefetch_cancelled = 0
        self.prefetch_wasted_bytes = 0
        self.prefetch_useful_bytes = 0

    # -- speculative accounting ------------------------------------------
    @staticmethod
    def _delivered_bytes(p: _PendingPrefetch, now: float | None) -> int:
        """Bytes of the speculative transfer on the wire by ``now``."""
        if now is None or now >= p.ready_at:
            return p.nbytes
        span = p.ready_at - p.started_at
        if span <= 0:
            return p.nbytes
        frac = max(0.0, min(1.0, (now - p.started_at) / span))
        return int(p.nbytes * frac)

    def cancel_prefetch(self, dst_name: str,
                        now: float | None = None) -> int:
        """Cancel the pending speculative transfer to ``dst_name``; returns
        the wasted bytes (what already streamed).  Chunks that fully arrived
        are still banked into the receiver's store — content-addressed
        chunks are immutable, so they may yet pay off — but the bytes are
        charged as waste because this speculation did not.  A transport-
        bound destination additionally gets a CANCEL frame (a no-op when
        the synchronous speculative stream already completed; it clears
        remote stream state if the transfer was interrupted)."""
        p = self._pending.pop(dst_name, None)
        if p is None:
            return 0
        wasted = self._delivered_bytes(p, now)
        if now is not None and now >= p.ready_at and p.dst_store is not None:
            p.dst_store.put_many(p.ser.chunks)
        if p.peer is not None:
            p.peer.cancel()
        self.prefetch_cancelled += 1
        self.prefetch_wasted_bytes += wasted
        return wasted

    def cancel_stale(self, keep: set[str],
                     now: float | None = None) -> list[tuple[str, int, int | None]]:
        """Cancel every pending speculation whose destination is not in
        ``keep``; returns (dst, wasted_bytes, predicted_order) tuples."""
        out = []
        for dst in [d for d in self._pending if d not in keep]:
            order = self._pending[dst].predicted_order
            out.append((dst, self.cancel_prefetch(dst, now), order))
        return out

    # -- cost model ------------------------------------------------------
    def transfer_seconds(self, nbytes: int, src: str | None = None,
                         dst: str | None = None) -> float:
        """Chunk-pipelined: latency + one chunk through every stage +
        the remaining chunks behind the bottleneck stage."""
        if nbytes <= 0:
            return self._link_seconds(0, src, dst)
        link = (self.registry.link(src, dst)
                if self.registry is not None and src is not None
                and dst is not None else None)
        net_bw = link.bandwidth if link is not None else self.bandwidth
        lat = link.latency if link is not None else self.latency
        nchunks = max(1, math.ceil(nbytes / self.chunk_bytes))
        chunk = nbytes / nchunks
        stage = [chunk / self.serialize_bandwidth,
                 chunk / self.compress_bandwidth, chunk / net_bw]
        return lat + sum(stage) + (nchunks - 1) * max(stage)

    # -- prefetch --------------------------------------------------------
    def begin_prefetch(self, src: ExecutionEnvironment,
                       dst: ExecutionEnvironment,
                       cell_source: str | None = None,
                       names: set[str] | None = None,
                       now: float = 0.0,
                       prob: float | None = None,
                       predicted_order: int | None = None) -> _PendingPrefetch | None:
        """Snapshot the delta ``cell_source`` will need on ``dst`` and start
        its transfer in the background (completes at ``ready_at`` on the sim
        clock).  Nothing is applied to ``dst`` until ``migrate`` claims it.

        ``prob`` marks the transfer as *speculative* with that predicted
        probability: the confidence gate must admit it, and a superseded
        speculation to the same destination is cancelled (wasted bytes
        accounted).  ``prob=None`` is a planned transfer and bypasses the
        gate (the paper's unconditional next-hop prefetch)."""
        import types as _types
        if getattr(src, "peer", None) is not None:
            return None      # a remote namespace cannot be snapshotted here
        if prob is not None and self.gate is not None \
                and not self.gate.allow(prob):
            self.prefetch_gated += 1
            self.gate.rejected()
            return None
        if dst.name in self._pending:
            # a newer prediction supersedes the in-flight speculation
            self.cancel_prefetch(dst.name, now)
        if names is None:
            if cell_source is not None:
                names, _, _ = self.reducer.reduce(src.state, cell_source)
            else:
                names = set(src.state.names())
        # Speculatively carry the *whole* needed set, not just the current
        # delta: the overlapped cell may invalidate names that look synced
        # right now, and the claim only applies what actually must travel.
        names = {n for n in names if n in src.state.ns
                 and not isinstance(src.state.get(n), _types.ModuleType)}
        if not names:
            return None
        ser = self.reducer.serialize_names(src.state, names, on_error="skip")
        if not ser.blobs:
            return None
        # only chunks the receiver's store lacks actually stream
        dst_peer = getattr(dst, "peer", None)
        if dst_peer is not None:
            # speculative frames really travel: the receiver banks the
            # chunks (no namespace apply until a claiming stream lands)
            stats = dst_peer.send_state(ser, speculative=True)
            held = frozenset(d for d in ser.chunks if d in stats.held)
            dst.chunk_store.put_many(ser.chunks)    # mirror what was banked
        else:
            held = frozenset(d for d in ser.chunks if dst.chunk_store.has(d))
        nbytes = ser.wire_nbytes(set(held))
        pending = _PendingPrefetch(
            src.name, dst.name, ser, started_at=now,
            ready_at=now + self.transfer_seconds(nbytes, src.name, dst.name),
            nbytes=nbytes, held=held, predicted_order=predicted_order,
            prob=prob, dst_store=dst.chunk_store, peer=dst_peer)
        self._pending[dst.name] = pending
        self.prefetch_issued += 1
        return pending

    def migrate(self, src: ExecutionEnvironment, dst: ExecutionEnvironment,
                cell_source: str | None = None,
                names: set[str] | None = None,
                strict: bool = True, now: float | None = None) -> MigrationResult:
        p = self._pending.get(dst.name)
        valid: dict[str, int] = {}
        if p is not None and p.src == src.name:
            # a name is applied wholesale iff the source still holds the
            # snapshotted content (else it must travel fresh) AND the
            # receiver doesn't already have it (else the claim would turn a
            # free no-op delta into a charged wait)
            known = self.synced.setdefault(dst.name, {})
            cand = {n: d for n, d in p.ser.digests.items()
                    if n in p.ser.blobs and n in src.state.ns
                    and known.get(n) != d}
            # one batched launch re-digests every candidate at once
            cur = self.reducer.digest_many(
                {n: src.state.ns[n] for n in cand})
            valid = {n: d for n, d in cand.items() if cur.get(n) == d}
            # the claim then validates per-chunk: content-addressed chunks
            # are immutable, so prefetched chunks are banked into the
            # receiver's store — but only those the transfer has physically
            # delivered.  Once the background transfer completed, everything
            # banks (a name redefined mid-flight re-serializes fresh, yet
            # its unchanged chunks no longer re-cross the wire); before
            # that, only the valid names' chunks bank, because exactly those
            # are paid for via the residual wait below.
            if now is not None and now >= p.ready_at:
                dst.chunk_store.put_many(p.ser.chunks)
            elif valid:
                dst.chunk_store.put_many(
                    {d: p.ser.chunks[d] for n in valid
                     for d in p.ser.blobs[n].chunk_digests()
                     if d in p.ser.chunks})
        if not valid:
            wasted = 0
            if p is not None and p.src == src.name:
                del self._pending[dst.name]      # consumed, nothing useful
                wasted = self._delivered_bytes(p, now)
                self.prefetch_wasted_bytes += wasted
                # like cancel_prefetch: chunks that fully arrived are banked
                # (immutable, content-addressed) so the fallback migration
                # below doesn't re-ship what already crossed the wire — a
                # redefined name re-serializes, but its unchanged chunks
                # collapse to the manifest
                if now is not None and now >= p.ready_at:
                    dst.chunk_store.put_many(p.ser.chunks)
            res = super().migrate(src, dst, cell_source, names=names,
                                  strict=strict, now=now)
            res.wasted_prefetch_bytes = wasted
            return res

        # mark the claimed names synced so the base delta skips them, but
        # apply nothing until the residual migration has succeeded — a
        # SerializationFailure must leave dst untouched
        saved = {n: known[n] for n in valid if n in known}
        known.update(valid)
        try:
            res = super().migrate(src, dst, cell_source, names=names,
                                  strict=strict, now=now)
        except Exception:
            for n in valid:
                known.pop(n, None)
            known.update(saved)
            raise
        del self._pending[dst.name]
        sub = SerializedState(
            codec=p.ser.codec, blobs={n: p.ser.blobs[n] for n in valid},
            digests=dict(valid))
        sub.chunks = {d: p.ser.chunks[d]
                      for b in sub.blobs.values() for d in b.chunk_digests()
                      if d in p.ser.chunks}
        if p.peer is not None:
            # remote claim: the chunks are already banked over there, so
            # this stream is manifest-only — the receiver materializes the
            # names from its own store.  Its frames are real traffic and
            # count on the result (the residual migrate above was likely
            # a frameless noop)
            claim_stats = p.peer.send_state(sub)
            res.wire_frames += claim_stats.frames
            res.wall_seconds += claim_stats.wall_seconds
            res.transport = getattr(dst, "transport", res.transport)
        else:
            objs = self.reducer.deserialize(sub, target_ns=dst.state.ns,
                                            chunk_store=dst.chunk_store)
            dst.state.update(objs)
        # residual wait models the applied subset streaming since started_at
        # (not the full speculative snapshot, which may be mostly synced);
        # chunks the receiver already held at begin time never streamed
        sub_wire = sub.wire_nbytes(set(p.held))
        wait = 0.0
        if now is not None:
            ready = p.started_at + self.transfer_seconds(
                sub_wire, src.name, dst.name)
            wait = max(0.0, ready - now)
        self.prefetch_hits += 1
        self.prefetch_useful_bytes += sub_wire
        # speculative bytes that streamed but were not part of the applied
        # subset (the snapshot carried names that turned out synced/stale)
        overshoot = max(0, min(p.nbytes, self._delivered_bytes(p, now))
                        - sub_wire)
        self.prefetch_wasted_bytes += overshoot
        res.names = tuple(sorted(set(res.names) | set(valid)))
        res.prefetched = tuple(sorted(valid))
        res.nbytes += sub_wire
        res.seconds += wait
        res.noop = False
        res.wasted_prefetch_bytes = overshoot
        return res


@dataclass
class _BankedName:
    """One name's trickled snapshot banked at a destination."""
    blob: object                 # SerializedName (chunks live in dst's store)
    digest: int
    nbytes: int                  # wire bytes this entry cost to trickle


class DeltaReplicator:
    """Background delta replication during think time (the tentpole).

    Between cells — while the user reads output — the replicator wakes,
    asks the reducer which names changed since the last trickle to each
    likely target (the interaction model's next-cell distribution picks the
    top-k), and streams those deltas ahead of any decision, rate-limited on
    the transport's low-priority lane so interactive traffic always
    preempts.  Receivers *bank* trickled chunks exactly like speculative
    prefetch: nothing touches the namespace until a real migration claims
    it, and a mid-trickle redefinition tombstones the stale entry (bytes
    charged to the engine's single waste ledger).

    At decision time the engine's :meth:`MigrationEngine.migrate` calls
    :meth:`peek_claim`: banked names whose content still digests the same
    are shipped as a manifest-only claim, and the residual delta is computed
    against (synced ∪ banked) — a converged trickle means the migration
    moves only the manifest plus the last cell's delta.

    Liveness pruning rides along: :func:`repro.core.astdeps.live_names`
    over the remaining plan bounds both what trickles and what full-state
    moves carry; on dynamic constructs (``exec``, star-imports, …) it
    degrades to "everything live".
    """

    def __init__(self, runtime: "HybridRuntime", *, rate: float = 50e6,
                 burst_seconds: float = 1.0, top_k: int = 2,
                 liveness: bool = True):
        self.rt = runtime
        self.engine = runtime.engine
        self.reducer = runtime.engine.reducer
        self.rate = float(rate)
        self.burst = self.rate * float(burst_seconds)
        self.top_k = int(top_k)
        self.liveness = bool(liveness)
        # dst env -> {name -> banked entry}; per-dst epoch of the last trickle
        self.banked: dict[str, dict[str, _BankedName]] = {}
        self._epochs: dict[str, int] = {}
        self._budget = self.burst
        self._last_step: float | None = None
        # latest live set over the remaining plan (None = everything live)
        # plus the dirty-epoch watermark at which it was computed: names
        # (re)defined after the snapshot are never pruned — the set was
        # computed with those definitions still ahead, so they appear as
        # kills, not as live-outs
        self._live: set[str] | None = None
        self._live_epoch = 0
        self.live_conservative = False
        # ledger
        self.trickled_bytes = 0
        self.claimed_bytes = 0
        self.claimed_names = 0
        self.cancelled_names = 0
        self.rounds = 0
        runtime.replicator = self
        runtime.engine.replicator = self
        runtime.analyzer.replication_view = self

    # -- liveness --------------------------------------------------------
    def update_liveness(self, remaining_sources) -> None:
        """Recompute the live set from the remaining cells' sources."""
        from repro.core.astdeps import live_names
        if not self.liveness:
            self._live = None
            return
        src = self.rt.envs[self.rt.current_env]
        self._live = live_names(list(remaining_sources), src.state.ns)
        self._live_epoch = src.state.epoch
        self.live_conservative = self._live is None

    def _is_live(self, name: str, state: ExecutionState) -> bool:
        if self._live is None:
            return True
        return (name in self._live
                or state.dirty.get(name, 0) > self._live_epoch)

    def prune_dead(self, names: set[str],
                   state: ExecutionState) -> set[str]:
        """Drop provably-dead names from a full-state move (conservative:
        with no live set — liveness off or dynamic code — nothing drops;
        names dirtied since the live snapshot always survive)."""
        if not self.liveness or self._live is None:
            return names
        return {n for n in names if self._is_live(n, state)}

    # -- analyzer view ---------------------------------------------------
    def banked_bytes(self, dst: str) -> int:
        return sum(e.nbytes for e in self.banked.get(dst, {}).values())

    def residual_bytes(self, nbytes: float, src: str, dst: str) -> float:
        """Cost-model discount: bytes already banked at ``dst`` won't
        travel again, so placement prices only the residual."""
        return max(0.0, nbytes - self.banked_bytes(dst))

    # -- trickling -------------------------------------------------------
    def step(self, now: float, remaining_sources=None,
             budget_bytes: float | None = None) -> int:
        """One think-time wakeup: refresh liveness, pick targets, trickle
        dirty deltas within the byte budget.  Returns bytes trickled.

        Without an explicit ``budget_bytes`` the budget accrues at ``rate``
        bytes per second of elapsed time (capped at one burst)."""
        self.rounds += 1
        rt = self.rt
        src = rt.envs[rt.current_env]
        if getattr(src, "peer", None) is not None:
            return 0       # a remote namespace cannot be snapshotted here
        if budget_bytes is None:
            if self._last_step is not None:
                self._budget = min(
                    self.burst,
                    self._budget + (now - self._last_step) * self.rate)
            self._last_step = now
            budget = self._budget
        else:
            budget = float(budget_bytes)
        if budget <= 0:
            return 0
        if remaining_sources is not None:
            self.update_liveness(remaining_sources)
        total = 0
        for dst_name in self._select_targets():
            total += self._trickle_to(src, rt.envs[dst_name], budget - total)
            if total >= budget:
                break
        if budget_bytes is None:
            self._budget = max(0.0, self._budget - total)
        return total

    def _select_targets(self) -> list[str]:
        """Top-k likely destination envs: the interaction model's next-cell
        distribution, each candidate cell priced through the analyzer's
        peeked decision (mirrors ``_maybe_prefetch``'s selection rule)."""
        rt = self.rt
        pred = rt._last_pred
        dist = pred["dist"] if pred else {}
        if rt.block_plan:
            # inside a committed block the session stays on the block env,
            # but the block's exit ships everything home — trickling home
            # during in-block think gaps pre-replicates that return trip
            if rt.current_env != rt.home:
                return [rt.home]
            candidates = [(o, None) for o in rt.block_plan[:self.top_k]]
        elif dist:
            candidates = top_candidates(dist, self.top_k)
        elif pred is not None:
            candidates = [(pred["order"] + 1, None)]
        else:
            return []
        taken: list[str] = []
        for nxt, _prob in candidates:
            if not 0 <= nxt < len(rt.nb.cells):
                continue
            cell = rt.nb.cells[nxt]
            d = rt.analyzer.decide(rt.nb, cell, current_env=rt.current_env,
                                   peek=True)
            target = d.env
            if rt.block_plan and rt.block_env is not None:
                target = (rt.block_env if nxt in rt.block_plan else rt.home)
            if target == rt.current_env or target in taken:
                continue
            env = rt.envs.get(target)
            if env is None or env.kind != "compute":
                continue
            taken.append(target)
            if len(taken) >= self.top_k:
                break
        return taken

    def _trickle_to(self, src, dst, budget: float) -> int:
        """Trickle the dirty delta from ``src``'s namespace to ``dst``'s
        bank, clamped to ``budget`` wire bytes (always at least one name so
        a large object still makes progress across wakeups)."""
        import types as _types
        if budget <= 0:
            return 0
        state = src.state
        bank = self.banked.setdefault(dst.name, {})
        known = self.engine.synced.get(dst.name, {})
        eff_known = {**known, **{n: e.digest for n, e in bank.items()}}
        last_epoch = self._epochs.get(dst.name, -1)
        names = {n for n in state.names()
                 if not isinstance(state.get(n), _types.ModuleType)
                 and self._is_live(n, state)}
        # dirty-since prefilter: one dict probe per name instead of a
        # digest launch over the whole namespace
        cand = {n for n in names
                if n not in eff_known or state.dirty.get(n, 0) > last_epoch}
        if not cand:
            self._epochs[dst.name] = state.epoch
            return 0
        send, _dead, here = self.reducer.delta_names(state, cand, eff_known)
        send &= cand
        if not send:
            self._epochs[dst.name] = state.epoch
            return 0
        ser = self.reducer.serialize_names(state, send, on_error="skip",
                                           digests=here)
        if not ser.blobs:
            self._epochs[dst.name] = state.epoch
            return 0
        dst_peer = getattr(dst, "peer", None)
        held = {d for d in ser.chunks if dst.chunk_store.has(d)}
        # budget clamp: take names (deterministic order) while their
        # incremental wire cost fits; each entry's cost is recorded so
        # tombstoning and claims account the same bytes
        take: list[str] = []
        costs: dict[str, int] = {}
        counted = set(held)
        running = 0
        for n in sorted(ser.blobs):
            blob = ser.blobs[n]
            cost = (len(blob.pickle_bytes)
                    + sum(len(a.get("scales", b"")) for a in blob.arrays))
            for d in blob.chunk_digests():
                cost += DIGEST_BYTES
                if d in counted or d not in ser.chunks:
                    continue
                counted.add(d)
                cost += len(ser.chunks[d]) - 1
            if take and running + cost > budget:
                break
            take.append(n)
            costs[n] = cost
            running += cost
        sub = SerializedState(codec=ser.codec,
                              blobs={n: ser.blobs[n] for n in take},
                              digests={n: ser.digests[n] for n in take})
        sub.chunks = {d: ser.chunks[d]
                      for b in sub.blobs.values() for d in b.chunk_digests()
                      if d in ser.chunks}
        if dst_peer is not None:
            # real frames on the low-priority lane; receiver banks them
            stats = dst_peer.send_state(sub, trickle=True, low_priority=True)
            wire_bytes = sub.wire_nbytes({d for d in sub.chunks
                                          if d in stats.held})
            dst.chunk_store.put_many(sub.chunks)    # mirror what was banked
        else:
            wire_bytes = sub.wire_nbytes(held)
            dst.chunk_store.put_many(sub.missing_chunks(held))
        src.chunk_store.put_many(sub.chunks)
        for n in take:
            old = bank.get(n)
            if old is not None:
                # superseded before any claim: the earlier bytes are waste
                self.engine.prefetch_wasted_bytes += old.nbytes
            bank[n] = _BankedName(blob=ser.blobs[n], digest=ser.digests[n],
                                  nbytes=costs[n])
        self.trickled_bytes += wire_bytes
        if len(take) == len(ser.blobs):
            # everything dirty went out: advance the epoch watermark
            self._epochs[dst.name] = state.epoch
        self.rt._emit(T.STATE_TRICKLED, None, target=dst.name,
                      names=tuple(take), nbytes=wire_bytes)
        return wire_bytes

    # -- invalidation ----------------------------------------------------
    def invalidate(self, names) -> int:
        """A cell (re)defined these names: banked copies are stale.  Pop
        them everywhere, charge their bytes to the single waste ledger, and
        CANCEL transport-bound receivers (banked chunks stay — immutable,
        content-addressed — only the stream/claim bookkeeping clears)."""
        dropped = 0
        for dst_name, bank in self.banked.items():
            stale = [n for n in names if n in bank]
            if not stale:
                continue
            waste = 0
            for n in stale:
                waste += bank.pop(n).nbytes
                self.cancelled_names += 1
            dropped += waste
            self.engine.prefetch_wasted_bytes += waste
            env = self.rt.envs.get(dst_name)
            peer = getattr(env, "peer", None) if env is not None else None
            if peer is not None:
                peer.cancel()
            self.rt._emit(T.STATE_TRICKLE_CANCELLED, None, target=dst_name,
                          names=tuple(sorted(stale)), wasted_bytes=waste)
        return dropped

    # -- claiming --------------------------------------------------------
    def peek_claim(self, src, dst, names: set[str],
                   known: dict[str, int]) -> SerializedState | None:
        """Banked names still content-identical to ``src``'s namespace,
        packaged as a manifest-only SerializedState (chunks are already at
        ``dst``).  Genuinely stale entries — in-place mutations the AST
        invalidation cannot see — are dropped (and charged as waste) here;
        the surviving claim is only *committed* (removed from the bank,
        counted) by :meth:`commit_claim` once the migration succeeds."""
        bank = self.banked.get(dst.name)
        if not bank:
            return None
        cand = {n: e for n, e in bank.items()
                if n in names and n in src.state.ns
                and known.get(n) != e.digest}
        if not cand:
            return None
        cur = self.reducer.digest_many({n: src.state.ns[n] for n in cand})
        valid = {n: e for n, e in cand.items() if cur.get(n) == e.digest}
        for n in list(cand):
            if n not in valid:
                e = bank.pop(n)
                self.engine.prefetch_wasted_bytes += e.nbytes
                self.cancelled_names += 1
        if not valid:
            return None
        return SerializedState(
            codec=self.reducer.codec,
            blobs={n: e.blob for n, e in valid.items()},
            digests={n: e.digest for n, e in valid.items()})

    def commit_claim(self, dst_name: str, sub: SerializedState) -> None:
        bank = self.banked.get(dst_name, {})
        nbytes = 0
        for n in sub.blobs:
            e = bank.pop(n, None)
            if e is not None:
                nbytes += e.nbytes
        self.claimed_names += len(sub.blobs)
        self.claimed_bytes += nbytes
        self.rt._emit(T.STATE_TRICKLE_CLAIMED, None, target=dst_name,
                      names=tuple(sorted(sub.blobs)), nbytes=nbytes)

    # -- lifecycle -------------------------------------------------------
    def forget(self, env_name: str) -> int:
        """``env_name`` died: its banked state is gone (and waste)."""
        bank = self.banked.pop(env_name, None)
        self._epochs.pop(env_name, None)
        if not bank:
            return 0
        waste = sum(e.nbytes for e in bank.values())
        self.cancelled_names += len(bank)
        self.engine.prefetch_wasted_bytes += waste
        return waste

    def dispose(self) -> int:
        """Session over: everything still banked was trickled for nothing."""
        waste = 0
        for dst_name in list(self.banked):
            waste += self.forget(dst_name)
        return waste


class HybridRuntime:
    """Wires sessions, telemetry, context, analyzer, engine together (Fig. 1).

    Environments come from an :class:`EnvironmentRegistry` (N environments,
    per-pair links); the legacy ``envs={"local": ..., "remote": ...}`` dict
    is adapted into a two-env registry.  ``registry.home`` plays the paper's
    "local" role: sessions start there and state returns there when a block
    completes or the plan deviates (Fig. 3).
    """

    def __init__(self, notebook: Notebook, *,
                 envs: dict[str, ExecutionEnvironment] | None = None,
                 registry: EnvironmentRegistry | None = None,
                 kb: KnowledgeBase | None = None,
                 reducer: StateReducer | None = None,
                 clock: SimClock | None = None,
                 policy: str = "block", use_knowledge: bool = True,
                 bandwidth: float = 1e9, latency: float = 0.5,
                 delta: bool = True, pipeline: bool = False,
                 engine: MigrationEngine | None = None,
                 arbiter=None,
                 model: InteractionModel | str | None = None,
                 horizon: int = 4, session_id: str | None = None,
                 objective: str = "seconds", slo: float | None = None):
        if registry is None:
            assert envs, "pass envs={...} or registry=EnvironmentRegistry(...)"
            registry = EnvironmentRegistry.from_envs(
                envs, bandwidth=bandwidth, latency=latency)
        assert registry.home is not None and registry.candidates(), \
            "registry needs a home env and at least one placement candidate"
        self.nb = notebook
        self.registry = registry
        self.envs = registry.envs()          # name -> env (back-compat view)
        self.home = registry.home
        self.clock = clock or SimClock()
        self.bus = T.MQBus()
        self.kb = kb or KnowledgeBase()
        self.context = ContextDetector(model)
        self.context.attach(self.bus)
        self.reducer = reducer or StateReducer()
        if engine is not None:
            self.engine = engine
            if self.engine.registry is None:
                self.engine.registry = registry
        else:
            engine_cls = PipelinedMigrationEngine if pipeline else MigrationEngine
            self.engine = engine_cls(self.reducer, bandwidth=bandwidth,
                                     latency=latency, delta=delta,
                                     registry=registry)
        self.analyzer = MigrationAnalyzer(
            self.kb, self.context, PerfModel(), policy=policy,
            use_knowledge=use_knowledge, migration_latency=latency,
            migration_bandwidth=bandwidth, registry=registry,
            horizon=horizon, objective=objective, slo=slo)
        self.current_env = self.home
        self.block_plan: list[int] = []
        self.block_env: str | None = None
        # deterministic ids opt-in (seeded fleet runs must reproduce their
        # ScheduleReport bit-for-bit; uuid4 would break that)
        self.session_id = session_id or T.new_session_id()
        self.migrations = 0
        self.queue_wait = 0.0
        # cost plane: modeled execution seconds billed per env (the dollar
        # meter's input) + per-cell request→completion latency (the SLO
        # attainment meter's input).  Both are pure bookkeeping — no
        # decision reads them.
        self.exec_env_seconds: dict[str, float] = {}
        self.cell_latencies: list[float] = []
        self.arbiter = arbiter               # shared capacity (SessionScheduler)
        # fleet failure injection: fault_check(env, start, end) -> failure
        # instant inside [start, end) or None.  When set, executions and
        # migrations become *interruptible*: the clock stops at the failure
        # instant and EnvFailure propagates to the fleet scheduler.
        self.fault_check = None
        # prediction scoring: last emitted next-cell distribution + the
        # speculative prefetches issued on it, scored when the next cell
        # actually runs (KB provenance + confidence-gate calibration)
        self.prediction_hits = 0
        self.prediction_total = 0
        self._last_pred: dict | None = None
        self.last_decision: Decision | None = None
        # background delta replicator (attach_replicator); None = off and
        # every decision/byte path is bit-identical to the unreplicated run
        self.replicator: DeltaReplicator | None = None
        # replica plane (attach_replicas); None = off — K=0 keeps every
        # decision and byte bit-identical to the unreplicated runtime
        self.replicas = None
        self._closed = False
        self._emit(T.SESSION_STARTED, None)

    # ------------------------------------------------------------------
    def _emit(self, type_: str, cell_id: str | None, **payload) -> None:
        self.bus.publish("telemetry", T.TelemetryMessage(
            datetime=self.clock.now(), type=type_, cell_id=cell_id,
            notebook=self.nb.name, cell_ids=self.nb.cell_ids(),
            session=self.session_id, path=self.nb.path, payload=payload))

    def attach_replicator(self, *, rate: float = 50e6, top_k: int = 2,
                          liveness: bool = True,
                          burst_seconds: float = 1.0) -> DeltaReplicator:
        """Turn on background delta replication: think-time wakeups trickle
        dirty state to the top-k likely targets so decision-time migrations
        ship only the residual (claimed bytes are manifest-only)."""
        return DeltaReplicator(self, rate=rate, top_k=top_k,
                               liveness=liveness,
                               burst_seconds=burst_seconds)

    def attach_replicas(self, followers, *, race: bool = False,
                        race_band: float = 0.25,
                        race_threshold: float = 0.35,
                        rate: float = 50e6, burst_seconds: float = 1.0):
        """Turn on the replica plane: keep ``followers`` converged with the
        primary during think time (zero-replay promotion on failure) and —
        with ``race=True`` — race confident cells on two candidate envs,
        committing the first result."""
        from repro.core.replica import SessionReplicaSet
        return SessionReplicaSet(self, followers, race=race,
                                 race_band=race_band,
                                 race_threshold=race_threshold,
                                 rate=rate, burst_seconds=burst_seconds)

    def probe(self, source: str, env_name: str) -> float:
        """Background probe for Algorithm 2 (no telemetry, no migration)."""
        env = self.envs[env_name]
        probe_ns = ExecutionEnvironment(f"probe-{env_name}", speedup=env.speedup,
                                        globals_seed=dict(env.state.ns))
        return probe_ns.execute(source)

    # ------------------------------------------------------------------
    def _do_migration(self, src: str, dst: str, cell_source: str | None) -> float:
        # return trips (no cell source) skip unserializable objects in place
        start = self.clock.now()
        res = self.engine.migrate(self.envs[src], self.envs[dst], cell_source,
                                  strict=cell_source is not None,
                                  now=start)
        if res.noop:          # empty delta: free, and not a migration at all
            return 0.0
        if self.fault_check is not None:
            tf = self.fault_check(dst, start, start + res.seconds)
            if tf is not None:
                # the transfer dies with its destination: charge the partial
                # stream, forget the receiver's content view (what landed
                # there is gone) and hand recovery to the fleet scheduler
                self.clock.advance(max(0.0, tf - start))
                self.engine.synced.pop(dst, None)
                self._emit(T.ENV_FAILED, None, env=dst, at=tf,
                           during="migration", wasted=tf - start)
                raise EnvFailure(dst, tf, during="migration",
                                 wasted=tf - start)
        self.clock.advance(res.seconds)
        self.migrations += 1
        self.analyzer.observe_state_size(self.nb.name, max(res.nbytes, 1))
        self.kb.record(ProvRecord(
            "migration", None, dst, self.clock.now() - res.seconds,
            self.clock.now(), params={"bytes": res.nbytes, "src": src},
            used=res.names))
        return res.seconds

    def _maybe_prefetch(self, order: int) -> None:
        """Pipelined engines push the predicted next hop's state while the
        current cell executes (transfer overlaps execution on the sim clock).

        Inside a committed block the next planned cell is a *planned*
        transfer (bypasses the gate).  Otherwise the interaction model's
        next-cell distribution drives *speculation*: the top-k candidates
        are prefetched, each admitted only if its probability mass clears
        the engine's confidence gate."""
        if not isinstance(self.engine, PipelinedMigrationEngine):
            return
        dist = self._last_pred["dist"] if self._last_pred else {}
        if self.block_plan:
            upcoming = [o for o in self.block_plan if o > order]
            nxt = upcoming[0] if upcoming else order + 1
            candidates: list[tuple[int, float | None]] = [(nxt, None)]
        elif dist:
            candidates = top_candidates(dist, self.engine.prefetch_top_k)
        else:
            # no evidence yet: the paper's unconditional next-cell walk
            candidates = [(order + 1, None)]
        issued: list[tuple[int, str, float | None]] = []
        taken = {self.current_env}
        gate = self.engine.gate
        for nxt, prob in candidates:
            if not 0 <= nxt < len(self.nb.cells):
                continue
            if prob is not None and gate is not None and not gate.allow(prob):
                # pre-gate: don't pay a full peeked placement decision for a
                # speculation the engine would reject anyway
                self.engine.prefetch_gated += 1
                gate.rejected()
                continue
            cell = self.nb.cells[nxt]
            d = self.analyzer.decide(self.nb, cell,
                                     current_env=self.current_env, peek=True)
            target = d.env
            if self.block_plan and self.block_env is not None:
                target = (self.block_env if nxt in self.block_plan
                          else self.home)
            if target in taken:
                continue
            p = self.engine.begin_prefetch(
                self.envs[self.current_env], self.envs[target], cell.source,
                now=self.clock.now(), prob=prob, predicted_order=nxt)
            if p is not None:
                taken.add(target)
                issued.append((nxt, target, prob))
                self._emit(T.STATE_PREFETCHED, cell.cell_id, target=target,
                           nbytes=p.nbytes, ready_at=p.ready_at,
                           predicted=nxt,
                           prob=prob if prob is not None else 1.0)
        if self._last_pred is not None:
            self._last_pred["issued"] = issued

    def _note_prediction(self, order: int) -> None:
        """Snapshot the model's next-cell distribution for the cell about to
        run (before its completion lands in the history) so the realized
        next cell can score it — every cell, pipelined or not."""
        self._last_pred = {
            "notebook": self.nb.name, "order": order,
            "dist": self.context.distribution(self.nb.name, order),
            "issued": []}

    def _score_prediction(self, cell: Cell, realized: int) -> None:
        """Score the previous cell's prediction against the cell that
        actually ran: KB provenance keeps (predicted distribution, realized)
        and every *issued* speculation's outcome calibrates the gate."""
        pred = self._last_pred
        self._last_pred = None
        if pred is None or pred["notebook"] != self.nb.name:
            return
        dist = pred["dist"]
        if dist:
            self.prediction_total += 1
            top = max(dist.items(), key=lambda kv: (kv[1], -kv[0]))[0]
            self.prediction_hits += int(top == realized)
            self.kb.record_prediction(cell.cell_id, self.nb.name, dist,
                                      realized, when=self.clock.now())
        if isinstance(self.engine, PipelinedMigrationEngine) \
                and self.engine.gate is not None:
            for nxt, _target, prob in pred["issued"]:
                if prob is not None:     # planned transfers don't calibrate
                    self.engine.gate.observe(nxt == realized)

    @property
    def prediction_hit_rate(self) -> float:
        if self.prediction_total == 0:
            return 0.0
        return self.prediction_hits / self.prediction_total

    def run_cell(self, ref, *, force_env: str | None = None) -> float:
        """Execute one cell under the policies; returns modeled duration."""
        cell = self.nb.cell(ref)
        order = self.nb.order(cell.cell_id)
        t_request = self.clock.now()
        self._emit(T.CELL_EXECUTION_REQUESTED, cell.cell_id, order=order)
        # the probability the interaction model gave THIS cell — the race
        # gate's admission signal — must be captured before scoring pops it
        pred = self._last_pred
        cell_prob = (pred["dist"].get(order)
                     if pred is not None and pred["notebook"] == self.nb.name
                     else None)
        self._score_prediction(cell, order)

        if force_env is not None:
            decision = Decision(force_env, force_env != self.current_env,
                                f"forced to {force_env}")
        elif self.block_plan and order in self.block_plan:
            decision = Decision(self.block_env or self.current_env, False,
                                "inside predicted block")
        elif self.block_plan and order not in self.block_plan:
            # deviation from predicted block: return home (Fig. 3)
            decision = Decision(self.home, False,
                                "deviated from predicted block")
            self.block_plan = []
            self.block_env = None
        else:
            decision = self.analyzer.decide(self.nb, cell,
                                            current_env=self.current_env)
        # exposed so the scheduler's forecast telemetry can reuse the
        # decision instead of re-running the policy chain per cell
        self.last_decision = decision

        target = decision.env
        # first-result-wins racing: with a replica set attached and the
        # confidence gate firing, launch the cell on the two cheapest
        # candidates; the modeled first RESULT (min expected cost) is where
        # the cell commits, and the loser is cancelled at commit time
        race = None
        if self.replicas is not None and force_env is None:
            race = self.replicas.plan_race(cell, order, decision,
                                           prob=cell_prob)
            if race is not None:
                target = race.winner
        # speculations that bet on a different destination are now stale:
        # cancel them before the migration below claims its own
        if isinstance(self.engine, PipelinedMigrationEngine):
            for dst, wasted, pred_order in self.engine.cancel_stale(
                    {target}, now=self.clock.now()):
                self._emit(T.STATE_PREFETCH_CANCELLED, cell.cell_id,
                           target=dst, wasted_bytes=wasted,
                           predicted=pred_order)
        if target != self.current_env:
            # committing to a block moves state once for the WHOLE block
            # (Fig. 3): later in-block cells run without migrating, so their
            # inputs must travel now, not just the current cell's
            move_source = cell.source
            if decision.block:
                move_source = "\n".join(
                    self.nb.cells[o].source for o in decision.block
                    if order <= o < len(self.nb.cells)) or cell.source
            try:
                self._do_migration(self.current_env, target, move_source)
                if decision.block:
                    self.block_plan = [o for o in decision.block if o >= order]
                    self.block_env = target
                self.current_env = target
            except SerializationFailure as e:
                cell.annotate(f"serialization failure -> {self.home}: {e}")
                target = self.home

        env = self.envs[self.current_env]
        # cold-start gate: a provisioning env accepts state (migration can
        # stream while it boots) but cannot execute before it is ready —
        # the wait is queue time, exactly what placement priced in
        ready_at = getattr(env, "ready_at", 0.0)
        if getattr(env, "status", "up") == "provisioning" \
                and ready_at > self.clock.now():
            wait = ready_at - self.clock.now()
            self.clock.advance(wait)
            self.queue_wait += wait
            self._emit(T.CELL_EXECUTION_QUEUED, cell.cell_id, order=order,
                       env=self.current_env, wait=wait, cold_start=True)
        # shared-capacity gate: queue when the target env is saturated
        if self.arbiter is not None:
            now = self.clock.now()
            est = (cell.cost / env.speedup) if cell.cost is not None else 0.0
            slot_start = self.arbiter.acquire(self.current_env, now, est)
            wait = slot_start - now
            if wait > 0:
                self.clock.advance(wait)
                self.queue_wait += wait
                self._emit(T.CELL_EXECUTION_QUEUED, cell.cell_id, order=order,
                           env=self.current_env, wait=wait)
        self._emit(T.CELL_EXECUTION_STARTED, cell.cell_id, order=order,
                   env=self.current_env)
        self._note_prediction(order)
        self._maybe_prefetch(order)
        exec_start = self.clock.now()
        duration = env.execute(cell.source, cell.cost)
        if self.fault_check is not None:
            tf = self.fault_check(self.current_env, exec_start,
                                  exec_start + duration)
            if tf is not None:
                # mid-cell env failure: the cell did NOT complete — charge
                # only the work up to the failure instant, free the slot,
                # and let the fleet scheduler drive recovery
                self.clock.advance(max(0.0, tf - exec_start))
                self.exec_env_seconds[self.current_env] = (
                    self.exec_env_seconds.get(self.current_env, 0.0)
                    + max(0.0, tf - exec_start))   # partial work still bills
                if self.arbiter is not None:
                    self.arbiter.release(self.current_env, exec_start, tf)
                self._emit(T.ENV_FAILED, cell.cell_id, env=self.current_env,
                           at=tf, during="execute", order=order,
                           wasted=tf - exec_start)
                raise EnvFailure(self.current_env, tf, order,
                                 wasted=tf - exec_start)
        self.clock.advance(duration)
        self.exec_env_seconds[self.current_env] = (
            self.exec_env_seconds.get(self.current_env, 0.0) + duration)
        if self.arbiter is not None:
            self.arbiter.release(self.current_env, exec_start, self.clock.now())
        base = cell.cost if cell.cost is not None else duration * env.speedup
        for name, e in self.registry.compute_envs().items():
            self.analyzer.perf.observe(cell.cell_id, name, base / e.speedup)
        # per-cell latency the user saw: request (incl. migration + queue +
        # cold-start waits) to result — what the SLO is stated against
        self.cell_latencies.append(self.clock.now() - t_request)
        self._emit(T.CELL_EXECUTION_COMPLETED, cell.cell_id, order=order,
                   env=self.current_env, duration=duration)

        # names this cell (re)defined are now stale on every peer
        from repro.core.astdeps import analyze_cell
        stores = analyze_cell(cell.source).stores
        self.engine.invalidate(self.current_env, stores)
        # dirty-epoch ledger feeds the replicator's dirty-since prefilter;
        # banked trickles of redefined names are tombstoned right here
        env.state.mark_dirty(stores)
        if self.replicator is not None:
            self.replicator.invalidate(stores)
        if self.replicas is not None:
            # the cell committed: followers are one cell behind until the
            # next think-time sync; a raced cell settles (loser CANCELLED,
            # waste charged) the moment its first RESULT lands
            self.replicas.note_cell(order)
            if race is not None:
                self.replicas.settle_race(race, duration=duration,
                                          now=self.clock.now())

        # block bookkeeping: leave the block env when it completes (Fig. 3)
        if self.block_plan:
            self.block_plan = [o for o in self.block_plan if o != order]
            if not self.block_plan:
                self.block_env = None
                if self.current_env != self.home:
                    self._do_migration(self.current_env, self.home, None)
                    self.current_env = self.home
        elif self.current_env != self.home and not decision.block:
            # single-cell strategy: immediately switch state back
            self._do_migration(self.current_env, self.home, None)
            self.current_env = self.home

        return duration

    def recover_from_failure(self, failed_env: str) -> None:
        """Reset session placement state after ``failed_env`` died: the
        session falls back to home, any committed block is abandoned, the
        engine forgets what the dead env held (its namespace is gone), and
        in-flight speculations targeting it are cancelled.  State *content*
        recovery (checkpoint restore or rerun) is the fleet scheduler's
        job — this only makes the runtime consistent again."""
        self.block_plan = []
        self.block_env = None
        if self.current_env == failed_env:
            self.current_env = self.home
        self.engine.synced.pop(failed_env, None)
        if self.replicator is not None:
            self.replicator.forget(failed_env)
        if self.replicas is not None:
            # a race interrupted by the failure is aborted WITHOUT touching
            # the loser's namespace: if that loser is the follower about to
            # be promoted, its converged state must survive the cancel
            self.replicas.abort_race(reason=f"{failed_env} failed")
            self.replicas.forget(failed_env)
        if isinstance(self.engine, PipelinedMigrationEngine):
            wasted = self.engine.cancel_prefetch(failed_env, self.clock.now())
            if wasted:
                self._emit(T.STATE_PREFETCH_CANCELLED, None, target=failed_env,
                           wasted_bytes=wasted, predicted=None)
        self._emit(T.SESSION_RECOVERED, None, failed_env=failed_env,
                   env=self.current_env)

    def reset_for_replay(self) -> None:
        """Rerun-from-home recovery: replaying the plan must not see the
        previous attempt's state, so every compute env gets a fresh
        namespace and the engine forgets all content views.  Chunk stores
        are untouched — content-addressed chunks are immutable, so the
        replay's migrations re-ship manifests, not bytes."""
        if isinstance(self.engine, PipelinedMigrationEngine):
            self.engine.cancel_stale(set(), now=self.clock.now())
        for env in self.envs.values():
            if env.kind == "compute":
                env.state = ExecutionState({})
        self.engine.synced.clear()

    def close(self) -> None:
        """Dispose the session: cancel in-flight speculations (their bytes
        are waste — nothing will ever claim them), emit the Table-I disposal
        message, and detach the context detector's bus subscription
        (idempotent — subscribers must not leak across sessions)."""
        if self._closed:
            return
        self._closed = True
        if self.replicator is not None:
            # unclaimed banked trickles are waste, same as dead speculation
            self.replicator.dispose()
        if isinstance(self.engine, PipelinedMigrationEngine):
            for dst, wasted, pred_order in self.engine.cancel_stale(
                    set(), now=self.clock.now()):
                self._emit(T.STATE_PREFETCH_CANCELLED, None, target=dst,
                           wasted_bytes=wasted, predicted=pred_order)
        self._emit(T.SESSION_DISPOSED, None)
        self.context.detach()
