"""Execution environments, the migration engine, and the hybrid runtime.

This is the paper's server-side machinery assembled: sessions emit Table-I
telemetry on the MQ bus; the context detector listens; the analyzer decides
placement; the engine moves *reduced, delta, compressed* state between
environments; everything is recorded as provenance.

An ExecutionEnvironment is "a place code can run with its own namespace":
the user's machine, a cloud node — or, in the TPU adaptation, a JAX mesh
(``DistContext``), which is how the same engine implements checkpointing
(migration to a disk env) and elastic rescaling (migration between meshes).
Timing follows the paper's §III protocol: declared cell costs (or measured
wall time) divided by the environment speedup, on a simulated clock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core import telemetry as T
from repro.core.analyzer import Decision, MigrationAnalyzer, PerfModel
from repro.core.context import ContextDetector
from repro.core.kb import KnowledgeBase, ProvRecord
from repro.core.notebook import Cell, Notebook
from repro.core.reducer import SerializationFailure, SerializedState, StateReducer
from repro.core.simclock import SimClock
from repro.core.state import ExecutionState


class ExecutionEnvironment:
    def __init__(self, name: str, *, speedup: float = 1.0,
                 mesh_ctx=None, globals_seed: dict | None = None):
        self.name = name
        self.speedup = float(speedup)
        self.mesh_ctx = mesh_ctx
        self.state = ExecutionState(dict(globals_seed or {}))

    def execute(self, source: str, cost: float | None = None) -> float:
        """Run real code against this env's namespace; return modeled seconds."""
        t0 = time.perf_counter()
        exec(compile(source, f"<{self.name}>", "exec"), self.state.ns)  # noqa: S102
        wall = time.perf_counter() - t0
        base = cost if cost is not None else wall
        return base / self.speedup


@dataclass
class MigrationResult:
    src: str
    dst: str
    names: tuple[str, ...]
    deleted: tuple[str, ...]
    nbytes: int
    seconds: float
    full_bytes: int = 0      # what a full-state migration would have cost


class MigrationEngine:
    """Reduced/delta/compressed state transfer between environments."""

    def __init__(self, reducer: StateReducer, *, bandwidth: float = 1e9,
                 latency: float = 0.5, delta: bool = True):
        self.reducer = reducer
        self.bandwidth = bandwidth
        self.latency = latency
        self.delta = delta
        # receiver's content view: env name -> {state name -> digest}
        self.synced: dict[str, dict[str, int]] = {}
        self.log: list[MigrationResult] = []

    def transfer_seconds(self, nbytes: int) -> float:
        return self.latency + nbytes / self.bandwidth

    # ------------------------------------------------------------------
    def migrate(self, src: ExecutionEnvironment, dst: ExecutionEnvironment,
                cell_source: str | None = None,
                names: set[str] | None = None,
                strict: bool = True) -> MigrationResult:
        """Move the state ``cell_source`` needs (or explicit ``names``) from
        src to dst; only new/changed names are serialized when delta is on."""
        import types as _types
        modules: set[str] = set()
        if names is None:
            if cell_source is not None:
                names, modules, _ = self.reducer.reduce(src.state, cell_source)
            else:
                names = set(src.state.names())
        # re-import module aliases on the destination (paper: preamble/deps)
        for alias, val in list(src.state.ns.items()):
            if isinstance(val, _types.ModuleType) and (
                    alias in names or val.__name__.split(".")[0] in modules):
                try:
                    dst.state.ns[alias] = __import__(val.__name__)
                    if "." in val.__name__:  # alias points at a submodule
                        import importlib
                        dst.state.ns[alias] = importlib.import_module(val.__name__)
                except ImportError:
                    pass
        # module aliases are re-imported on the destination, never serialized
        names = {n for n in names
                 if not isinstance(src.state.get(n), _types.ModuleType)}
        known = self.synced.setdefault(dst.name, {})
        if self.delta:
            send, dead, here = self.reducer.delta_names(src.state, names, known)
            send &= set(names)
        else:
            send, dead = set(names), set()
            here = self.reducer.digests(src.state, names)

        ser = self.reducer.serialize_names(
            src.state, send, on_error="raise" if strict else "skip")
        objs = self.reducer.deserialize(ser, target_ns=dst.state.ns)
        dst.state.update(objs)
        dst.state.drop(dead)

        known.update(ser.digests)
        for n in dead:
            known.pop(n, None)
        # the sender's own content view is now also known
        self.synced.setdefault(src.name, {}).update(here)

        seconds = self.transfer_seconds(ser.nbytes)
        res = MigrationResult(src.name, dst.name, tuple(sorted(send)),
                              tuple(sorted(dead)), ser.nbytes, seconds)
        self.log.append(res)
        return res

    def invalidate(self, env_name: str, names) -> None:
        """``env_name`` (re)defined these names: its content view is stale."""
        view = self.synced.get(env_name)
        if view:
            for n in names:
                view.pop(n, None)


class HybridRuntime:
    """Wires sessions, telemetry, context, analyzer, engine together (Fig. 1)."""

    def __init__(self, notebook: Notebook, *, envs: dict[str, ExecutionEnvironment],
                 kb: KnowledgeBase | None = None,
                 reducer: StateReducer | None = None,
                 clock: SimClock | None = None,
                 policy: str = "block", use_knowledge: bool = True,
                 bandwidth: float = 1e9, latency: float = 0.5,
                 delta: bool = True):
        assert "local" in envs and "remote" in envs
        self.nb = notebook
        self.envs = envs
        self.clock = clock or SimClock()
        self.bus = T.MQBus()
        self.kb = kb or KnowledgeBase()
        self.context = ContextDetector()
        self.context.attach(self.bus)
        self.reducer = reducer or StateReducer()
        self.engine = MigrationEngine(self.reducer, bandwidth=bandwidth,
                                      latency=latency, delta=delta)
        self.analyzer = MigrationAnalyzer(
            self.kb, self.context, PerfModel(), policy=policy,
            use_knowledge=use_knowledge, migration_latency=latency,
            migration_bandwidth=bandwidth)
        self.current_env = "local"
        self.block_plan: list[int] = []
        self.session_id = T.new_session_id()
        self.migrations = 0
        self._emit(T.SESSION_STARTED, None)

    # ------------------------------------------------------------------
    def _emit(self, type_: str, cell_id: str | None, **payload) -> None:
        self.bus.publish("telemetry", T.TelemetryMessage(
            datetime=self.clock.now(), type=type_, cell_id=cell_id,
            notebook=self.nb.name, cell_ids=self.nb.cell_ids(),
            session=self.session_id, path=self.nb.path, payload=payload))

    def probe(self, source: str, env_name: str) -> float:
        """Background probe for Algorithm 2 (no telemetry, no migration)."""
        env = self.envs[env_name]
        probe_ns = ExecutionEnvironment(f"probe-{env_name}", speedup=env.speedup,
                                        globals_seed=dict(env.state.ns))
        return probe_ns.execute(source)

    # ------------------------------------------------------------------
    def _do_migration(self, src: str, dst: str, cell_source: str | None) -> float:
        # return trips (no cell source) skip unserializable objects in place
        res = self.engine.migrate(self.envs[src], self.envs[dst], cell_source,
                                  strict=cell_source is not None)
        self.clock.advance(res.seconds)
        self.migrations += 1
        self.analyzer.observe_state_size(self.nb.name, max(res.nbytes, 1))
        self.kb.record(ProvRecord(
            "migration", None, dst, self.clock.now() - res.seconds,
            self.clock.now(), params={"bytes": res.nbytes, "src": src},
            used=res.names))
        return res.seconds

    def run_cell(self, ref, *, force_env: str | None = None) -> float:
        """Execute one cell under the policies; returns modeled duration."""
        cell = self.nb.cell(ref)
        order = self.nb.order(cell.cell_id)
        self._emit(T.CELL_EXECUTION_REQUESTED, cell.cell_id, order=order)

        if force_env is not None:
            decision = Decision(force_env, force_env != self.current_env,
                                f"forced to {force_env}")
        elif self.block_plan and order in self.block_plan:
            decision = Decision("remote", False, "inside predicted block")
        elif self.block_plan and order not in self.block_plan:
            # deviation from predicted block: return to local (Fig. 3)
            decision = Decision("local", False, "deviated from predicted block")
            self.block_plan = []
        else:
            decision = self.analyzer.decide(self.nb, cell)

        target = decision.env
        if target != self.current_env:
            try:
                self._do_migration(self.current_env, target, cell.source)
                if decision.block:
                    self.block_plan = [o for o in decision.block if o >= order]
                self.current_env = target
            except SerializationFailure as e:
                cell.annotate(f"serialization failure -> local: {e}")
                target = "local"

        env = self.envs[self.current_env]
        self._emit(T.CELL_EXECUTION_STARTED, cell.cell_id, order=order,
                   env=self.current_env)
        duration = env.execute(cell.source, cell.cost)
        self.clock.advance(duration)
        base = cell.cost if cell.cost is not None else duration * env.speedup
        self.analyzer.perf.observe(cell.cell_id, "local", base)
        self.analyzer.perf.observe(cell.cell_id, "remote",
                                   base / self.envs["remote"].speedup)
        self._emit(T.CELL_EXECUTION_COMPLETED, cell.cell_id, order=order,
                   env=self.current_env, duration=duration)

        # names this cell (re)defined are now stale on every peer
        from repro.core.astdeps import analyze_cell
        self.engine.invalidate(self.current_env, analyze_cell(cell.source).stores)

        # block bookkeeping: leave remote when the block completes (Fig. 3)
        if self.block_plan:
            self.block_plan = [o for o in self.block_plan if o != order]
            if not self.block_plan and self.current_env != "local":
                self._do_migration(self.current_env, "local", None)
                self.current_env = "local"
        elif self.current_env != "local" and not decision.block:
            # single-cell strategy: immediately switch state back
            self._do_migration(self.current_env, "local", None)
            self.current_env = "local"

        return duration

    def close(self) -> None:
        self._emit(T.SESSION_DISPOSED, None)
