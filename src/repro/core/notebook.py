"""Cells and notebooks (a minimal, faithful Notebook Document Format model).

Cells carry real Python source (executed with ``exec`` against the session's
ExecutionState), an optional simulated base cost (the paper's §III protocol
forces cell times), and the explainability annotations the tool attaches
("cells are automatically annotated with explainability on cell migration
decisions")."""
from __future__ import annotations

import uuid
from dataclasses import dataclass, field


@dataclass
class Cell:
    source: str
    cell_type: str = "code"          # code | markdown | raw
    cell_id: str = field(default_factory=lambda: str(uuid.uuid4()))
    cost: float | None = None        # simulated base (local) seconds
    annotations: list[str] = field(default_factory=list)

    def annotate(self, note: str) -> None:
        self.annotations.append(note)


class Notebook:
    def __init__(self, name: str, cells: list[Cell] | None = None,
                 path: str = ""):
        self.name = name
        self.path = path or f"{name}.ipynb"
        self.cells: list[Cell] = list(cells or [])

    # ------------------------------------------------------------------
    def add_cell(self, source: str, **kw) -> Cell:
        cell = Cell(source=source, **kw)
        self.cells.append(cell)
        return cell

    def order(self, cell_id: str) -> int:
        for i, c in enumerate(self.cells):
            if c.cell_id == cell_id:
                return i
        raise KeyError(cell_id)

    def cell(self, ref) -> Cell:
        if isinstance(ref, int):
            return self.cells[ref]
        return self.cells[self.order(ref)]

    def cell_ids(self) -> tuple[str, ...]:
        return tuple(c.cell_id for c in self.cells)

    def code_cells(self) -> list[Cell]:
        """The extension only operates on code cells (§II-A)."""
        return [c for c in self.cells if c.cell_type == "code"]

    # ------------------------------------------------------------------
    def to_ipynb(self) -> dict:
        return {
            "nbformat": 4, "nbformat_minor": 5,
            "metadata": {"name": self.name},
            "cells": [{"id": c.cell_id, "cell_type": c.cell_type,
                       "source": c.source,
                       "metadata": {"repro": {"cost": c.cost,
                                              "annotations": c.annotations}}}
                      for c in self.cells],
        }

    @classmethod
    def from_ipynb(cls, doc: dict, name: str = "nb") -> "Notebook":
        nb = cls(doc.get("metadata", {}).get("name", name))
        for c in doc["cells"]:
            meta = c.get("metadata", {}).get("repro", {})
            src = c["source"]
            if isinstance(src, list):
                src = "".join(src)
            nb.cells.append(Cell(source=src, cell_type=c.get("cell_type", "code"),
                                 cell_id=c.get("id", str(uuid.uuid4())),
                                 cost=meta.get("cost"),
                                 annotations=list(meta.get("annotations", []))))
        return nb
