"""Notebook state reducer (paper §II-D): reduced capture, serialization,
content hashing, delta migration, compression codecs.

Pipeline (faithful to the paper, TPU-adapted per DESIGN.md §4):

1. ``reduce``: AST Load-closure over the live namespace -> needed names only.
2. ``serialize``: arrays leave the pickle stream and are stored as raw
   buffers (optionally block-quantized to int8 on device); everything else
   pickles.  Serialization failure => the caller executes locally (§II-D).
3. ``digests``: content hash per name — jax arrays hash *on device* with the
   Pallas ``hash_delta`` kernel (digests, not tensors, cross to host);
   host objects hash with blake2b over their serialized bytes.
4. ``delta``: only new/changed names move (both directions); deletions are
   propagated as tombstones.
5. codecs: none | zlib (paper's choice) | zstd | quant8+zstd (lossy, opt-in).
"""
from __future__ import annotations

import contextvars
import hashlib
import io
import marshal
import pickle
import types
import zlib
from dataclasses import dataclass, field
from typing import Any

import numpy as np

try:
    import zstandard as _zstd
except ImportError:  # pragma: no cover
    _zstd = None

import jax
import jax.numpy as jnp

from repro.core.astdeps import cell_dependencies
from repro.core.state import ExecutionState

CODECS = ("none", "zlib", "zstd", "quant8+zstd")


class SerializationFailure(Exception):
    """Paper §II-D: on serialization failure the cell executes locally."""


# ----------------------------------------------------------------------
# codec helpers
# ----------------------------------------------------------------------

def _compress(data: bytes, codec: str) -> bytes:
    if codec == "none":
        return data
    if codec == "zlib":
        return zlib.compress(data, level=6)
    if codec in ("zstd", "quant8+zstd"):
        if _zstd is None:
            return zlib.compress(data, level=6)
        return _zstd.ZstdCompressor(level=6).compress(data)
    raise ValueError(codec)


def _decompress(data: bytes, codec: str) -> bytes:
    if codec == "none":
        return data
    if codec == "zlib":
        return zlib.decompress(data)
    if codec in ("zstd", "quant8+zstd"):
        if _zstd is None:
            return zlib.decompress(data)
        return _zstd.ZstdDecompressor().decompress(data)
    raise ValueError(codec)


# ----------------------------------------------------------------------
# array-aware pickling
# ----------------------------------------------------------------------

def _is_array(x) -> bool:
    return isinstance(x, (np.ndarray, jax.Array)) and not np.isscalar(x)


# Target namespace for function-globals rebinding during deserialization:
# a migrated cell-defined function must resolve its globals in the
# *destination* environment's namespace (paper: the remote kernel).
_TARGET_NS: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "repro_target_ns", default=None)


def _make_function(code_bytes: bytes, name: str, defaults, closure_vals):
    code = marshal.loads(code_bytes)  # noqa: S302 — our own serialized stream
    g = _TARGET_NS.get()
    if g is None:
        g = {"__builtins__": __builtins__}
    closure = tuple(types.CellType(v) for v in closure_vals) or None
    fn = types.FunctionType(code, g, name, defaults, closure)
    return fn


def _by_value(fn: types.FunctionType) -> bool:
    """Cell/exec-defined functions can't be pickled by reference."""
    import sys
    mod = getattr(fn, "__module__", None)
    if mod in (None, "__main__"):
        return True
    m = sys.modules.get(mod)
    return m is None or getattr(m, fn.__qualname__.split(".")[0], None) is not fn


class _Pickler(pickle.Pickler):
    def __init__(self, f, store: list):
        super().__init__(f, protocol=pickle.HIGHEST_PROTOCOL)
        self._store = store

    def persistent_id(self, obj):
        if _is_array(obj):
            self._store.append(np.asarray(obj))
            return ("arr", len(self._store) - 1)
        return None

    def reducer_override(self, obj):
        if isinstance(obj, types.FunctionType) and _by_value(obj):
            closure_vals = tuple(c.cell_contents for c in (obj.__closure__ or ()))
            return (_make_function, (marshal.dumps(obj.__code__), obj.__name__,
                                     obj.__defaults__, closure_vals))
        return NotImplemented


class _Unpickler(pickle.Unpickler):
    def __init__(self, f, store: list):
        super().__init__(f)
        self._store = store

    def persistent_load(self, pid):
        kind, idx = pid
        assert kind == "arr"
        return self._store[idx]


_QUANT_OK = (np.float32, np.float64, np.dtype("bfloat16").type
             if hasattr(np.dtype("bfloat16"), "type") else np.float32)


def _encode_array(a: np.ndarray, codec: str, interpret_kernels: bool) -> dict:
    meta = {"shape": a.shape, "dtype": str(a.dtype)}
    if codec == "quant8+zstd" and a.dtype in (np.dtype("float32"),
                                              np.dtype("float64"),
                                              jnp.bfloat16.dtype):
        from repro.kernels.quant_blockwise.ops import quantize
        impl = "pallas" if interpret_kernels else "xla"
        q, s = quantize(jnp.asarray(a), interpret=interpret_kernels, impl=impl)
        meta.update(quant=True,
                    data=_compress(np.asarray(q).tobytes(), codec),
                    scales=_compress(np.asarray(s).tobytes(), codec))
        return meta
    raw = np.ascontiguousarray(a).tobytes()
    meta.update(quant=False, data=_compress(raw, codec))
    return meta


def _decode_array(meta: dict, codec: str) -> np.ndarray:
    shape = tuple(meta["shape"])
    dtype = np.dtype(meta["dtype"]) if meta["dtype"] != "bfloat16" else jnp.bfloat16.dtype
    if meta["quant"]:
        from repro.kernels.quant_blockwise.ops import dequantize
        q = np.frombuffer(_decompress(meta["data"], codec), np.int8).reshape(-1, 1024)
        s = np.frombuffer(_decompress(meta["scales"], codec), np.float32)
        x = dequantize(jnp.asarray(q), jnp.asarray(s), shape,
                       jnp.dtype(dtype), impl="xla")
        return np.asarray(x)
    raw = _decompress(meta["data"], codec)
    return np.frombuffer(raw, dtype).reshape(shape).copy()


# ----------------------------------------------------------------------
# public containers
# ----------------------------------------------------------------------

@dataclass
class SerializedName:
    pickle_bytes: bytes
    arrays: list[dict]

    @property
    def nbytes(self) -> int:
        n = len(self.pickle_bytes)
        for a in self.arrays:
            n += len(a["data"]) + len(a.get("scales", b""))
        return n


@dataclass
class SerializedState:
    codec: str
    blobs: dict[str, SerializedName]
    deleted: tuple[str, ...] = ()
    modules: tuple[str, ...] = ()
    digests: dict[str, int] = field(default_factory=dict)
    skipped: tuple[str, ...] = ()

    @property
    def nbytes(self) -> int:
        return sum(b.nbytes for b in self.blobs.values())


# ----------------------------------------------------------------------
# the reducer
# ----------------------------------------------------------------------

class StateReducer:
    def __init__(self, codec: str = "zlib", reduce_state: bool = True,
                 interpret_kernels: bool = False):
        assert codec in CODECS, codec
        self.codec = codec
        self.reduce_state = reduce_state
        self.interpret_kernels = interpret_kernels

    # -- step 1: which names does this cell need? ----------------------
    def reduce(self, state: ExecutionState, cell_source: str):
        if not self.reduce_state:
            names = set(state.names())
            return names, set(), None
        needed, modules, info = cell_dependencies(cell_source, state.ns)
        return needed, modules, info

    # -- step 2/3: serialize + digest -----------------------------------
    def serialize_names(self, state: ExecutionState, names,
                        codec: str | None = None,
                        on_error: str = "raise") -> SerializedState:
        """on_error="raise": SerializationFailure aborts (caller runs the cell
        locally, §II-D).  on_error="skip": unserializable names simply don't
        travel (used on return migrations — the object stays remote)."""
        codec = codec or self.codec
        blobs: dict[str, SerializedName] = {}
        skipped: list[str] = []
        for name in sorted(names):
            obj = state.ns[name]
            try:
                store: list = []
                buf = io.BytesIO()
                _Pickler(buf, store).dump(obj)
                arrays = [_encode_array(a, codec, self.interpret_kernels)
                          for a in store]
                blobs[name] = SerializedName(
                    pickle_bytes=_compress(buf.getvalue(), codec), arrays=arrays)
            except Exception as e:  # noqa: BLE001 — paper: fall back to local
                if on_error == "skip":
                    skipped.append(name)
                    continue
                raise SerializationFailure(f"{name}: {e}") from e
        ser = SerializedState(codec=codec, blobs=blobs)
        ser.digests = {n: self.digest(state.ns[n]) for n in blobs}
        ser.skipped = tuple(skipped)
        return ser

    def deserialize(self, ser: SerializedState,
                    target_ns: dict | None = None) -> dict[str, Any]:
        token = _TARGET_NS.set(target_ns)
        try:
            out: dict[str, Any] = {}
            for name, blob in ser.blobs.items():
                store = [_decode_array(m, ser.codec) for m in blob.arrays]
                buf = io.BytesIO(_decompress(blob.pickle_bytes, ser.codec))
                out[name] = _Unpickler(buf, store).load()
            return out
        finally:
            _TARGET_NS.reset(token)

    # -- step 3: content digests ---------------------------------------
    def digest(self, obj) -> int:
        from repro.kernels.hash_delta.ops import tensor_digest
        impl = "pallas" if self.interpret_kernels else "xla"
        if _is_array(obj):
            return int(tensor_digest(jnp.asarray(obj),
                                     interpret=self.interpret_kernels, impl=impl))
        leaves, treedef = jax.tree_util.tree_flatten(obj)
        if leaves and all(_is_array(l) for l in leaves):
            h = hashlib.blake2b(str(treedef).encode(), digest_size=8)
            for l in leaves:
                d = int(tensor_digest(jnp.asarray(l),
                                      interpret=self.interpret_kernels, impl=impl))
                h.update(d.to_bytes(8, "little"))
            return int.from_bytes(h.digest(), "little")
        try:
            store: list = []
            buf = io.BytesIO()
            _Pickler(buf, store).dump(obj)
        except Exception:
            return -1  # unhashable => always migrate (paper §II-D)
        h = hashlib.blake2b(buf.getvalue(), digest_size=8)
        for a in store:
            h.update(np.ascontiguousarray(a).tobytes())
            h.update(str(a.shape).encode())
        return int.from_bytes(h.digest(), "little")

    def digests(self, state: ExecutionState, names) -> dict[str, int]:
        return {n: self.digest(state.ns[n]) for n in names if n in state.ns}

    # -- step 4: delta ---------------------------------------------------
    def delta_names(self, state: ExecutionState, names,
                    known: dict[str, int]):
        """Returns (names to send, tombstones, sender digests).
        ``known`` = receiver's current content view."""
        send: set[str] = set()
        here = self.digests(state, names)
        for n, d in here.items():
            if d == -1 or known.get(n) != d:
                send.add(n)
        dead = {n for n in known if n not in state.ns}
        return send, dead, here
