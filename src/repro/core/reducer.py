"""Notebook state reducer (paper §II-D): reduced capture, chunked
serialization onto a content-addressed store, content hashing, delta
migration, compression codecs.

Pipeline (faithful to the paper, TPU-adapted per DESIGN.md §4, then
generalized from name to chunk granularity):

1. ``reduce``: AST Load-closure over the live namespace -> needed names only.
2. ``serialize``: arrays leave the pickle stream and their raw buffers are
   split into fixed-size chunks, each compressed and content-addressed by a
   64-bit digest (optionally block-quantized to int8 on device first);
   everything else pickles.  Identical chunks dedup within one capture.
   Serialization failure => the caller executes locally (§II-D).
3. ``digests``: content hash per name — jax arrays hash *on device* with the
   Pallas ``hash_delta`` kernel (per-block digest lanes, not tensors, cross
   to host; folded to one 64-bit digest per leaf); host objects hash with
   blake2b over their serialized bytes.  Array chunk digests reuse the same
   per-block vector.
4. ``delta``: per-name digests pick which names move; per-chunk manifests
   then ship only the chunks the receiver's store does not already hold, so
   a 1-element update to a 1 GB array moves one chunk, not the array.
   Deletions are propagated as tombstones.
5. codecs: none | zlib (paper's choice) | zstd | quant8+zstd (lossy,
   opt-in), applied chunk-by-chunk and recorded per chunk.
"""
from __future__ import annotations

import contextvars
import hashlib
import io
import marshal
import pickle
import types
import zlib
from dataclasses import dataclass, field
from typing import Any

import numpy as np

try:
    import zstandard as _zstd
except ImportError:  # pragma: no cover
    _zstd = None

import jax
import jax.numpy as jnp

from repro.core.astdeps import cell_dependencies
from repro.core.chunkstore import (
    CHUNK_BYTES, array_chunk_digests_many, decode_chunk, encode_chunk,
    split_chunks,
)
from repro.core.state import ExecutionState

CODECS = ("none", "zlib", "zstd", "quant8+zstd")

DIGEST_BYTES = 8     # manifest cost of advertising one chunk digest


class SerializationFailure(Exception):
    """Paper §II-D: on serialization failure the cell executes locally."""


# ----------------------------------------------------------------------
# codec helpers (scales + pickle streams; chunks carry their own codec tag)
# ----------------------------------------------------------------------

def _compress(data: bytes, codec: str) -> bytes:
    if codec == "none":
        return data
    if codec == "zlib":
        return zlib.compress(data, level=6)
    if codec in ("zstd", "quant8+zstd"):
        if _zstd is None:
            return zlib.compress(data, level=6)
        return _zstd.ZstdCompressor(level=6).compress(data)
    raise ValueError(codec)


def _decompress(data: bytes, codec: str) -> bytes:
    if codec == "none":
        return data
    if codec == "zlib":
        return zlib.decompress(data)
    if codec in ("zstd", "quant8+zstd"):
        if _zstd is None:
            return zlib.decompress(data)
        return _zstd.ZstdDecompressor().decompress(data)
    raise ValueError(codec)


# ----------------------------------------------------------------------
# array-aware pickling
# ----------------------------------------------------------------------

def _is_array(x) -> bool:
    return isinstance(x, (np.ndarray, jax.Array)) and not np.isscalar(x)


# Target namespace for function-globals rebinding during deserialization:
# a migrated cell-defined function must resolve its globals in the
# *destination* environment's namespace (paper: the remote kernel).
_TARGET_NS: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "repro_target_ns", default=None)


def _make_function(code_bytes: bytes, name: str, defaults, closure_vals):
    code = marshal.loads(code_bytes)  # noqa: S302 — our own serialized stream
    g = _TARGET_NS.get()
    if g is None:
        g = {"__builtins__": __builtins__}
    closure = tuple(types.CellType(v) for v in closure_vals) or None
    fn = types.FunctionType(code, g, name, defaults, closure)
    return fn


def _by_value(fn: types.FunctionType) -> bool:
    """Cell/exec-defined functions can't be pickled by reference."""
    import sys
    mod = getattr(fn, "__module__", None)
    if mod in (None, "__main__"):
        return True
    m = sys.modules.get(mod)
    return m is None or getattr(m, fn.__qualname__.split(".")[0], None) is not fn


class _Pickler(pickle.Pickler):
    def __init__(self, f, store: list):
        super().__init__(f, protocol=pickle.HIGHEST_PROTOCOL)
        self._store = store

    def persistent_id(self, obj):
        if _is_array(obj):
            self._store.append(np.asarray(obj))
            return ("arr", len(self._store) - 1)
        return None

    def reducer_override(self, obj):
        if isinstance(obj, types.FunctionType) and _by_value(obj):
            closure_vals = tuple(c.cell_contents for c in (obj.__closure__ or ()))
            return (_make_function, (marshal.dumps(obj.__code__), obj.__name__,
                                     obj.__defaults__, closure_vals))
        return NotImplemented


class _Unpickler(pickle.Unpickler):
    def __init__(self, f, store: list):
        super().__init__(f)
        self._store = store

    def persistent_load(self, pid):
        kind, idx = pid
        assert kind == "arr"
        return self._store[idx]


def _prepare_array(a: np.ndarray, codec: str,
                   interpret_kernels: bool) -> tuple[dict, bytes]:
    """Array -> (chunk-manifest meta sans digests, raw payload bytes).

    Digesting is deferred so the caller can batch every payload of a
    capture into one device launch (:func:`array_chunk_digests_many`)."""
    meta = {"shape": a.shape, "dtype": str(a.dtype)}
    impl = "pallas" if interpret_kernels else "xla"
    if codec == "quant8+zstd" and a.dtype in (np.dtype("float32"),
                                              np.dtype("float64"),
                                              jnp.bfloat16.dtype):
        from repro.kernels.quant_blockwise.ops import quantize
        q, s = quantize(jnp.asarray(a), interpret=interpret_kernels, impl=impl)
        q = np.asarray(q)
        payload = q.tobytes()
        meta.update(quant=True, block=int(q.shape[1]),
                    scales=_compress(np.asarray(s).tobytes(), codec))
    else:
        payload = np.ascontiguousarray(a).tobytes()
        meta.update(quant=False)
    return meta, payload


def _decode_array(meta: dict, codec: str, chunks: dict[int, bytes],
                  store=None) -> np.ndarray:
    shape = tuple(meta["shape"])
    dtype = np.dtype(meta["dtype"]) if meta["dtype"] != "bfloat16" else jnp.bfloat16.dtype

    def fetch(d: int) -> bytes:
        if d in chunks:
            return decode_chunk(chunks[d])
        if store is not None and store.has(d):
            return decode_chunk(store.get(d))
        raise KeyError(f"missing chunk {d:016x}")

    raw = b"".join(fetch(d) for d in meta["chunks"])
    if meta["quant"]:
        from repro.kernels.quant_blockwise.ops import dequantize
        block = int(meta["block"])   # quant block size travels in the meta
        q = np.frombuffer(raw, np.int8).reshape(-1, block)
        s = np.frombuffer(_decompress(meta["scales"], codec), np.float32)
        x = dequantize(jnp.asarray(q), jnp.asarray(s), shape,
                       jnp.dtype(dtype), impl="xla")
        return np.asarray(x)
    return np.frombuffer(raw, dtype).reshape(shape).copy()


# ----------------------------------------------------------------------
# public containers
# ----------------------------------------------------------------------

@dataclass
class SerializedName:
    pickle_bytes: bytes
    arrays: list[dict]

    @property
    def nbytes(self) -> int:
        """Standalone transfer cost of this name (chunks shared with other
        names in the same capture are counted here per reference)."""
        n = len(self.pickle_bytes)
        for a in self.arrays:
            n += sum(a["clens"]) + len(a.get("scales", b""))
        return n

    def chunk_digests(self) -> list[int]:
        return [d for a in self.arrays for d in a["chunks"]]


@dataclass
class SerializedState:
    codec: str
    blobs: dict[str, SerializedName]
    chunks: dict[int, bytes] = field(default_factory=dict)  # digest -> encoded
    deleted: tuple[str, ...] = ()
    modules: tuple[str, ...] = ()
    digests: dict[str, int] = field(default_factory=dict)
    skipped: tuple[str, ...] = ()

    @property
    def nbytes(self) -> int:
        """Full transfer cost: pickle streams + scales + every unique chunk
        (what crosses the wire to a receiver holding nothing)."""
        n = sum(len(b.pickle_bytes)
                + sum(len(a.get("scales", b"")) for a in b.arrays)
                for b in self.blobs.values())
        return n + sum(len(c) - 1 for c in self.chunks.values())

    @property
    def ref_nbytes(self) -> int:
        """Whole-name accounting (the pre-CAS protocol): every chunk counted
        once per reference, no cross-name dedup — the paper's Table-II
        measurement of a plain serialized transfer."""
        return sum(b.nbytes for b in self.blobs.values())

    def wire_nbytes(self, held: set[int]) -> int:
        """Transfer cost against a receiver advertising ``held`` chunk
        digests: full streams for pickles/scales, encoded bytes for missing
        chunks, and DIGEST_BYTES per referenced chunk (the manifest)."""
        n = sum(len(b.pickle_bytes)
                + sum(len(a.get("scales", b"")) for a in b.arrays)
                for b in self.blobs.values())
        refs = 0
        counted: set[int] = set()
        for b in self.blobs.values():
            for d in b.chunk_digests():
                refs += 1
                if d in held or d in counted or d not in self.chunks:
                    continue
                counted.add(d)
                n += len(self.chunks[d]) - 1
        return n + refs * DIGEST_BYTES

    def missing_chunks(self, held: set[int]) -> dict[int, bytes]:
        return {d: c for d, c in self.chunks.items() if d not in held}


# ----------------------------------------------------------------------
# the reducer
# ----------------------------------------------------------------------

class StateReducer:
    def __init__(self, codec: str = "zlib", reduce_state: bool = True,
                 interpret_kernels: bool = False,
                 chunk_bytes: int = CHUNK_BYTES):
        assert codec in CODECS, codec
        self.codec = codec
        self.reduce_state = reduce_state
        self.interpret_kernels = interpret_kernels
        # chunk_bytes <= 0 => one chunk per payload (whole-name granularity,
        # the pre-CAS baseline; benchmarks compare against it)
        self.chunk_bytes = int(chunk_bytes)
        # (name, array-slot) -> (block_h64, chunk_digests, payload_len):
        # priors for the fused digest+compare launch, so re-serializing a
        # partially-changed array folds only its changed chunks on host.
        # Reuse is content-verified on device, so a stale entry can only
        # cost a recompute, never a wrong digest.
        self._chunk_cache: dict[tuple[str, int], tuple] = {}

    # -- step 1: which names does this cell need? ----------------------
    def reduce(self, state: ExecutionState, cell_source: str):
        if not self.reduce_state:
            names = set(state.names())
            return names, set(), None
        needed, modules, info = cell_dependencies(cell_source, state.ns)
        return needed, modules, info

    # -- step 2/3: serialize + digest -----------------------------------
    def serialize_names(self, state: ExecutionState, names,
                        codec: str | None = None,
                        on_error: str = "raise",
                        digests: dict[str, int] | None = None
                        ) -> SerializedState:
        """on_error="raise": SerializationFailure aborts (caller runs the cell
        locally, §II-D).  on_error="skip": unserializable names simply don't
        travel (used on return migrations — the object stays remote).

        ``digests`` lets a caller that already holds this capture's content
        digests (``delta_names`` returns them) pass them through instead of
        re-digesting.

        Chunk digesting is two-pass: pass 1 pickles every name and collects
        raw array payloads; pass 2 digests *all* payloads in one device
        launch + one host sync (with on-device compare against the previous
        capture's block lanes, so unchanged chunks skip their host fold);
        pass 3 encodes chunks with the original per-name rollback."""
        codec = codec or self.codec
        blobs: dict[str, SerializedName] = {}
        chunks: dict[int, bytes] = {}
        skipped: list[str] = []
        prepared: list[tuple[str, bytes, list]] = []
        for name in sorted(names):
            obj = state.ns[name]
            try:
                store: list = []
                buf = io.BytesIO()
                _Pickler(buf, store).dump(obj)
                arrays = [_prepare_array(a, codec, self.interpret_kernels)
                          for a in store]
                prepared.append((name, _compress(buf.getvalue(), codec),
                                 arrays))
            except Exception as e:  # noqa: BLE001 — paper: fall back to local
                if on_error == "skip":
                    skipped.append(name)
                    continue
                raise SerializationFailure(f"{name}: {e}") from e

        keys = [(name, k) for name, _, arrs in prepared
                for k in range(len(arrs))]
        payloads = [p for _, _, arrs in prepared for _, p in arrs]
        impl = "pallas" if self.interpret_kernels else "xla"
        digest_lists, h64s = array_chunk_digests_many(
            payloads, self.chunk_bytes, interpret=self.interpret_kernels,
            impl=impl, priors=[self._chunk_cache.get(k) for k in keys])
        if len(self._chunk_cache) > 4096:   # bounded: priors are a cache
            self._chunk_cache.clear()
        for key, p, digs, h64 in zip(keys, payloads, digest_lists, h64s):
            self._chunk_cache[key] = (h64, digs, len(p))

        pos = 0
        for name, pickle_bytes, arrays in prepared:
            digs_here = digest_lists[pos:pos + len(arrays)]
            pos += len(arrays)
            # chunks newly inserted by this name; an earlier name's chunks
            # were inserted under *its* entry, so rolling these back on a
            # skip can never orphan a previous blob's references
            added: list[int] = []
            try:
                metas = []
                for (meta, payload), digests_a in zip(arrays, digs_here):
                    clens = []
                    for d, chunk in zip(digests_a,
                                        split_chunks(payload,
                                                     self.chunk_bytes)):
                        if d not in chunks:
                            chunks[d] = encode_chunk(chunk, codec)
                            added.append(d)
                        # the 1-byte codec tag is store framing, not wire
                        # payload
                        clens.append(len(chunks[d]) - 1)
                    metas.append(dict(meta, chunks=digests_a, clens=clens))
                blobs[name] = SerializedName(pickle_bytes=pickle_bytes,
                                             arrays=metas)
            except Exception as e:  # noqa: BLE001 — paper: fall back to local
                for d in added:
                    chunks.pop(d, None)
                if on_error == "skip":
                    skipped.append(name)
                    continue
                raise SerializationFailure(f"{name}: {e}") from e
        ser = SerializedState(codec=codec, blobs=blobs, chunks=chunks)
        if digests is None:
            ser.digests = self.digest_many({n: state.ns[n] for n in blobs})
        else:
            ser.digests = {n: digests[n] for n in blobs if n in digests}
            missing = [n for n in blobs if n not in digests]
            if missing:
                ser.digests.update(self.digest_many(
                    {n: state.ns[n] for n in missing}))
        ser.skipped = tuple(skipped)
        return ser

    def deserialize(self, ser: SerializedState,
                    target_ns: dict | None = None,
                    chunk_store=None) -> dict[str, Any]:
        """Rebuild objects; chunks resolve from ``ser.chunks`` first, then
        from ``chunk_store`` (the receiver's CAS)."""
        token = _TARGET_NS.set(target_ns)
        try:
            out: dict[str, Any] = {}
            for name, blob in ser.blobs.items():
                store = [_decode_array(m, ser.codec, ser.chunks, chunk_store)
                         for m in blob.arrays]
                buf = io.BytesIO(_decompress(blob.pickle_bytes, ser.codec))
                out[name] = _Unpickler(buf, store).load()
            return out
        finally:
            _TARGET_NS.reset(token)

    # -- step 3: content digests ---------------------------------------
    @staticmethod
    def _hashable_leaf(a):
        """Map a leaf to a form whose uint32 hashing keeps *every* bit.

        With x64 disabled ``jnp.asarray`` silently narrows int64/float64,
        and the device prep keeps only the (real-part, low-bit) lanes of a
        complex array — a change confined to the dropped bits would hash
        identically and the delta would drop a real update.  So any dtype
        wider than 4 bytes (and any complex dtype, host or device) is
        re-laned to a contiguous uint32 view on the host.  The re-lane
        never falls through silently: a buffer that cannot be viewed as
        uint32 lanes is hashed via its zero-padded raw bytes, and an
        array with no stable bit pattern (object dtype) raises."""
        wide = a.dtype.itemsize > 4 or a.dtype.kind == "c"
        if isinstance(a, jax.Array) and not wide:
            return a                      # device leaf: hash on device
        a = np.asarray(a)
        if a.dtype.kind == "O":
            raise TypeError("object arrays have no stable bit pattern")
        if not wide and a.dtype.kind in "biuf":
            return a
        a = np.ascontiguousarray(a)
        try:
            return a.reshape(-1).view(np.uint32)
        except (TypeError, ValueError):
            buf = a.tobytes()
            buf += b"\0" * ((-len(buf)) % 4)
            return np.frombuffer(buf, np.uint32)

    def _array_digest(self, a) -> int:
        """Per-leaf device digest (wide host dtypes re-lane'd first)."""
        from repro.kernels.hash_delta.ops import tensor_digest
        impl = "pallas" if self.interpret_kernels else "xla"
        return tensor_digest(jnp.asarray(self._hashable_leaf(a)),
                             interpret=self.interpret_kernels, impl=impl)

    def _host_digest(self, obj) -> int:
        """Pickle-stream blake2b for objects that are not pure array trees."""
        try:
            store: list = []
            buf = io.BytesIO()
            _Pickler(buf, store).dump(obj)
        except Exception:
            return -1  # unhashable => always migrate (paper §II-D)
        h = hashlib.blake2b(buf.getvalue(), digest_size=8)
        for a in store:
            h.update(np.ascontiguousarray(a).tobytes())
            h.update(str(a.shape).encode())
        return int.from_bytes(h.digest(), "little")

    def digest(self, obj) -> int:
        if _is_array(obj):
            return self._array_digest(obj)
        leaves, treedef = jax.tree_util.tree_flatten(obj)
        if leaves and all(_is_array(l) for l in leaves):
            h = hashlib.blake2b(str(treedef).encode(), digest_size=8)
            for l in leaves:
                h.update(self._array_digest(l).to_bytes(8, "little"))
            return int.from_bytes(h.digest(), "little")
        return self._host_digest(obj)

    def _split_for_batch(self, objs: dict[str, Any]):
        """Partition names into the batched-digest plan.

        Returns (slots, leaves, host) where ``leaves`` is the flat leaf
        list for one batched launch and each slot is (name, treedef|None,
        leaf_count) consuming that many leaves in order; ``host`` holds the
        names digested via the pickle path."""
        slots: list[tuple[str, Any, int]] = []
        leaves: list = []
        host: dict[str, Any] = {}
        for n, obj in objs.items():
            if _is_array(obj):
                slots.append((n, None, 1))
                leaves.append(self._hashable_leaf(obj))
                continue
            ls, treedef = jax.tree_util.tree_flatten(obj)
            if ls and all(_is_array(l) for l in ls):
                slots.append((n, treedef, len(ls)))
                leaves.extend(self._hashable_leaf(l) for l in ls)
            else:
                host[n] = obj
        return slots, leaves, host

    @staticmethod
    def _fold_slots(slots, leaf_digests) -> dict[str, int]:
        out: dict[str, int] = {}
        i = 0
        for n, treedef, k in slots:
            if treedef is None:
                out[n] = leaf_digests[i]
            else:
                h = hashlib.blake2b(str(treedef).encode(), digest_size=8)
                for d in leaf_digests[i:i + k]:
                    h.update(d.to_bytes(8, "little"))
                out[n] = int.from_bytes(h.digest(), "little")
            i += k
        return out

    def digest_many(self, objs: dict[str, Any]) -> dict[str, int]:
        """Digest a whole manifest: every array leaf across every name is
        packed into ONE kernel launch with ONE host sync (vs one launch +
        one ``np.asarray`` round-trip per leaf), bit-identical to calling
        :meth:`digest` per name."""
        from repro.kernels.hash_delta.ops import digest_leaves
        slots, leaves, host = self._split_for_batch(objs)
        out = {n: self._host_digest(o) for n, o in host.items()}
        if slots:
            impl = "pallas" if self.interpret_kernels else "xla"
            ds = digest_leaves(leaves, interpret=self.interpret_kernels,
                               impl=impl)
            out.update(self._fold_slots(slots, ds))
        return out

    def digests(self, state: ExecutionState, names) -> dict[str, int]:
        return self.digest_many({n: state.ns[n] for n in names
                                 if n in state.ns})

    # -- step 4: delta ---------------------------------------------------
    def delta_names(self, state: ExecutionState, names,
                    known: dict[str, int]):
        """Returns (names to send, tombstones, sender digests).
        ``known`` = receiver's current content view.

        Pure-array names ride the fused digest->compare->gather path: the
        fresh digests are compared against ``known`` on device and only the
        changed-name index list crosses to the host — one launch, one sync
        for the whole manifest."""
        from repro.kernels.hash_delta.ops import digest_leaves_delta
        objs = {n: state.ns[n] for n in names if n in state.ns}
        slots, leaves, host = self._split_for_batch(objs)
        here = {n: self._host_digest(o) for n, o in host.items()}
        send = {n for n, d in here.items() if d == -1 or known.get(n) != d}
        if slots:
            # per-leaf priors: a single-array name compares on device
            # against the receiver's view of that name; tree leaves carry
            # no per-leaf prior (their name digest is a host-side blake2b
            # fold) so their real compare happens after the fold
            prior: list = []
            leaf_name: dict[int, str] = {}   # flat leaf idx -> array name
            i = 0
            for n, treedef, k in slots:
                if treedef is None:
                    prior.append(known.get(n))
                    leaf_name[i] = n
                else:
                    prior.extend([None] * k)
                i += k
            impl = "pallas" if self.interpret_kernels else "xla"
            ds, changed = digest_leaves_delta(
                leaves, prior, interpret=self.interpret_kernels, impl=impl)
            folded = self._fold_slots(slots, ds)
            here.update(folded)
            send.update(leaf_name[j] for j in changed if j in leaf_name)
            send.update(n for n, treedef, _k in slots
                        if treedef is not None and known.get(n) != folded[n])
        dead = {n for n in known if n not in state.ns}
        return send, dead, here
