"""The receiver half of a :class:`~repro.core.transport.SubprocessEnv`.

    python -m repro.core.remote_worker --connect HOST:PORT [--codec zlib]

Connects back to the parent, then serves the wire protocol until BYE: state
streams land in a real :class:`MemoryChunkStore` and materialize into this
process's namespace, EXEC runs cells against that namespace, FETCH streams
requested state back (the round trip home).  This is the smallest honest
"remote kernel": everything the parent knows about it, it learned through
frames.
"""
from __future__ import annotations

import argparse
import socket
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--connect", required=True, metavar="HOST:PORT")
    ap.add_argument("--codec", default="zlib")
    ap.add_argument("--chunk-bytes", type=int, default=None)
    args = ap.parse_args(argv)

    # imports deferred past argparse so --help stays instant
    from repro.core.chunkstore import CHUNK_BYTES, MemoryChunkStore
    from repro.core.reducer import StateReducer
    from repro.core.transport import (
        SocketTransport, WireReceiver, serve_receiver,
    )

    host, _, port = args.connect.rpartition(":")
    sock = socket.create_connection((host, int(port)), timeout=30.0)
    sock.settimeout(None)
    transport = SocketTransport(sock)
    reducer = StateReducer(
        codec=args.codec,
        chunk_bytes=args.chunk_bytes if args.chunk_bytes else CHUNK_BYTES)
    receiver = WireReceiver(MemoryChunkStore(), reducer,
                            ns={"__builtins__": __builtins__})
    try:
        err = serve_receiver(receiver, transport, timeout=None)
    finally:
        transport.close()
    if err is not None:
        print(f"remote_worker: {err}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
