"""Replica plane: converged follower namespaces, zero-replay failover, and
first-result-wins cell racing.

Checkpoint recovery (PR 6) pays detect + restore + replay; the NotebookOS
observation (PAPERS.md) is that a session replicated across environments
turns failure into an instant *promotion*.  :class:`SessionReplicaSet`
keeps K follower namespaces converged by shipping each committed cell's
delta — new chunks plus tombstones — to the followers during think time,
riding the same reducer/CAS machinery the :class:`DeltaReplicator` trickles
with, but *applying* the delta at the follower instead of banking it.  A
per-follower **convergence watermark** (the commit sequence number of the
last cell whose effects are fully applied there) is tracked in telemetry;
on heartbeat-detected primary failure the scheduler promotes the
most-converged follower, applies only the residual banked trickle, and
resumes the plan with ``commit_seq - watermark`` cells to replay — zero
when the follower had converged.

Replication and trickling share bytes both ways (the dedupe satellite):

* a delta the replica set *applied* lands in ``engine.synced[follower]``,
  so the DeltaReplicator's effective-known view skips those names — they
  never trickle again;
* a delta the replicator already *banked* at a follower is claimed via
  :meth:`DeltaReplicator.peek_claim` — manifest-only, the chunks are
  already in the follower's store — instead of re-serializing.

On top of converged followers sits **first-result-wins racing**: when the
interaction model's confidence gate fires for the cell about to run and two
candidate envs disagree on expected total cost within a configurable band,
the cell launches on both (the loser leg via the transport's RACE frame
when it is socket-bound), the first RESULT commits, the loser is CANCELLED
— its namespace untouched, so the committed result is bit-identical to a
solo run — and the loser's wasted work is charged to the engine's single
speculation-waste ledger.

With ``replicas=0`` (the default — no :class:`SessionReplicaSet` attached)
none of these hooks exist and every decision and byte is bit-identical to
the unreplicated runtime.
"""
from __future__ import annotations

import types as _types
from dataclasses import dataclass, field

from repro.core import telemetry as T
from repro.core.analyzer import Decision, _modeled_exec_seconds
from repro.core.interaction import ConfidenceGate
from repro.core.reducer import DIGEST_BYTES, SerializedState

__all__ = ["SessionReplicaSet", "RaceTicket"]


@dataclass
class RaceTicket:
    """One in-flight first-result-wins race."""
    race_id: str
    order: int
    winner: str                  # env the cell commits on (modeled min cost)
    loser: str                   # env whose leg is cancelled
    winner_est: float
    loser_est: float
    started_at: float
    policy_env: str = ""         # what the policy alone would have picked
    leg_bytes: int = 0           # wire bytes the loser leg cost to launch
    settled: bool = field(default=False)


class SessionReplicaSet:
    """Keep K follower namespaces converged with the primary (tentpole).

    ``followers`` are compute-env names in the runtime's registry.  The
    primary is wherever the session currently runs (``rt.current_env``);
    a follower the session migrates onto is trivially converged and sync
    skips it.  :meth:`sync` runs during think time (the fleet scheduler's
    replica proc, mirroring the trickle proc); :meth:`note_cell` advances
    the commit sequence after every committed cell; :meth:`promote` is the
    failover path.
    """

    def __init__(self, runtime, followers, *, race: bool = False,
                 race_band: float = 0.25, race_threshold: float = 0.35,
                 rate: float = 50e6, burst_seconds: float = 1.0):
        self.rt = runtime
        self.engine = runtime.engine
        self.reducer = runtime.engine.reducer
        seen: list[str] = []
        for f in followers:
            env = runtime.envs.get(f)
            assert env is not None, f"unknown follower env {f!r}"
            assert env.kind == "compute", f"follower {f!r} must be compute"
            if f not in seen:
                seen.append(f)
        self.followers: tuple[str, ...] = tuple(seen)
        self.race_enabled = bool(race)
        self.race_band = float(race_band)
        self.race_gate = ConfidenceGate(threshold=float(race_threshold))
        self.rate = float(rate)
        self.burst = self.rate * float(burst_seconds)
        self._budget = self.burst
        self._last_sync: float | None = None
        # convergence bookkeeping: one commit sequence for the session,
        # a watermark per follower (commit seq it has fully converged to),
        # and the dirty-epoch of the primary namespace at that watermark
        # (the dirty-since prefilter, same trick as the trickle ledger)
        self.commit_seq = 0
        self.watermark: dict[str, int] = {f: 0 for f in self.followers}
        self._epochs: dict[str, int] = {}
        # ledger
        self.replicated_bytes = 0
        self.shared_bytes = 0        # claimed from the trickle bank (dedupe)
        self.promotions = 0
        self.races = 0
        self.race_wins: dict[str, int] = {}
        self.race_waste_seconds = 0.0
        self.race_leg_bytes = 0      # wire bytes the losing legs cost
        self._active_race: RaceTicket | None = None
        self._race_seq = 0
        runtime.replicas = self

    # -- convergence -----------------------------------------------------
    def lag(self, follower: str | None = None) -> int:
        """Cells a follower is behind the primary (max over followers when
        none is named); the promotion path replays exactly this many."""
        if follower is not None:
            return max(0, self.commit_seq - self.watermark.get(follower, 0))
        if not self.watermark:
            return 0
        return max(self.lag(f) for f in self.watermark)

    def note_cell(self, order: int) -> None:
        """A cell committed on the primary: every follower not hosting the
        primary is now one cell behind until the next sync converges it."""
        self.commit_seq += 1
        cur = self.rt.current_env
        for f in self.watermark:
            if f == cur:
                self.watermark[f] = self.commit_seq

    # -- think-time sync -------------------------------------------------
    def sync(self, now: float, budget_bytes: float | None = None) -> int:
        """One think-time wakeup: ship each follower the primary's delta and
        *apply* it (namespace + tombstones), advancing the watermark when a
        follower fully converges.  Returns wire bytes shipped.  Without an
        explicit budget, bytes accrue at ``rate`` per second (one burst cap)
        — mirroring the trickle's pacing so replication never outruns the
        low-priority lane it shares."""
        rt = self.rt
        src = rt.envs[rt.current_env]
        if getattr(src, "peer", None) is not None:
            return 0        # a remote primary cannot be snapshotted here
        if budget_bytes is None:
            if self._last_sync is not None:
                self._budget = min(
                    self.burst,
                    self._budget + (now - self._last_sync) * self.rate)
            self._last_sync = now
            budget = self._budget
        else:
            budget = float(budget_bytes)
        if budget <= 0:
            return 0
        total = 0
        for f in self.followers:
            if f == rt.current_env:
                continue        # hosting the primary: trivially converged
            env = rt.envs.get(f)
            if env is None or not env.placeable_now():
                continue
            total += self._sync_to(src, env, budget - total)
            if total >= budget:
                break
        if budget_bytes is None:
            self._budget = max(0.0, self._budget - total)
        return total

    def _sync_to(self, src, dst, budget: float) -> int:
        """Converge one follower: claim whatever the trickle already banked
        there (manifest-only — the shared-bytes half of the dedupe), then
        serialize and apply the residual delta, then drop tombstones."""
        if budget <= 0:
            return 0
        state = src.state
        known = self.engine.synced.setdefault(dst.name, {})
        # tombstones first: names the follower's view holds that the
        # primary no longer does converge even mid-stream
        dead = sorted(n for n in known if n not in state.ns)
        if dead:
            dst.state.drop(dead)
            for n in dead:
                known.pop(n, None)
        # claim the trickle bank (dedupe): content re-validated by digest,
        # chunks already at the follower, only the manifest applies
        rep = self.rt.replicator
        names = {n for n in state.names()
                 if not isinstance(state.get(n), _types.ModuleType)}
        claimed: tuple[str, ...] = ()
        if rep is not None:
            claim = rep.peek_claim(src, dst, names, known)
            if claim is not None:
                objs = self.reducer.deserialize(
                    claim, target_ns=dst.state.ns,
                    chunk_store=dst.chunk_store)
                dst.state.update(objs)
                known.update(claim.digests)
                rep.commit_claim(dst.name, claim)
                held = {d for b in claim.blobs.values()
                        for d in b.chunk_digests()}
                self.shared_bytes += claim.wire_nbytes(held)
                claimed = tuple(sorted(claim.blobs))
        # residual delta, dirty-since prefiltered like the trickle
        last_epoch = self._epochs.get(dst.name, -1)
        cand = {n for n in names
                if n not in known or state.dirty.get(n, 0) > last_epoch}
        applied: list[str] = []
        wire_bytes = 0
        converged = True
        if cand:
            send, _dead, here = self.reducer.delta_names(state, cand, known)
            send &= cand
            if send:
                ser = self.reducer.serialize_names(
                    state, send, on_error="skip", digests=here)
                if ser.blobs:
                    wire_bytes, applied, converged = self._apply(
                        src, dst, ser, budget)
        if converged:
            self._epochs[dst.name] = state.epoch
            old = self.watermark.get(dst.name, 0)
            self.watermark[dst.name] = self.commit_seq
            advanced = self.watermark[dst.name] != old
        else:
            advanced = False
        if applied or dead or claimed or advanced:
            self.rt._emit(T.STATE_REPLICATED, None, follower=dst.name,
                          names=tuple(applied), claimed=claimed,
                          deleted=tuple(dead), nbytes=wire_bytes,
                          watermark=self.watermark.get(dst.name, 0),
                          commit_seq=self.commit_seq)
        return wire_bytes

    def _apply(self, src, dst, ser, budget: float):
        """Ship and apply a serialized delta within ``budget`` wire bytes
        (always at least one name, so a large object still progresses).
        Returns (wire_bytes, applied_names, fully_converged)."""
        known = self.engine.synced.setdefault(dst.name, {})
        dst_peer = getattr(dst, "peer", None)
        held = {d for d in ser.chunks if dst.chunk_store.has(d)}
        take: list[str] = []
        counted = set(held)
        running = 0
        for n in sorted(ser.blobs):
            blob = ser.blobs[n]
            cost = (len(blob.pickle_bytes)
                    + sum(len(a.get("scales", b"")) for a in blob.arrays))
            for d in blob.chunk_digests():
                cost += DIGEST_BYTES
                if d in counted or d not in ser.chunks:
                    continue
                counted.add(d)
                cost += len(ser.chunks[d]) - 1
            if take and running + cost > budget:
                break
            take.append(n)
            running += cost
        sub = SerializedState(codec=ser.codec,
                              blobs={n: ser.blobs[n] for n in take},
                              digests={n: ser.digests[n] for n in take})
        sub.chunks = {d: ser.chunks[d]
                      for b in sub.blobs.values() for d in b.chunk_digests()
                      if d in ser.chunks}
        if dst_peer is not None:
            # real frames: a REPLICA header announces the convergence delta,
            # then a normal non-speculative state stream applies at the far
            # side (the receiver's END handler materializes it)
            dst_peer.replicate(self.rt.session_id, self.commit_seq, sub)
            wire_bytes = sub.wire_nbytes({d for d in sub.chunks
                                          if dst.chunk_store.has(d)})
            dst.chunk_store.put_many(sub.chunks)    # mirror the remote store
        else:
            wire_bytes = sub.wire_nbytes(held)
            dst.chunk_store.put_many(sub.missing_chunks(held))
            objs = self.reducer.deserialize(sub, target_ns=dst.state.ns,
                                            chunk_store=dst.chunk_store)
            dst.state.update(objs)
        src.chunk_store.put_many(sub.chunks)
        known.update(sub.digests)
        self.replicated_bytes += wire_bytes
        return wire_bytes, take, len(take) == len(ser.blobs)

    # -- promotion -------------------------------------------------------
    def pick_follower(self, exclude=()) -> str | None:
        """Most-converged live follower (deterministic name tie-break)."""
        live = [f for f in self.followers
                if f not in exclude and f in self.rt.envs
                and self.rt.envs[f].placeable_now()]
        if not live:
            return None
        return sorted(live,
                      key=lambda f: (-self.watermark.get(f, 0), f))[0]

    def promote(self, failed_env: str, now: float) -> tuple[str, int] | None:
        """Failover: promote the most-converged follower to primary.

        Applies only the *residual* banked trickle (manifest-only — the
        chunks already sit in the follower's store), hands the primary role
        over, and returns ``(follower, cells_to_replay)`` — zero when the
        follower had converged.  Returns None when no live follower is
        left (the caller falls back to checkpoint/rerun recovery)."""
        rt = self.rt
        follower = self.pick_follower(exclude=(failed_env,))
        if follower is None:
            return None
        env = rt.envs[follower]
        known = self.engine.synced.setdefault(follower, {})
        # residual banked delta: entries were digest-validated when banked
        # and tombstoned on every later redefinition, so what is left is
        # the freshest shipped content — the primary that could re-validate
        # them is gone, which is exactly why they were replicated ahead
        rep = rt.replicator
        residual: tuple[str, ...] = ()
        if rep is not None:
            bank = rep.banked.get(follower)
            if bank:
                sub = SerializedState(
                    codec=self.reducer.codec,
                    blobs={n: e.blob for n, e in bank.items()},
                    digests={n: e.digest for n, e in bank.items()})
                objs = self.reducer.deserialize(
                    sub, target_ns=env.state.ns, chunk_store=env.chunk_store)
                env.state.update(objs)
                known.update(sub.digests)
                rep.commit_claim(follower, sub)
                residual = tuple(sorted(sub.blobs))
        peer = getattr(env, "peer", None)
        epoch = self.watermark.get(follower, 0)
        if peer is not None:
            # handshake: the follower's own watermark is authoritative (a
            # stale promoter learns the real residual from the reply)
            epoch = min(epoch, peer.promote(rt.session_id, epoch))
        replay = max(0, self.commit_seq - epoch)
        rt.current_env = follower
        self.promotions += 1
        # the new primary no longer follows itself; its watermark rides
        # the commit sequence from here on (note_cell keeps it pinned)
        self.watermark[follower] = self.commit_seq - replay
        rt._emit(T.SESSION_PROMOTED, None, follower=follower,
                 failed_env=failed_env, watermark=epoch,
                 commit_seq=self.commit_seq, replay=replay,
                 residual=residual)
        return follower, replay

    def forget(self, env_name: str) -> None:
        """``env_name`` died: a dead follower cannot be promoted until it
        re-converges from scratch (its watermark and epoch ledger reset)."""
        if env_name in self.watermark:
            self.watermark[env_name] = 0
        self._epochs.pop(env_name, None)

    # -- first-result-wins racing ----------------------------------------
    def plan_race(self, cell, order: int, decision: Decision,
                  prob: float | None) -> RaceTicket | None:
        """Race admission: gate on the interaction model's confidence for
        the cell about to run, then race only when the two best candidate
        envs disagree on expected total cost within ``race_band``.  The
        modeled first RESULT — the env with minimum expected cost — is the
        winner; the runtime commits the cell there and the loser leg is
        cancelled at commit time."""
        if not self.race_enabled or self._active_race is not None:
            return None
        if len(decision.block) > 1:
            return None     # a committed multi-cell block pins placement
        if prob is None or not self.race_gate.allow(prob):
            if prob is not None:
                self.race_gate.rejected()
            return None
        rt = self.rt
        an = rt.analyzer
        nbytes = an.state_size_estimate.get(rt.nb.name, 0.0)

        def total_cost(env_name: str) -> float | None:
            t = _modeled_exec_seconds(an, cell, env_name)
            if t is None:
                return None
            return (t + an.pair_migration_time(nbytes, rt.current_env,
                                               env_name)
                    + an.env_overhead(env_name))

        # rivals: the policy's choice vs the converged followers (plus the
        # current env — racing in place against a follower is the common
        # shape); a lagging follower would commit a stale namespace
        cands = {decision.env, rt.current_env}
        for f in self.followers:
            if self.watermark.get(f, 0) == self.commit_seq:
                cands.add(f)
        priced = []
        for name in sorted(cands):
            env = rt.envs.get(name)
            if env is None or env.kind != "compute" \
                    or not env.placeable_now():
                continue
            c = total_cost(name)
            if c is not None:
                priced.append((c, name))
        if len(priced) < 2:
            return None
        priced.sort()
        (a_cost, a_env), (b_cost, b_env) = priced[0], priced[1]
        if b_cost - a_cost > self.race_band * max(a_cost, b_cost, 1e-12):
            return None     # clear winner: no point paying a second leg
        self._race_seq += 1
        ticket = RaceTicket(
            race_id=f"{rt.session_id}-race-{self._race_seq}",
            order=order, winner=a_env, loser=b_env,
            winner_est=a_cost, loser_est=b_cost,
            started_at=rt.clock.now(), policy_env=decision.env)
        self._active_race = ticket
        self.races += 1
        # the loser leg launches over the wire when it is transport-bound;
        # in-process legs are modeled only — the loser's namespace is never
        # mutated, which is what keeps the committed result bit-identical
        loser_env = rt.envs.get(b_env)
        peer = getattr(loser_env, "peer", None) if loser_env is not None \
            else None
        if peer is not None:
            ticket.leg_bytes = peer.race(ticket.race_id, cell.source)
        rt._emit(T.CELL_RACED, cell.cell_id, order=order,
                 race_id=ticket.race_id, winner=a_env, loser=b_env,
                 winner_est=a_cost, loser_est=b_cost, prob=prob)
        return ticket

    def settle_race(self, ticket: RaceTicket, *, duration: float,
                    now: float) -> None:
        """The winner's RESULT committed: CANCEL the loser and charge its
        wasted work — it ran for the winner's wall time (first-result-wins)
        or its own estimate, whichever is less — into the race ledger; any
        bytes the losing leg streamed go to the engine's single
        speculation-waste ledger, same as a dead prefetch."""
        if ticket.settled:
            return
        ticket.settled = True
        self._active_race = None
        rt = self.rt
        wasted = min(max(duration, 0.0), ticket.loser_est)
        self.race_waste_seconds += wasted
        self.race_wins[ticket.winner] = self.race_wins.get(
            ticket.winner, 0) + 1
        self.engine.prefetch_wasted_bytes += ticket.leg_bytes
        self.race_leg_bytes += ticket.leg_bytes
        self._cancel_leg(ticket)
        # calibration: an upset (the race committed somewhere the policy
        # alone would not have) justifies the second leg; a race the
        # policy's own pick won anyway was wasted breadth — tighten
        self.race_gate.observe(ticket.winner != ticket.policy_env)
        rt._emit(T.CELL_RACE_CANCELLED, None, race_id=ticket.race_id,
                 loser=ticket.loser, wasted_seconds=wasted,
                 committed=ticket.winner)

    def abort_race(self, *, reason: str = "failure") -> None:
        """The primary died mid-race: cancel the loser leg WITHOUT touching
        its namespace — if that loser is about to be promoted, its committed
        (converged) state must survive the cancel."""
        ticket = self._active_race
        if ticket is None:
            return
        ticket.settled = True
        self._active_race = None
        self._cancel_leg(ticket)
        self.rt._emit(T.CELL_RACE_CANCELLED, None, race_id=ticket.race_id,
                      loser=ticket.loser, wasted_seconds=0.0,
                      committed=None, reason=reason)

    def _cancel_leg(self, ticket: RaceTicket) -> None:
        env = self.rt.envs.get(ticket.loser)
        peer = getattr(env, "peer", None) if env is not None else None
        if peer is not None:
            peer.race_cancel(ticket.race_id)
