"""Event-driven fleet scheduler: many notebook sessions on one live fabric.

The paper serves a single user on a single cloud node; its §II-B insight is
that *think-time gaps* between cell executions are what make migration free.
This module puts those gaps (and everything else a fleet has: arrivals,
cold starts, idle culls, failures, autoscaling) on a discrete-event loop
(:mod:`repro.core.events`):

* **sessions** arrive from a :class:`WorkloadTrace` (Poisson or recorded)
  and think between cells; each session still owns a private
  :class:`HybridRuntime` over a ``registry.clone_topology()`` while one
  shared :class:`CapacityArbiter` models the physical pool;
* **env lifecycle** rides the loop: provisioning (cold start), idle culls
  and failure injection transition the shared registry's state machine and
  mirror into every session clone;
* **failure recovery** goes through the state plane: periodic background
  checkpoints (:class:`SessionCheckpointer` — migration into a storage env's
  CAS) let a session restore and replay only the cells since the last
  checkpoint instead of rerunning from scratch;
* **autoscaling** (:class:`AutoscalePolicy`) watches queue-wait/idle
  telemetry and provisions or culls pool environments.

The paper's setup is the degenerate instance: zero arrival gaps, zero
think-time, no failures, a static always-up fleet — then the event loop
replays the historical earliest-clock-first interleave exactly (session
index breaks ties, as ``min()`` over the session list used to).
"""
from __future__ import annotations

from bisect import bisect_left, bisect_right, insort
from dataclasses import dataclass, field

from repro.core import telemetry as T
from repro.core.analyzer import _modeled_exec_seconds
from repro.core.events import EventLoop
from repro.core.fabric import EnvironmentRegistry
from repro.core.migration import EnvFailure, HybridRuntime
from repro.core.notebook import Cell, Notebook
from repro.core.reducer import SerializedState


class CapacityArbiter:
    """Per-env slot accounting shared by every session in the fleet.

    ``acquire(env, now)`` returns the earliest start time a slot is free
    (== ``now`` when under capacity); ``release`` records the busy interval.
    """

    def __init__(self, registry: EnvironmentRegistry):
        self._cap = {n: registry.capacity(n) for n in registry.names()}
        # interval history per env: acquire times are NOT monotone across
        # sessions (migrations advance a session's clock between the
        # scheduler's earliest-first pick and the gate), so freed slots
        # can't be popped destructively — admission is computed against the
        # retained intervals.  ``prune`` drops intervals that end before the
        # fleet's minimum session clock (no later acquire can see them),
        # which keeps this scan from growing O(total-history).
        self._busy: dict[str, list[tuple[float, float]]] = {
            n: [] for n in registry.names()}
        # interval index: per-env sorted start/end arrays kept alongside the
        # insertion-ordered history, so admission probes are bisects instead
        # of scans — running(t) = |starts ≤ t| − |ends ≤ t| — and each
        # acquire costs O(log live + probes-in-window), not O(live)
        self._starts: dict[str, list[float]] = {n: [] for n in registry.names()}
        self._ends: dict[str, list[float]] = {n: [] for n in registry.names()}
        self.busy_seconds: dict[str, float] = {n: 0.0 for n in registry.names()}
        self.last_release: dict[str, float] = {}
        self.queue_events: list[tuple[str, float, float]] = []  # env, asked, got
        self.horizon = 0.0
        self.pruned_intervals = 0

    def _earliest(self, env: str, now: float, duration: float) -> float:
        """Earliest start ≥ ``now`` with a free slot for all of ``duration``.

        Checking only the start instant would let a session slip in ahead of
        a later-starting recorded interval and overlap it (per-session sim
        clocks are not globally ordered); probing every interval start
        inside the candidate window keeps utilization ≤ 1 whenever declared
        cell costs match actual durations."""
        cap = self._cap.get(env, 1)
        if cap <= 0:
            raise ValueError(f"acquire on env {env!r} with capacity {cap}: "
                             f"placement should never target it")
        self._busy.setdefault(env, [])
        starts = self._starts.setdefault(env, [])
        ends = self._ends.setdefault(env, [])

        def running(q: float) -> int:
            # intervals with start ≤ q < end; closed starts cancel against
            # closed ends, so zero-length intervals never count
            return bisect_right(starts, q) - bisect_right(ends, q)

        t = now
        while True:
            lo = bisect_right(starts, t)
            hi = bisect_left(starts, t + duration)
            blocked_at = None
            for q in (t, *starts[lo:hi]):
                if running(q) >= cap:
                    blocked_at = q
                    break
            if blocked_at is None:
                return t
            # advance to the earliest end after the blocked probe: never
            # past the earliest *running* end, so no admission is skipped —
            # the loop re-probes from there
            t = ends[bisect_right(ends, blocked_at)]

    def acquire(self, env: str, now: float, duration: float = 0.0) -> float:
        t = self._earliest(env, now, duration)
        if t > now:
            self.queue_events.append((env, now, t))
        return t

    def expected_wait(self, env: str, now: float) -> float:
        """Peek the current queue wait without recording a queue event —
        the fleet view's placement-pricing probe."""
        if self._cap.get(env, 1) <= 0:
            return float("inf")
        return self._earliest(env, now, 0.0) - now

    def release(self, env: str, start: float, end: float) -> None:
        self._busy.setdefault(env, []).append((start, end))
        insort(self._starts.setdefault(env, []), start)
        insort(self._ends.setdefault(env, []), end)
        self.busy_seconds[env] = self.busy_seconds.get(env, 0.0) + (end - start)
        self.last_release[env] = max(self.last_release.get(env, 0.0), end)
        self.horizon = max(self.horizon, end)

    def prune(self, before: float) -> int:
        """Drop intervals that ended at or before ``before`` (the fleet's
        minimum session clock): every future ``acquire`` passes ``now >=
        before``, and an interval with ``end <= now`` can never block a
        probe at ``q >= now`` — so the scan stays bounded by the number of
        *live* intervals instead of the whole history."""
        dropped = 0
        for env, intervals in self._busy.items():
            keep = [iv for iv in intervals if iv[1] > before]
            if len(keep) != len(intervals):
                dropped += len(intervals) - len(keep)
                self._busy[env] = keep
                self._starts[env] = sorted(s for s, _ in keep)
                self._ends[env] = sorted(e for _, e in keep)
        self.pruned_intervals += dropped
        return dropped

    def set_capacity(self, env: str, cap: int) -> None:
        self._cap[env] = int(cap)

    def capacity(self, env: str) -> int:
        return self._cap.get(env, 1)

    def utilization(self, env: str) -> float:
        if self.horizon <= 0:
            return 0.0
        return self.busy_seconds.get(env, 0.0) / (
            max(self._cap.get(env, 1), 1) * self.horizon)


# ----------------------------------------------------------------------
# workload traces: arrivals + think-time
# ----------------------------------------------------------------------

@dataclass
class WorkloadTrace:
    """Session arrival offsets and per-cell think-time gaps.

    ``arrivals[i]`` is when session *i* starts; ``think[i][k]`` is the idle
    gap the user leaves after that session's *k*-th executed cell (§II-B —
    these gaps are what migration and prefetch hide inside).  Recorded
    traces pass the lists directly; :meth:`poisson` draws both from a
    seeded generator so runs are reproducible."""

    arrivals: list[float]
    think: list[list[float]]

    @classmethod
    def static(cls, n_sessions: int) -> "WorkloadTrace":
        """The paper's degenerate instance: everyone at t=0, no gaps."""
        return cls([0.0] * n_sessions, [[] for _ in range(n_sessions)])

    @classmethod
    def poisson(cls, n_sessions: int, *, rate: float, think_mean: float,
                cells_per_session: int, seed: int = 0) -> "WorkloadTrace":
        """Poisson arrivals at ``rate``/s, exponential think-times with mean
        ``think_mean`` s — both pre-drawn from ``seed`` (determinism)."""
        import numpy as np
        rng = np.random.default_rng(seed)
        if rate > 0:
            gaps = rng.exponential(1.0 / rate, n_sessions)
            arrivals = [float(t) for t in np.cumsum(gaps) - gaps[0]]
        else:
            arrivals = [0.0] * n_sessions
        think = []
        for _ in range(n_sessions):
            if think_mean > 0:
                think.append([float(x) for x in
                              rng.exponential(think_mean, cells_per_session)])
            else:
                think.append([])
        return cls(arrivals, think)


# ----------------------------------------------------------------------
# workload families (cost plane)
# ----------------------------------------------------------------------

def gpu_training_notebook(name: str = "gpu-train", *, steps: int = 4,
                          step_cost: float = 45.0, params_mb: float = 4.0,
                          data_mb: float = 8.0) -> Notebook:
    """A GPU-heavy training notebook (NotebookOS-style on-demand
    accelerator binding): one cheap setup cell, ``steps`` expensive train
    steps that each mutate the model weights (checkpoint-heavy — every
    step dirties the largest name in the namespace), and a cheap eval
    cell.  Declared costs are home-seconds; an accelerator env's speedup
    divides them, which is exactly the asymmetry the placement DP trades
    against its $/hour price tag."""
    p_elems = max(1, int(params_mb * 131072))     # float64 MB -> elements
    d_elems = max(1, int(data_mb * 131072))
    cells = [Cell((
        "import numpy as np\n"
        f"data = np.arange({d_elems}, dtype=np.float64)\n"
        f"weights = np.zeros({p_elems}, dtype=np.float64)\n"
        "losses = []\n"), cost=2.0, cell_id="setup")]
    for i in range(steps):
        cells.append(Cell((
            f"weights = weights + {float(i + 1)}\n"
            "losses.append(float(weights[0] + data[0]))\n"),
            cost=float(step_cost), cell_id=f"train-{i}"))
    cells.append(Cell("summary = (len(losses), float(weights[-1]))\n",
                      cost=1.0, cell_id="eval"))
    return Notebook(name, cells)


def remote_sensing_notebook(name: str = "remote-sensing", *, scenes: int = 4,
                            scene_mb: float = 6.0,
                            band_cost: float = 30.0) -> Notebook:
    """A remote-sensing pipeline whose working set is dominated by the
    ingested scene stack: heavy per-band computations reference the whole
    stack, so migrating the computation means migrating the dataset.  With
    the dataset homed next to a storage env and egress priced on the link
    out, data gravity must pull compute *to* the data — shipping the stack
    pays egress dollars and loses the placement comparison."""
    s_elems = max(1, int(scene_mb * 131072))
    cells = [Cell((
        "import numpy as np\n"
        f"scenes = np.ones(({scenes}, {s_elems}), dtype=np.float64)\n"
        "products = {}\n"), cost=3.0, cell_id="ingest")]
    for i, stage in enumerate(("ndvi", "cloudmask", "mosaic")):
        cells.append(Cell(
            f"products['{stage}'] = float(scenes[{i % scenes}].sum())\n",
            cost=float(band_cost), cell_id=stage))
    cells.append(Cell("report = sorted(products.items())\n",
                      cost=1.0, cell_id="report"))
    return Notebook(name, cells)


# ----------------------------------------------------------------------
# autoscaling
# ----------------------------------------------------------------------

class AutoscalePolicy:
    """Provision/cull pool environments from queue + idle telemetry.

    ``pool`` names registry envs the policy may scale (they must exist in
    the registry — registered ``status="down"`` for burst capacity the
    policy can bring up).  Every ``check_interval`` seconds of sim time:

    * if any up compute env's expected queue wait exceeds
      ``scale_up_wait``, the first down pool env is provisioned (it comes
      up after its ``cold_start``);
    * an up pool env idle longer than its ``idle_timeout`` — with no
      session currently placed on it — is culled (``draining → down``).
    """

    def __init__(self, pool: list[str], *, check_interval: float = 5.0,
                 scale_up_wait: float = 1.0):
        assert pool, "autoscale needs at least one pool env"
        self.pool = list(pool)
        self.check_interval = float(check_interval)
        self.scale_up_wait = float(scale_up_wait)

    def decide(self, stats: dict) -> list[tuple[str, str]]:
        """``stats``: {env: {status, expected_wait, idle_for, idle_timeout,
        occupied}}.  Returns [(action, env)] with action provision|cull."""
        actions: list[tuple[str, str]] = []
        pressure = max((s["expected_wait"] for s in stats.values()
                        if s["status"] == "up"), default=0.0)
        if pressure > self.scale_up_wait:
            for name in self.pool:
                if stats.get(name, {}).get("status") == "down":
                    actions.append(("provision", name))
                    break
        for name in self.pool:
            s = stats.get(name)
            if (s and s["status"] == "up" and not s["occupied"]
                    and s["idle_timeout"] is not None
                    and s["idle_for"] > s["idle_timeout"]):
                actions.append(("cull", name))
        return actions


# ----------------------------------------------------------------------
# checkpoint/restore through the state plane
# ----------------------------------------------------------------------

class SessionCheckpointer:
    """Periodic background checkpoints of one session into a storage env.

    A save *is* a migration: the session's engine moves the current env's
    namespace into the storage env's content-addressed chunk store (delta
    against the previous save — unchanged names cost a manifest entry,
    unchanged chunks nothing) and the cumulative per-name manifests are
    kept so every checkpoint is self-contained.  ``restore`` deserializes
    the manifest into the home namespace and charges only the chunks home's
    store doesn't already hold — usually a small fraction, because the
    session's own earlier migrations banked most of them."""

    def __init__(self, runtime: HybridRuntime, storage_env):
        self.rt = runtime
        self.storage = storage_env
        self._blobs: dict[str, object] = {}    # name -> SerializedName
        self._digests: dict[str, int] = {}
        self._skipped: set[str] = set()        # unserializable: never captured
        self.cursor = 0                        # plan cursor the save captured
        self.saves = 0
        self.bytes_written = 0

    def save(self, cursor: int, now: float) -> int:
        src = self.rt.envs[self.rt.current_env]
        res = self.rt.engine.migrate(src, self.storage, names=None,
                                     strict=False, now=now)
        for n in res.deleted:
            self._blobs.pop(n, None)
            self._digests.pop(n, None)
        ser = self.rt.engine.last_ser
        if ser is not None:
            self._blobs.update(ser.blobs)
            self._digests.update(ser.digests)
            self._skipped |= set(ser.skipped)
        self.cursor = cursor
        self.saves += 1
        self.bytes_written += res.nbytes
        return res.nbytes

    def restore(self, now: float) -> tuple[int, float]:
        """Rebuild the checkpointed namespace on home; returns (wire bytes,
        modeled seconds).  Chunks already in home's CAS never re-travel."""
        rt = self.rt
        home = rt.envs[rt.home]
        ser = SerializedState(codec=rt.reducer.codec, blobs=dict(self._blobs),
                              digests=dict(self._digests))
        held: set[int] = set()
        for blob in ser.blobs.values():
            for d in blob.chunk_digests():
                if home.chunk_store.has(d):
                    held.add(d)
                elif d not in ser.chunks and self.storage.chunk_store.has(d):
                    ser.chunks[d] = self.storage.chunk_store.get(d)
        wire = ser.wire_nbytes(held)
        seconds = rt.registry.transfer_seconds(self.storage.name, rt.home,
                                               wire)
        objs = rt.reducer.deserialize(ser, target_ns=home.state.ns,
                                      chunk_store=self.storage.chunk_store)
        home.state.update(objs)
        home.chunk_store.put_many(ser.chunks)
        # roll back names the session defined *after* this checkpoint —
        # replay must not see them.  Module aliases survive (never
        # serialized; re-imports are free) and so do names the save had to
        # skip as unserializable (dropping those would lose state replay
        # cannot rebuild from the checkpointed cells).
        import types as _types
        keep = set(self._blobs) | self._skipped
        extra = [n for n in home.state.names()
                 if n not in keep
                 and not isinstance(home.state.get(n), _types.ModuleType)]
        home.state.drop(extra)
        # restored content supersedes whatever any peer thought it held
        rt.engine.invalidate(rt.home, list(objs) + extra)
        return wire, seconds


# ----------------------------------------------------------------------
# reports
# ----------------------------------------------------------------------

@dataclass
class SessionReport:
    session: str
    notebook: str
    cells_run: int
    makespan: float
    queue_wait: float
    migrations: int
    prediction_hits: int = 0
    prediction_total: int = 0
    arrival: float = 0.0
    think_time: float = 0.0
    recoveries: int = 0
    # live replication: think-time bytes trickled ahead / bytes a migration
    # claimed from the bank / speculative bytes (prefetch + trickle) wasted
    trickled_bytes: int = 0
    trickle_claimed_bytes: int = 0
    wasted_bytes: int = 0
    # replica plane (all zero with --replicas 0): follower convergence lag
    # at drain, bytes applied / claimed-from-bank, promotions and races
    replica_lag: int = 0
    replicated_bytes: int = 0
    replica_shared_bytes: int = 0
    promotions: int = 0
    races: int = 0
    race_wins: dict = field(default_factory=dict)
    race_waste_seconds: float = 0.0
    # cost plane (all zero on an unpriced fleet): execution dollars billed
    # per-env, egress dollars for migration bytes, and the fraction of this
    # session's cells that completed within the per-cell latency SLO
    compute_dollars: float = 0.0
    egress_dollars: float = 0.0
    slo_attainment: float = 1.0

    @property
    def dollars(self) -> float:
        return self.compute_dollars + self.egress_dollars

    @property
    def prediction_hit_rate(self) -> float:
        if self.prediction_total == 0:
            return 0.0
        return self.prediction_hits / self.prediction_total


@dataclass
class _Session:
    runtime: HybridRuntime
    plan: list
    cursor: int = 0
    arrival: float = 0.0
    think: list[float] = field(default_factory=list)
    think_used: int = 0
    think_total: float = 0.0
    recoveries: int = 0
    ckpt: SessionCheckpointer | None = None
    rep: object | None = None          # DeltaReplicator when replication on
    replicas: object | None = None     # SessionReplicaSet when replicas on

    def done(self) -> bool:
        return self.cursor >= len(self.plan)

    def next_think(self) -> float:
        if self.think_used < len(self.think):
            t = self.think[self.think_used]
            self.think_used += 1
            return float(t)
        return 0.0


@dataclass
class ScheduleReport:
    sessions: list[SessionReport]
    env_utilization: dict[str, float]
    queue_events: int
    makespan: float
    # predicted per-env demand (modeled seconds the scheduler expected each
    # env to absorb, from peeked placement decisions) next to the realized
    # busy-seconds — the queue telemetry's forecast-vs-actual pair
    predicted_env_seconds: dict[str, float] = field(default_factory=dict)
    actual_env_seconds: dict[str, float] = field(default_factory=dict)
    # fleet plane: lifecycle + failure + recovery + autoscale telemetry
    failures: list[tuple[str, float]] = field(default_factory=list)
    recoveries: int = 0
    checkpoints: int = 0
    checkpoint_bytes: int = 0
    restored_bytes: int = 0
    scale_events: list[tuple[float, str, str]] = field(default_factory=list)
    lifecycle_events: list[tuple[float, str, str, str]] = field(
        default_factory=list)
    fault_events: list[tuple[float, str, str, str]] = field(
        default_factory=list)
    pruned_intervals: int = 0
    # transport plane: which transport each env's migration traffic rides
    env_transports: dict[str, str] = field(default_factory=dict)
    # live replication plane (zero when replication is off): ONE waste
    # ledger covers prefetch speculation and trickled-but-never-claimed
    trickled_bytes: int = 0
    trickle_claimed_bytes: int = 0
    wasted_speculation_bytes: int = 0
    # replica plane (zero with --replicas 0): fleet-wide sums
    replicated_bytes: int = 0
    replica_shared_bytes: int = 0
    promotions: int = 0
    races: int = 0
    race_waste_seconds: float = 0.0
    # cost plane (zero on an unpriced fleet): fleet-wide dollar meter and
    # SLO attainment (cell-weighted across sessions); ``preemptions`` counts
    # injected failures on spot (hazard-rated) envs
    compute_dollars: float = 0.0
    egress_dollars: float = 0.0
    total_dollars: float = 0.0
    preemptions: int = 0
    slo_attainment: float = 1.0
    total_queue_wait: float = field(init=False)
    total_think_time: float = field(init=False)
    prediction_hit_rate: float = field(init=False)

    def __post_init__(self):
        self.total_queue_wait = sum(s.queue_wait for s in self.sessions)
        self.total_think_time = sum(s.think_time for s in self.sessions)
        hits = sum(s.prediction_hits for s in self.sessions)
        total = sum(s.prediction_total for s in self.sessions)
        self.prediction_hit_rate = hits / total if total else 0.0


class _FleetView:
    """What placement policies see of the live fleet: per-env overhead =
    remaining provisioning cold-start + current expected queue wait."""

    def __init__(self, sched: "SessionScheduler"):
        self.sched = sched

    def overhead_seconds(self, env: str) -> float:
        sched = self.sched
        if env not in sched.registry:
            return 0.0
        e = sched.registry[env]
        now = sched._loop.now() if sched._loop is not None else 0.0
        overhead = 0.0
        if e.status == "provisioning":
            overhead += max(0.0, e.ready_at - now)
        wait = sched.arbiter.expected_wait(env, now)
        if wait == float("inf"):
            return wait
        return overhead + wait


class _RecoveryView:
    """What the price-aware placement DP sees of the fleet's recovery
    ladder: the expected (seconds, dollars) ONE preemption costs under the
    configured recovery mode — replica promotion (detection only; the
    follower already converged), checkpoint restore (detection + expected
    replay since the last save, half the checkpoint interval), or rerun
    (detection + expected half-plan replay at home).  Replay runs at the
    home env, so its seconds bill at the home price."""

    def __init__(self, sched: "SessionScheduler"):
        self.sched = sched
        self._plan_cache: float | None = None

    def _mean_plan_seconds(self) -> float:
        if self._plan_cache is None:
            totals = []
            for s in self.sched._sessions:
                nb = s.runtime.nb
                totals.append(sum(nb.cell(ref).cost or 0.0
                                  for ref in s.plan))
            self._plan_cache = (sum(totals) / len(totals)) if totals else 0.0
        return self._plan_cache

    def expected_recovery(self, env: str) -> tuple[float, float]:
        sched = self.sched
        detect = sched.detect_delay
        if sched.replica_cfg is not None:
            sec = detect
        elif sched.recovery == "checkpoint":
            sec = detect + sched.checkpoint_interval / 2.0
        else:
            sec = detect + self._mean_plan_seconds() / 2.0
        home = sched.registry[sched.registry.home]
        return sec, sec * home.price_per_hour / 3600.0


class SessionScheduler:
    """Multiplex N sessions over shared environments with per-env capacity.

    Sessions also share the fabric's *state plane*: every per-session env
    clone fronts the registry-level chunk store of the physical env it
    stands for, so when N sessions load the same dataset its chunks cross
    the wire once and every later session ships only a manifest
    (``share_chunks=False`` isolates the stores instead).

    ``run()`` drives everything on a discrete-event loop.  With the default
    knobs (no workload trace, no failures, no autoscaling) the event order
    is exactly the historical earliest-clock-first interleave; the fleet
    features are strictly additive:

    * ``add_notebook(..., arrival=, think=)`` / ``set_workload(trace)``
      give sessions arrival offsets and per-cell think-time gaps;
    * ``inject_failure(env, at)`` kills an env mid-flight; sessions on it
      recover via checkpoint restore (``enable_recovery("checkpoint")``)
      or by rerunning their plan from the start (``"rerun"``);
    * ``enable_autoscale(policy)`` provisions/culls pool envs from queue
      telemetry and attaches a fleet view so cost/horizon placement prices
      cold starts and queue depth.
    """

    def __init__(self, registry: EnvironmentRegistry, *,
                 share_chunks: bool = True,
                 beat_interval: float = 1.0, miss_threshold: int = 3):
        self.registry = registry
        self.share_chunks = share_chunks
        self.arbiter = CapacityArbiter(registry)
        self.beat_interval = float(beat_interval)
        self.miss_threshold = int(miss_threshold)
        self._sessions: list[_Session] = []
        self._failures: list[tuple[str, float, float | None]] = []
        self._env_failures: dict[str, list[float]] = {}
        self.autoscale: AutoscalePolicy | None = None
        self.recovery: str | None = None       # checkpoint | rerun | None
        self.checkpoint_interval = 30.0
        self.ckpt_storage_name: str | None = None
        self.scale_events: list[tuple[float, str, str]] = []
        self.replication: dict | None = None
        self.replica_cfg: dict | None = None
        self._loop: EventLoop | None = None
        self._coord = None

    # -- fleet configuration -------------------------------------------
    def set_transport(self, env: str, kind: str, *, now: float = 0.0) -> None:
        """Transport plane: mark which transport carries migration traffic
        to ``env`` ("loopback" | "socket" | "subprocess").  The mark lands
        on the physical registry (audit-logged) and is mirrored into every
        session clone, so engines that later attach a live peer — and the
        report below — agree on the binding."""
        self.registry.set_transport(env, kind, now=now)
        for s in self._sessions:
            if env in s.runtime.registry:
                s.runtime.registry[env].transport = kind

    def env_transports(self) -> dict[str, str]:
        """Current transport binding per registered env."""
        return {n: getattr(e, "transport", "loopback")
                for n, e in self.registry.envs().items()}

    @property
    def detect_delay(self) -> float:
        """Failure-detection latency: the heartbeat protocol's miss window
        (``distributed/fault.py``: a worker missing ``miss_threshold``
        beats is declared dead)."""
        if self._coord is not None:
            return self._coord.detection_delay
        return self.beat_interval * self.miss_threshold

    def inject_failure(self, env: str, at: float,
                       recover_after: float | None = None) -> None:
        """Schedule env death at sim time ``at``; with ``recover_after`` the
        env re-provisions that many seconds later (cold start applies)."""
        if env == self.registry.home:
            raise ValueError("cannot fail the home environment")
        if env not in self.registry:
            raise KeyError(env)
        self._failures.append((env, float(at), recover_after))
        self._env_failures.setdefault(env, []).append(float(at))

    def enable_spot_hazards(self, *, seed: int = 0, horizon: float = 900.0,
                            recover_after: float | None = 20.0) -> int:
        """Draw seeded preemption times for every spot env (``hazard_rate
        > 0``) and inject them through the ordinary failure path — the
        heartbeat detector, recovery ladder and EnvFailure machinery treat
        a preemption exactly like any other env death.  Inter-preemption
        gaps are exponential at the env's hazard rate, pre-drawn from a
        per-env substream of ``seed`` out to ``horizon`` sim-seconds, so
        two runs with the same seed see identical preemptions.  With
        ``recover_after`` the capacity comes back that many seconds later
        (spot pools refill).  Returns the number injected."""
        import numpy as np
        injected = 0
        for i, name in enumerate(sorted(self.registry.names())):
            env = self.registry[name]
            if env.hazard_rate <= 0 or name == self.registry.home:
                continue
            rng = np.random.default_rng([int(seed), i])
            t = 0.0
            while True:
                t += float(rng.exponential(1.0 / env.hazard_rate))
                if t > horizon:
                    break
                self.inject_failure(name, t, recover_after=recover_after)
                injected += 1
        return injected

    def enable_recovery(self, mode: str = "checkpoint", *,
                        interval: float = 30.0,
                        storage: str = "fleet-ckpt") -> None:
        """``checkpoint``: periodic background saves into a storage env's
        CAS, restore + replay-since-checkpoint on failure.  ``rerun``:
        no checkpoints — a failed session replays its whole plan."""
        assert mode in ("checkpoint", "rerun"), mode
        self.recovery = mode
        self.checkpoint_interval = float(interval)
        if mode == "checkpoint":
            from repro.core.fabric import ExecutionEnvironment
            if storage not in self.registry:
                self.registry.register(
                    ExecutionEnvironment(storage, kind="storage"))
            self.ckpt_storage_name = storage

    def enable_autoscale(self, policy: AutoscalePolicy) -> None:
        self.autoscale = policy

    def enable_replication(self, *, rate: float = 50e6, top_k: int = 2,
                           liveness: bool = True,
                           interval: float = 1.0) -> None:
        """Live replication: every session gets a background process on the
        event loop that wakes each ``interval`` seconds of think time and
        trickles dirty state to the top-k likely targets at ``rate`` bytes
        per second (the transport's low-priority lane).  ``liveness`` prunes
        provably-dead names from both trickle and full-state moves."""
        self.replication = {"rate": float(rate), "top_k": int(top_k),
                            "liveness": bool(liveness),
                            "interval": float(interval)}

    def enable_replicas(self, k: int = 1, *, followers: list[str] | None = None,
                        race: bool = False, race_band: float = 0.25,
                        race_threshold: float = 0.35, rate: float = 50e6,
                        interval: float = 1.0) -> None:
        """Replica plane: every session keeps ``k`` follower namespaces
        converged during think time (a background sync process on the event
        loop, mirroring the trickle proc) so a primary failure promotes the
        most-converged follower and replays only the unconverged tail —
        zero cells when it had caught up.  ``followers`` pins the follower
        envs explicitly; otherwise the first ``k`` non-home compute envs
        (sorted) follow each session.  ``race=True`` adds first-result-wins
        cell racing on top (see :class:`repro.core.replica.SessionReplicaSet`).
        ``k=0`` without explicit followers is a no-op — today's behavior."""
        k = int(k)
        if k < 0:
            raise ValueError(f"replicas must be >= 0, got {k}")
        if followers is not None and len(set(followers)) != len(followers):
            raise ValueError(f"duplicate follower envs: {followers}")
        if k == 0 and not followers:
            self.replica_cfg = None
            return
        self.replica_cfg = {"k": k, "followers": list(followers or []),
                            "race": bool(race), "race_band": float(race_band),
                            "race_threshold": float(race_threshold),
                            "rate": float(rate), "interval": float(interval)}

    def _pick_followers(self, rt: HybridRuntime) -> list[str]:
        cfg = self.replica_cfg
        if cfg["followers"]:
            return [f for f in cfg["followers"] if f in rt.registry]
        cands = sorted(n for n, e in rt.registry.envs().items()
                       if e.kind == "compute" and n != rt.home)
        return cands[:cfg["k"]]

    # ------------------------------------------------------------------
    def add_session(self, runtime: HybridRuntime, plan, *,
                    arrival: float = 0.0,
                    think: list[float] | None = None) -> HybridRuntime:
        """Attach an existing runtime (it must gate through our arbiter)."""
        runtime.arbiter = self.arbiter
        self._sessions.append(_Session(runtime, list(plan), arrival=arrival,
                                       think=list(think or [])))
        return runtime

    def add_notebook(self, notebook: Notebook, plan=None, *,
                     arrival: float = 0.0, think: list[float] | None = None,
                     **runtime_kw) -> HybridRuntime:
        """Spawn a session on a private clone of the shared fabric topology."""
        reg = self.registry.clone_topology(
            share_chunk_stores=self.share_chunks)
        runtime_kw.setdefault(
            "session_id",
            f"s{len(self._sessions):03d}-{notebook.name}")
        rt = HybridRuntime(notebook, registry=reg, **runtime_kw)
        if plan is None:
            plan = list(range(len(notebook.cells)))
        return self.add_session(rt, plan, arrival=arrival, think=think)

    def set_workload(self, trace: WorkloadTrace) -> None:
        """Apply a workload trace to the sessions added so far, by index."""
        for i, s in enumerate(self._sessions):
            if i < len(trace.arrivals):
                s.arrival = float(trace.arrivals[i])
            if i < len(trace.think):
                s.think = list(trace.think[i])

    # ------------------------------------------------------------------
    @staticmethod
    def _note_predicted_load(s: _Session, cell_ref,
                             predicted: dict[str, float]) -> None:
        """Accumulate the env the cell's placement decision chose and its
        modeled duration into the forecast telemetry.  The decision is the
        one ``run_cell`` just made (``runtime.last_decision``) — the
        forecast is free, no second policy evaluation — while the *actual*
        side of the pair comes from the arbiter's realized busy-seconds
        (which diverge e.g. when a serialization failure forces home)."""
        rt = s.runtime
        d = rt.last_decision
        if d is None:
            return
        cell = rt.nb.cell(cell_ref)
        est = 0.0
        if d.env in rt.registry:
            # measured per-env history first (real .ipynb cells rarely carry
            # a declared cost), then declared-cost / speedup
            est = _modeled_exec_seconds(rt.analyzer, cell, d.env) or 0.0
        predicted[d.env] = predicted.get(d.env, 0.0) + est

    # -- lifecycle plumbing ---------------------------------------------
    def _set_status(self, name: str, status: str, now: float) -> None:
        """Transition the shared registry (audit-logged) and mirror the new
        state into every session's clone — the clones stand for the same
        physical environment."""
        self.registry.set_status(name, status, now=now)
        for s in self._sessions:
            if name in s.runtime.registry:
                clone = s.runtime.registry[name]
                clone.status = self.registry[name].status
                clone.ready_at = self.registry[name].ready_at

    def _fault_check(self, env: str, start: float, end: float) -> float | None:
        """Fault hook handed to every runtime: a failure instant inside the
        work window, or the window start when the env is already dead."""
        if env in self.registry and self.registry[env].status == "failed":
            return start
        for tf in self._env_failures.get(env, ()):
            if start <= tf < end:
                return tf
        return None

    def _fail_env(self, env: str, at: float, recover_after: float | None):
        if env not in self.registry:
            return
        if self.registry[env].status in ("failed", "down"):
            return
        self._set_status(env, "failed", at)
        if recover_after is not None:
            self._loop.call_at(at + recover_after, self._reprovision, env,
                               priority=-10)

    def _reprovision(self, env: str) -> None:
        now = self._loop.now()
        if env not in self.registry:
            return
        if self.registry[env].status not in ("failed", "down"):
            return
        self._set_status(env, "provisioning", now)
        # stale failure times at/before the re-provision can no longer
        # interrupt new work (windows always start at now or later)
        self._env_failures[env] = [
            t for t in self._env_failures.get(env, ()) if t > now]
        ready = self.registry[env].ready_at
        self._loop.call_at(ready, self._mark_up, env, priority=-10)

    def _mark_up(self, env: str) -> None:
        if env not in self.registry:
            return
        e = self.registry[env]
        now = self._loop.now()
        # a provision cycle interrupted by a failure leaves this event
        # stale: the re-provision pushed ready_at later, so only the event
        # that fires at (or after) the *current* ready_at may transition
        if e.status == "provisioning" and now >= e.ready_at - 1e-9:
            self._set_status(env, "up", now)
            # a fresh env's idle clock starts at readiness, not at t=0 —
            # otherwise it could be culled before it ever ran a cell
            self.arbiter.last_release[env] = max(
                self.arbiter.last_release.get(env, 0.0), now)

    # -- autoscale -------------------------------------------------------
    def _occupied(self, env: str) -> bool:
        return any(not s.done() and s.runtime.current_env == env
                   for s in self._sessions)

    def _autoscale_tick(self):
        if all(s.done() for s in self._sessions):
            # fleet drained: reclaim whatever burst capacity is still up
            # (idle-kernel reclamation), then stop the timer
            now = self._loop.now()
            for env in self.autoscale.pool:
                if env in self.registry \
                        and self.registry[env].status == "up":
                    self._set_status(env, "draining", now)
                    self._set_status(env, "down", now)
                    self.scale_events.append((now, "cull", env))
            return False
        now = self._loop.now()
        stats = {}
        for name, e in self.registry.envs().items():
            if e.kind != "compute":
                continue
            wait = (self.arbiter.expected_wait(name, now)
                    if e.status == "up" else 0.0)
            stats[name] = {
                "status": e.status,
                "expected_wait": wait,
                "idle_for": now - self.arbiter.last_release.get(name, 0.0),
                "idle_timeout": e.idle_timeout,
                "occupied": self._occupied(name),
            }
        for action, env in self.autoscale.decide(stats):
            if action == "provision":
                self._set_status(env, "provisioning", now)
                self._loop.call_at(self.registry[env].ready_at,
                                   self._mark_up, env, priority=-10)
            elif action == "cull":
                self._set_status(env, "draining", now)
                self._set_status(env, "down", now)
            self.scale_events.append((now, action, env))

    # -- heartbeats (audit trail via distributed/fault.py) ---------------
    def _beat(self):
        if all(s.done() for s in self._sessions):
            return False                  # fleet drained: stop the timer
        for name, e in self.registry.envs().items():
            if name in self._coord.workers and e.status not in ("failed",
                                                                "down"):
                self._coord.heartbeat(name)
        self._coord.check_failures()

    # -- checkpoints ------------------------------------------------------
    def _checkpoint_tick(self, s: _Session):
        if s.done():
            return False                  # stop this session's timer series
        if self._loop.now() < s.arrival:
            return None
        rt = s.runtime
        env = rt.registry[rt.current_env] if rt.current_env in rt.registry \
            else None
        if env is None or not env.placeable_now():
            return None                   # nothing trustworthy to capture
        nbytes = s.ckpt.save(s.cursor, self._loop.now())
        rt._emit(T.SESSION_CHECKPOINTED, None, cursor=s.cursor,
                 nbytes=nbytes, env=rt.current_env)
        return None

    # -- the session step process ----------------------------------------
    def _prune_arbiter(self) -> None:
        active = [max(s.runtime.clock.now(), s.arrival)
                  for s in self._sessions if not s.done()]
        if active:
            self.arbiter.prune(min(active))

    def _step(self, s: _Session, idx: int, predicted: dict[str, float]):
        if s.done():
            return
        gap = self._loop.now() - s.runtime.clock.now()
        if gap > 0:
            # arrival offset or think-time: the user was idle, the session
            # clock absorbs the gap (queue wait is tracked separately)
            s.runtime.clock.advance_to(self._loop.now())
            if s.cursor > 0:
                s.think_total += gap
        self._prune_arbiter()
        try:
            s.runtime.run_cell(s.plan[s.cursor])
        except EnvFailure as e:
            self._recover(s, idx, e, predicted)
            return
        self._note_predicted_load(s, s.plan[s.cursor], predicted)
        s.cursor += 1
        if s.done():
            return
        t_next = s.runtime.clock.now() + s.next_think()
        self._loop.call_at(t_next, self._step, s, idx, predicted,
                           priority=idx)

    def _trickle_proc(self, s: _Session):
        """Per-session background replication process on the event loop:
        wakes every ``interval`` seconds, and — only while the session is
        idle in think time (its clock has caught up to the loop) — trickles
        the dirty delta over the remaining plan's live set.  Budget accrual
        inside the replicator rate-limits the stream; the transport's
        low-priority lane keeps it out of interactive traffic's way."""
        interval = self.replication["interval"]
        while not s.done():
            yield interval
            if s.done():
                break
            rt = s.runtime
            now = self._loop.now()
            if now < s.arrival or rt.clock.now() > now + 1e-9:
                continue           # not arrived yet, or mid-cell
            remaining = [rt.nb.cell(ref).source
                         for ref in s.plan[s.cursor:]]
            s.rep.step(now, remaining_sources=remaining)

    def _replica_proc(self, s: _Session):
        """Per-session follower-convergence process: wakes every
        ``interval`` seconds of think time (same idle guard as the trickle
        proc) and ships the primary's committed delta to each follower,
        applying it there.  Runs at priority 1001 — after a same-instant
        trickle step — so whatever the trickle just banked at a follower is
        claimed manifest-only instead of re-serialized (the dedupe)."""
        interval = self.replica_cfg["interval"]
        while not s.done():
            yield interval
            if s.done():
                break
            rt = s.runtime
            now = self._loop.now()
            if now < s.arrival or rt.clock.now() > now + 1e-9:
                continue           # not arrived yet, or mid-cell
            s.replicas.sync(now)

    def _recover(self, s: _Session, idx: int, e: EnvFailure,
                 predicted: dict[str, float]) -> None:
        """Failure recovery: detection (heartbeat miss window), then — in
        preference order — follower promotion (replay only the unconverged
        tail), checkpoint restore + replay-since-checkpoint, or
        rerun-from-home."""
        s.recoveries += 1
        rt = s.runtime
        rt.recover_from_failure(e.env)
        rt.clock.advance(self.detect_delay)
        if s.replicas is not None:
            res = s.replicas.promote(e.env, rt.clock.now())
            if res is not None:
                _follower, replay = res
                s.cursor = max(0, s.cursor - replay)
                self._loop.call_at(rt.clock.now(), self._step, s, idx,
                                   predicted, priority=idx)
                return
        if self.recovery == "checkpoint" and s.ckpt is not None \
                and s.ckpt.saves > 0:
            wire, seconds = s.ckpt.restore(rt.clock.now())
            rt.clock.advance(seconds)
            s.cursor = min(s.ckpt.cursor, s.cursor)
            self._restored_bytes += wire
        else:
            s.cursor = 0               # rerun the whole plan from home
            rt.reset_for_replay()      # fresh namespaces: no double-exec state
        self._loop.call_at(rt.clock.now(), self._step, s, idx, predicted,
                           priority=idx)

    # ------------------------------------------------------------------
    def run(self) -> ScheduleReport:
        """Drive arrivals, cells, think-time, lifecycle, failures,
        checkpoints and autoscaling to completion on the event loop."""
        from repro.distributed.fault import Coordinator

        loop = self._loop = EventLoop()
        self._restored_bytes = 0
        predicted: dict[str, float] = {n: 0.0 for n in self.registry.names()}
        dynamic = bool(self._failures or self.autoscale is not None
                       or any(s.arrival or s.think for s in self._sessions))
        if dynamic:
            for s in self._sessions:
                s.runtime.fault_check = self._fault_check
            self._coord = Coordinator(
                [n for n, e in self.registry.envs().items()
                 if e.kind == "compute"],
                clock=loop.clock, beat_interval=self.beat_interval,
                miss_threshold=self.miss_threshold)
            loop.every(self.beat_interval, self._beat, priority=-5)
        if dynamic:
            # live-fleet placement pricing: cost/horizon policies see the
            # remaining cold start of a provisioning env and each env's
            # current expected queue wait (the degenerate static fleet
            # stays unpriced — decisions bit-identical to the paper's)
            view = _FleetView(self)
            for s in self._sessions:
                s.runtime.analyzer.fleet_view = view
        if any(e.price_per_hour > 0 or e.hazard_rate > 0
               for e in self.registry.envs().values()):
            # cost plane: price-aware placement sees the recovery ladder so
            # a spot env's hazard is weighed at what a preemption actually
            # costs under the configured recovery mode (an unpriced fleet
            # attaches nothing — decisions bit-identical to the seed)
            rview = _RecoveryView(self)
            for s in self._sessions:
                s.runtime.analyzer.recovery_view = rview
        if self.autoscale is not None:
            loop.every(self.autoscale.check_interval, self._autoscale_tick,
                       priority=-5)
        if self.recovery == "checkpoint":
            storage = self.registry[self.ckpt_storage_name]
            for s in self._sessions:
                s.ckpt = SessionCheckpointer(s.runtime, storage)
                loop.every(self.checkpoint_interval, self._checkpoint_tick, s,
                           priority=-1, start_after=max(
                               s.arrival, self.checkpoint_interval))
        if self.replication is not None:
            cfg = self.replication
            for s in self._sessions:
                s.rep = s.runtime.attach_replicator(
                    rate=cfg["rate"], top_k=cfg["top_k"],
                    liveness=cfg["liveness"])
                # priority 1000: a same-instant session step always fires
                # first, so the trickle sees the post-cell namespace
                loop.process(self._trickle_proc(s), priority=1000,
                             delay=max(s.arrival, cfg["interval"]))
        if self.replica_cfg is not None:
            cfg = self.replica_cfg
            for s in self._sessions:
                followers = self._pick_followers(s.runtime)
                if not followers:
                    continue
                s.replicas = s.runtime.attach_replicas(
                    followers, race=cfg["race"],
                    race_band=cfg["race_band"],
                    race_threshold=cfg["race_threshold"], rate=cfg["rate"])
                loop.process(self._replica_proc(s), priority=1001,
                             delay=max(s.arrival, cfg["interval"]))
        for env, at, recover_after in self._failures:
            loop.call_at(at, self._fail_env, env, at, recover_after,
                         priority=-10)
        for idx, s in enumerate(self._sessions):
            loop.call_at(s.arrival, self._step, s, idx, predicted,
                         priority=idx)
        try:
            loop.run()
        finally:
            # every runtime closes — and its speculations cancel — even when
            # a cell raises mid-drain (bus subscribers must not leak)
            for s in self._sessions:
                s.runtime.close()
        slo = next((s.runtime.analyzer.slo for s in self._sessions
                    if s.runtime.analyzer.slo is not None), None)

        def _dollars(rt: HybridRuntime) -> tuple[float, float]:
            comp = sum(self.registry[e].price_per_hour * sec / 3600.0
                       for e, sec in rt.exec_env_seconds.items()
                       if e in self.registry)
            egress = sum(self.registry.transfer_dollars(m.src, m.dst, m.nbytes)
                         for m in rt.engine.log
                         if m.src in self.registry and m.dst in self.registry)
            return comp, egress

        def _attainment(rt: HybridRuntime) -> float:
            if slo is None or not rt.cell_latencies:
                return 1.0
            ok = sum(1 for lat in rt.cell_latencies if lat <= slo + 1e-9)
            return ok / len(rt.cell_latencies)

        reports = []
        for s in self._sessions:
            comp_d, egress_d = _dollars(s.runtime)
            reports.append(SessionReport(
                session=s.runtime.session_id,
                notebook=s.runtime.nb.name,
                cells_run=s.cursor,
                makespan=s.runtime.clock.now(),
                queue_wait=s.runtime.queue_wait,
                migrations=s.runtime.migrations,
                prediction_hits=s.runtime.prediction_hits,
                prediction_total=s.runtime.prediction_total,
                arrival=s.arrival,
                think_time=s.think_total,
                recoveries=s.recoveries,
                trickled_bytes=s.rep.trickled_bytes if s.rep else 0,
                trickle_claimed_bytes=s.rep.claimed_bytes if s.rep else 0,
                wasted_bytes=getattr(s.runtime.engine,
                                     "prefetch_wasted_bytes", 0),
                replica_lag=s.replicas.lag() if s.replicas else 0,
                replicated_bytes=(s.replicas.replicated_bytes
                                  if s.replicas else 0),
                replica_shared_bytes=(s.replicas.shared_bytes
                                      if s.replicas else 0),
                promotions=s.replicas.promotions if s.replicas else 0,
                races=s.replicas.races if s.replicas else 0,
                race_wins=dict(s.replicas.race_wins) if s.replicas else {},
                race_waste_seconds=(s.replicas.race_waste_seconds
                                    if s.replicas else 0.0),
                compute_dollars=comp_d,
                egress_dollars=egress_d,
                slo_attainment=_attainment(s.runtime)))
        util = {n: self.arbiter.utilization(n) for n in self.registry.names()}
        makespan = max((r.makespan for r in reports), default=0.0)
        return ScheduleReport(
            sessions=reports, env_utilization=util,
            queue_events=len(self.arbiter.queue_events),
            makespan=makespan,
            predicted_env_seconds=predicted,
            actual_env_seconds=dict(self.arbiter.busy_seconds),
            failures=[(env, at) for env, at, _ in self._failures],
            recoveries=sum(s.recoveries for s in self._sessions),
            checkpoints=sum(s.ckpt.saves for s in self._sessions if s.ckpt),
            checkpoint_bytes=sum(s.ckpt.bytes_written
                                 for s in self._sessions if s.ckpt),
            restored_bytes=self._restored_bytes,
            scale_events=list(self.scale_events),
            lifecycle_events=list(self.registry.lifecycle_log),
            fault_events=[(ev.time, ev.kind, ev.worker, ev.detail)
                          for ev in (self._coord.events if self._coord
                                     else [])],
            pruned_intervals=self.arbiter.pruned_intervals,
            env_transports=self.env_transports(),
            trickled_bytes=sum(r.trickled_bytes for r in reports),
            trickle_claimed_bytes=sum(r.trickle_claimed_bytes
                                      for r in reports),
            wasted_speculation_bytes=sum(r.wasted_bytes for r in reports),
            replicated_bytes=sum(r.replicated_bytes for r in reports),
            replica_shared_bytes=sum(r.replica_shared_bytes
                                     for r in reports),
            promotions=sum(r.promotions for r in reports),
            races=sum(r.races for r in reports),
            race_waste_seconds=sum(r.race_waste_seconds for r in reports),
            compute_dollars=sum(r.compute_dollars for r in reports),
            egress_dollars=sum(r.egress_dollars for r in reports),
            total_dollars=sum(r.dollars for r in reports),
            preemptions=sum(1 for env, _, _ in self._failures
                            if env in self.registry
                            and self.registry[env].hazard_rate > 0),
            slo_attainment=(
                sum(r.slo_attainment * len(s.runtime.cell_latencies)
                    for r, s in zip(reports, self._sessions))
                / max(1, sum(len(s.runtime.cell_latencies)
                             for s in self._sessions))
                if any(s.runtime.cell_latencies for s in self._sessions)
                else 1.0))
