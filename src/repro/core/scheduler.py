"""Multi-session scheduler: many concurrent notebook sessions on one fabric.

The paper serves a single user on a single cloud node.  At fleet scale
(NotebookOS-style) many sessions contend for a shared pool of accelerator
environments, so placement decisions meet *capacity*: when a session's
target env is saturated, the session queues and the wait is telemetry.

Design: each session owns a private :class:`HybridRuntime` over a
``registry.clone_topology()`` (its own kernel namespaces, its own sim
clock), while one shared :class:`CapacityArbiter` — keyed by env *name* —
models the physical hardware all the clones stand for.  The scheduler
interleaves sessions earliest-clock-first, which keeps the global event
order consistent across the independent per-session clocks.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.analyzer import _modeled_exec_seconds
from repro.core.fabric import EnvironmentRegistry
from repro.core.migration import HybridRuntime
from repro.core.notebook import Notebook


class CapacityArbiter:
    """Per-env slot accounting shared by every session in the fleet.

    ``acquire(env, now)`` returns the earliest start time a slot is free
    (== ``now`` when under capacity); ``release`` records the busy interval.
    """

    def __init__(self, registry: EnvironmentRegistry):
        self._cap = {n: registry.capacity(n) for n in registry.names()}
        # full interval history per env: acquire times are NOT monotone
        # across sessions (migrations advance a session's clock between the
        # scheduler's min-clock pick and the gate), so freed slots can't be
        # popped destructively — admission is computed against all intervals.
        self._busy: dict[str, list[tuple[float, float]]] = {
            n: [] for n in registry.names()}
        self.busy_seconds: dict[str, float] = {n: 0.0 for n in registry.names()}
        self.queue_events: list[tuple[str, float, float]] = []  # env, asked, got
        self.horizon = 0.0

    def acquire(self, env: str, now: float, duration: float = 0.0) -> float:
        """Earliest start ≥ ``now`` with a free slot for all of ``duration``.

        Checking only the start instant would let a session slip in ahead of
        a later-starting recorded interval and overlap it (per-session sim
        clocks are not globally ordered); probing every interval start
        inside the candidate window keeps utilization ≤ 1 whenever declared
        cell costs match actual durations."""
        cap = self._cap.get(env, 1)
        intervals = self._busy.setdefault(env, [])

        def running_at(t: float) -> list[float]:
            return [e for s, e in intervals if s <= t < e]

        t = now
        while True:
            probes = [t] + sorted(s for s, _ in intervals
                                  if t < s < t + duration)
            blocked = None
            for q in probes:
                ends = running_at(q)
                if len(ends) >= cap:
                    blocked = ends
                    break
            if blocked is None:
                break
            t = min(blocked)         # earliest slot to free while saturated
        if t > now:
            self.queue_events.append((env, now, t))
        return t

    def release(self, env: str, start: float, end: float) -> None:
        self._busy.setdefault(env, []).append((start, end))
        self.busy_seconds[env] = self.busy_seconds.get(env, 0.0) + (end - start)
        self.horizon = max(self.horizon, end)

    def utilization(self, env: str) -> float:
        if self.horizon <= 0:
            return 0.0
        return self.busy_seconds.get(env, 0.0) / (
            self._cap.get(env, 1) * self.horizon)


@dataclass
class SessionReport:
    session: str
    notebook: str
    cells_run: int
    makespan: float
    queue_wait: float
    migrations: int
    prediction_hits: int = 0
    prediction_total: int = 0

    @property
    def prediction_hit_rate(self) -> float:
        if self.prediction_total == 0:
            return 0.0
        return self.prediction_hits / self.prediction_total


@dataclass
class _Session:
    runtime: HybridRuntime
    plan: list
    cursor: int = 0

    def done(self) -> bool:
        return self.cursor >= len(self.plan)


@dataclass
class ScheduleReport:
    sessions: list[SessionReport]
    env_utilization: dict[str, float]
    queue_events: int
    makespan: float
    # predicted per-env demand (modeled seconds the scheduler expected each
    # env to absorb, from peeked placement decisions) next to the realized
    # busy-seconds — the queue telemetry's forecast-vs-actual pair
    predicted_env_seconds: dict[str, float] = field(default_factory=dict)
    actual_env_seconds: dict[str, float] = field(default_factory=dict)
    total_queue_wait: float = field(init=False)
    prediction_hit_rate: float = field(init=False)

    def __post_init__(self):
        self.total_queue_wait = sum(s.queue_wait for s in self.sessions)
        hits = sum(s.prediction_hits for s in self.sessions)
        total = sum(s.prediction_total for s in self.sessions)
        self.prediction_hit_rate = hits / total if total else 0.0


class SessionScheduler:
    """Multiplex N sessions over shared environments with per-env capacity.

    Sessions also share the fabric's *state plane*: every per-session env
    clone fronts the registry-level chunk store of the physical env it
    stands for, so when N sessions load the same dataset its chunks cross
    the wire once and every later session ships only a manifest
    (``share_chunks=False`` isolates the stores instead)."""

    def __init__(self, registry: EnvironmentRegistry, *,
                 share_chunks: bool = True):
        self.registry = registry
        self.share_chunks = share_chunks
        self.arbiter = CapacityArbiter(registry)
        self._sessions: list[_Session] = []

    # ------------------------------------------------------------------
    def add_session(self, runtime: HybridRuntime, plan) -> HybridRuntime:
        """Attach an existing runtime (it must gate through our arbiter)."""
        runtime.arbiter = self.arbiter
        self._sessions.append(_Session(runtime, list(plan)))
        return runtime

    def add_notebook(self, notebook: Notebook, plan=None,
                     **runtime_kw) -> HybridRuntime:
        """Spawn a session on a private clone of the shared fabric topology."""
        reg = self.registry.clone_topology(
            share_chunk_stores=self.share_chunks)
        rt = HybridRuntime(notebook, registry=reg, **runtime_kw)
        if plan is None:
            plan = list(range(len(notebook.cells)))
        return self.add_session(rt, plan)

    # ------------------------------------------------------------------
    @staticmethod
    def _note_predicted_load(s: _Session, cell_ref,
                             predicted: dict[str, float]) -> None:
        """Accumulate the env the cell's placement decision chose and its
        modeled duration into the forecast telemetry.  The decision is the
        one ``run_cell`` just made (``runtime.last_decision``) — the
        forecast is free, no second policy evaluation — while the *actual*
        side of the pair comes from the arbiter's realized busy-seconds
        (which diverge e.g. when a serialization failure forces home)."""
        rt = s.runtime
        d = rt.last_decision
        if d is None:
            return
        cell = rt.nb.cell(cell_ref)
        est = 0.0
        if d.env in rt.registry:
            # measured per-env history first (real .ipynb cells rarely carry
            # a declared cost), then declared-cost / speedup
            est = _modeled_exec_seconds(rt.analyzer, cell, d.env) or 0.0
        predicted[d.env] = predicted.get(d.env, 0.0) + est

    def run(self) -> ScheduleReport:
        """Earliest-clock-first interleave until every session drains."""
        predicted: dict[str, float] = {n: 0.0 for n in self.registry.names()}
        while True:
            ready = [s for s in self._sessions if not s.done()]
            if not ready:
                break
            s = min(ready, key=lambda s: s.runtime.clock.now())
            s.runtime.run_cell(s.plan[s.cursor])
            self._note_predicted_load(s, s.plan[s.cursor], predicted)
            s.cursor += 1
        reports = []
        for s in self._sessions:
            s.runtime.close()          # also detaches its bus subscribers
            reports.append(SessionReport(
                session=s.runtime.session_id,
                notebook=s.runtime.nb.name,
                cells_run=s.cursor,
                makespan=s.runtime.clock.now(),
                queue_wait=s.runtime.queue_wait,
                migrations=s.runtime.migrations,
                prediction_hits=s.runtime.prediction_hits,
                prediction_total=s.runtime.prediction_total))
        util = {n: self.arbiter.utilization(n) for n in self.registry.names()}
        makespan = max((r.makespan for r in reports), default=0.0)
        return ScheduleReport(
            sessions=reports, env_utilization=util,
            queue_events=len(self.arbiter.queue_events),
            makespan=makespan,
            predicted_env_seconds=predicted,
            actual_env_seconds=dict(self.arbiter.busy_seconds))
