"""Deterministic simulated clock.

The paper's §III policy experiments "forced a fixed migration time and remote
speedups" — i.e. timing is controlled, not measured.  SimClock reproduces
that protocol: real computations run on CPU, but *reported* durations are
base_time / env.speedup and migrations advance the clock by the modeled
transfer time.  A real deployment swaps in WallClock.

Both clocks are time sources for the event loop in
:mod:`repro.core.events`: the loop *advances* a SimClock to each event's
due time and *sleeps* a WallClock (whose ``advance`` is a no-op — that
no-op is the protocol signal that real time cannot be skipped).  The
shared contract — ``now()`` monotone non-decreasing, ``advance(dt)``
returning a time ``>= now()`` before the call — is pinned by the clock
conformance suite in ``tests/test_events.py``.
"""
from __future__ import annotations

import time


class SimClock:
    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        assert dt >= 0, dt
        self._t += float(dt)
        return self._t

    def advance_to(self, t: float) -> float:
        """Jump forward to ``t`` (no-op when already past it): how a session
        clock absorbs arrival offsets and think-time gaps."""
        if t > self._t:
            self._t = float(t)
        return self._t


class WallClock:
    def now(self) -> float:
        return time.monotonic()

    def advance(self, dt: float) -> float:  # real time cannot be advanced
        return self.now()

    def advance_to(self, t: float) -> float:  # (the event loop sleeps instead)
        return self.now()
