"""Deterministic simulated clock.

The paper's §III policy experiments "forced a fixed migration time and remote
speedups" — i.e. timing is controlled, not measured.  SimClock reproduces
that protocol: real computations run on CPU, but *reported* durations are
base_time / env.speedup and migrations advance the clock by the modeled
transfer time.  A real deployment swaps in WallClock.
"""
from __future__ import annotations

import time


class SimClock:
    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        assert dt >= 0, dt
        self._t += float(dt)
        return self._t


class WallClock:
    def now(self) -> float:
        return time.monotonic()

    def advance(self, dt: float) -> float:  # real time cannot be advanced
        return self.now()
