"""Trace-driven policy simulator (paper §III-B/C, Figs. 4-10).

The paper evaluates its four policies by replaying recorded user-interaction
traces under forced migration times and remote speedups.  This module
generates the two trace families of Fig. 4 (synthetic loops; adapted
TensorFlow guide) and replays them under {local, single-cell, block-cell,
remote} with the *real* ContextDetector running online.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.context import ContextDetector


@dataclass(frozen=True)
class Trace:
    name: str
    order: tuple[int, ...]        # executed cell order ids (Fig. 4 y-axis)
    costs: dict[int, float]       # base local seconds per cell (Fig. 7)


# ----------------------------------------------------------------------
# Fig. 4 trace generators (deterministic)
# ----------------------------------------------------------------------

def synthetic_loops_trace(seed: int = 0) -> Trace:
    """~600 interactions over 15 cells with large execution cycles
    (e.g. cells 1-7 executed repeatedly) and scattered cell times."""
    rng = np.random.default_rng(seed)
    order: list[int] = []
    order += list(range(15))                       # first full pass
    for _ in range(10):                            # big cycle over 0..7
        order += list(range(0, 8))
    order += list(range(8, 15))
    for _ in range(8):                             # cycle over 3..9
        order += list(range(3, 10))
    for _ in range(8):                             # cycle over 0..7 again
        order += list(range(0, 8))
    order += list(range(15))                       # final pass
    # scattered execution times (paper: "more scattered" than the TF guide)
    costs = {i: float(np.round(rng.lognormal(mean=0.0, sigma=1.6) * 2.0, 3))
             for i in range(15)}
    return Trace("synthetic-loops", tuple(order), costs)


def tf_guide_trace(seed: int = 1) -> Trace:
    """Adapted TensorFlow beginner's guide: 12 cells, shorter blocks, two
    clear time groups (many cheap cells + a few heavy train cells)."""
    rng = np.random.default_rng(seed)
    order: list[int] = []
    order += list(range(12))
    for _ in range(6):                             # tweak-and-retrain loops
        order += [6, 7, 8]
    for _ in range(5):
        order += [4, 5, 6, 7]
    for _ in range(6):
        order += [8, 9, 10]
    order += list(range(12))
    costs = {}
    for i in range(12):
        if i in (6, 9):                            # model.fit-style cells
            costs[i] = float(np.round(30.0 + 10.0 * rng.random(), 3))
        else:
            costs[i] = float(np.round(0.1 + 0.4 * rng.random(), 3))
    return Trace("tf-guide", tuple(order), costs)


TRACES = {"synthetic-loops": synthetic_loops_trace, "tf-guide": tf_guide_trace}


# ----------------------------------------------------------------------
# policy replay
# ----------------------------------------------------------------------

@dataclass
class SimResult:
    policy: str
    total_seconds: float
    migrations: int

    def speedup_vs(self, local_seconds: float) -> float:
        return local_seconds / self.total_seconds


def simulate(trace: Trace, policy: str, *, migration_time: float | None = None,
             remote_speedup: float | None = None, registry=None,
             state_nbytes: float = 0.0) -> SimResult:
    """Replay a trace under a policy.  Costs come either from the paper's
    forced scalars (``migration_time``/``remote_speedup``) or from an
    :class:`~repro.core.fabric.EnvironmentRegistry`: the offload env is the
    fastest placement candidate and the migration time is the home<->offload
    link cost for ``state_nbytes`` of state."""
    if registry is not None:
        cand = max(registry.candidates(), key=lambda n: registry[n].speedup)
        if remote_speedup is None:
            remote_speedup = registry[cand].speedup
        if migration_time is None:
            migration_time = registry.transfer_seconds(
                registry.home, cand, state_nbytes)
    assert migration_time is not None and remote_speedup is not None, \
        "pass migration_time/remote_speedup or a registry"
    c = trace.costs
    s = remote_speedup
    m = migration_time

    if policy == "local":
        return SimResult("local", sum(c[o] for o in trace.order), 0)

    if policy == "remote":
        total = m + sum(c[o] / s for o in trace.order)  # one initial migration
        return SimResult("remote", total, 1)

    if policy == "single":
        total, migs = 0.0, 0
        for o in trace.order:
            if c[o] / s + 2 * m < c[o]:
                total += c[o] / s + 2 * m
                migs += 2                  # two data migrations per cell (§II-C)
            else:
                total += c[o]
        return SimResult("single", total, migs)

    if policy == "block":
        det = ContextDetector()
        total, migs = 0.0, 0
        remote = False
        plan: list[int] = []
        hist: list[int] = []
        for o in trace.order:
            if remote:
                if o in plan:
                    total += c[o] / s
                    plan.remove(o)
                    if not plan:           # block complete -> return (Fig. 3)
                        total += m
                        migs += 1
                        remote = False
                    hist.append(o)
                    det.record(trace.name, o)
                    continue
                # deviation -> return to local first (Fig. 3)
                total += m
                migs += 1
                remote = False
            block, score, ncand = det.predict_block_scored(trace.name, o)
            known = [b for b in block if b in c]
            loc_sum = sum(c[b] for b in known)
            rem_sum = sum(c[b] / s for b in known)
            # beyond-paper guard against deviation cost: an unproven
            # prediction (single candidate sequence) must be justified by the
            # current cell ALONE (pessimistic single-cell value); the block
            # plan is kept as upside if the prediction does hold.
            conf = 1.0 if len(known) <= 1 else min(score / 100.0 + 0.5, 1.0)
            if len(known) > 1 and ncand < 2:
                commit = c[o] / s + 2 * m < c[o]
            else:
                commit = bool(known) and rem_sum + 2 * m < conf * loc_sum
            if commit:
                total += m
                migs += 1
                remote = True
                plan = [b for b in known if b != o]
                total += c[o] / s
                if not plan:
                    total += m
                    migs += 1
                    remote = False
            else:
                total += c[o]
            hist.append(o)
            det.record(trace.name, o)
        if remote:
            total += m
            migs += 1
        return SimResult("block", total, migs)

    raise ValueError(policy)


def policy_grid(trace: Trace, migration_times, remote_speedups,
                policies=("single", "block"), use_registry: bool = False) -> dict:
    """Speedup (vs local) grids — the data behind Figs. 5/6/8/9/10.

    With ``use_registry`` each grid point is evaluated through a two-env
    :class:`~repro.core.fabric.EnvironmentRegistry` (the fabric API); the
    derived scalars are identical, so decisions match the paper runs."""
    from repro.core.fabric import EnvironmentRegistry
    local = simulate(trace, "local", migration_time=0, remote_speedup=1)
    out = {
        "trace": trace.name,
        "local_seconds": local.total_seconds,
        "migration_times": list(migration_times),
        "remote_speedups": list(remote_speedups),
        "speedup": {p: [] for p in policies},
        "migrations": {p: [] for p in policies},
    }
    for p in policies:
        for mt in migration_times:
            row_s, row_m = [], []
            for rs in remote_speedups:
                if use_registry:
                    reg = EnvironmentRegistry.two_env(
                        remote_speedup=rs, bandwidth=float("inf"), latency=mt)
                    r = simulate(trace, p, registry=reg)
                else:
                    r = simulate(trace, p, migration_time=mt, remote_speedup=rs)
                row_s.append(local.total_seconds / r.total_seconds)
                row_m.append(r.migrations)
            out["speedup"][p].append(row_s)
            out["migrations"][p].append(row_m)
    return out


def cell_frequency(trace: Trace) -> dict[int, dict]:
    """Fig. 7: execution count and relative frequency per cell."""
    counts: dict[int, int] = {}
    for o in trace.order:
        counts[o] = counts.get(o, 0) + 1
    n = len(trace.order)
    return {o: {"count": k, "freq": k / n, "cost": trace.costs[o]}
            for o, k in sorted(counts.items())}
