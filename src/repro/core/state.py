"""Execution state: the live namespace a notebook session mutates (§II-D).

Values may be anything — JAX arrays (possibly sharded), pytrees, numpy
arrays, plain Python objects, functions.  The reducer serializes a *subset*
of names; the state itself is never mutated by capture ("objects are
attached back once the serialization process completes" — we simply never
detach, which is the functional equivalent).
"""
from __future__ import annotations

from typing import Any, Iterator

_HIDDEN_PREFIX = "_"


class ExecutionState:
    def __init__(self, ns: dict[str, Any] | None = None):
        self.ns: dict[str, Any] = dict(ns or {})

    # dict-ish API -----------------------------------------------------
    def __getitem__(self, k: str) -> Any:
        return self.ns[k]

    def __setitem__(self, k: str, v: Any) -> None:
        self.ns[k] = v

    def __contains__(self, k: str) -> bool:
        return k in self.ns

    def get(self, k: str, default: Any = None) -> Any:
        return self.ns.get(k, default)

    def names(self) -> Iterator[str]:
        """User-visible (serializable-candidate) names."""
        for k in self.ns:
            if not k.startswith(_HIDDEN_PREFIX) and k not in ("__builtins__",):
                yield k

    def subset(self, names) -> dict[str, Any]:
        return {k: self.ns[k] for k in names if k in self.ns}

    def update(self, objs: dict[str, Any]) -> None:
        self.ns.update(objs)

    def drop(self, names) -> None:
        for k in names:
            self.ns.pop(k, None)
