"""Execution state: the live namespace a notebook session mutates (§II-D).

Values may be anything — JAX arrays (possibly sharded), pytrees, numpy
arrays, plain Python objects, functions.  The reducer serializes a *subset*
of names; the state itself is never mutated by capture ("objects are
attached back once the serialization process completes" — we simply never
detach, which is the functional equivalent).
"""
from __future__ import annotations

import itertools
from typing import Any, Iterator

_HIDDEN_PREFIX = "_"

# dirty epochs tick off ONE process-wide counter so epochs from different
# states (a session migrating between envs writes into several namespaces)
# stay comparable: "dirtied after that live-set snapshot" is well-defined
# even when snapshot and definition happened in different envs
_EPOCHS = itertools.count(1)


class ExecutionState:
    def __init__(self, ns: dict[str, Any] | None = None):
        self.ns: dict[str, Any] = dict(ns or {})
        # dirty-since-epoch ledger for the background replicator: ``epoch``
        # records the last mark, ``dirty[name]`` the epoch at which the
        # name was last (re)defined.  A trickle target that synced at epoch
        # E only needs names with dirty > E — the cheap prefilter before
        # the digest-level delta.  Names never marked (e.g. seeded at
        # construction) are epoch 0.
        self.epoch: int = 0
        self.dirty: dict[str, int] = {}

    # dirty-epoch ledger ----------------------------------------------
    def mark_dirty(self, names) -> None:
        """Record that ``names`` were just (re)defined (one call per
        completed cell; the epoch comes off the process-wide counter)."""
        self.epoch = next(_EPOCHS)
        for n in names:
            self.dirty[n] = self.epoch

    def dirty_since(self, epoch: int) -> set[str]:
        """Names (re)defined strictly after ``epoch``, still present."""
        return {n for n, e in self.dirty.items() if e > epoch and n in self.ns}

    # dict-ish API -----------------------------------------------------
    def __getitem__(self, k: str) -> Any:
        return self.ns[k]

    def __setitem__(self, k: str, v: Any) -> None:
        self.ns[k] = v

    def __contains__(self, k: str) -> bool:
        return k in self.ns

    def get(self, k: str, default: Any = None) -> Any:
        return self.ns.get(k, default)

    def names(self) -> Iterator[str]:
        """User-visible (serializable-candidate) names."""
        for k in self.ns:
            if not k.startswith(_HIDDEN_PREFIX) and k not in ("__builtins__",):
                yield k

    def subset(self, names) -> dict[str, Any]:
        return {k: self.ns[k] for k in names if k in self.ns}

    def update(self, objs: dict[str, Any]) -> None:
        self.ns.update(objs)

    def drop(self, names) -> None:
        for k in names:
            self.ns.pop(k, None)
