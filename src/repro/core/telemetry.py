"""Telemetry message types and MQ bus (paper §II-A, Table I).

The JupyterLab front-end extension of the paper emits telemetry for every
relevant user action through an authenticated endpoint onto a message-queue
bus (Redis in the paper).  Here the bus is an in-process synchronous pub/sub
with the same message schema; the interface mirrors a Redis channel so a
networked broker can be dropped in.
"""
from __future__ import annotations

import uuid
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable

# Table I — telemetry message types
SESSION_STARTED = "session-started"
SESSION_DISPOSED = "session-disposed"
CELL_EXECUTION_REQUESTED = "cell-execution-requested"
CELL_EXECUTION_STARTED = "cell-execution-started"
CELL_EXECUTION_COMPLETED = "cell-execution-completed"
CELL_MODIFIED = "cell-modified"
# fabric extensions (beyond Table I): multi-session queueing + pipelining
CELL_EXECUTION_QUEUED = "cell-execution-queued"
STATE_PREFETCHED = "state-prefetched"

ALL_TYPES = (SESSION_STARTED, SESSION_DISPOSED, CELL_EXECUTION_REQUESTED,
             CELL_EXECUTION_STARTED, CELL_EXECUTION_COMPLETED, CELL_MODIFIED,
             CELL_EXECUTION_QUEUED, STATE_PREFETCHED)


@dataclass(frozen=True)
class TelemetryMessage:
    """Schema per §II-A: datetime, cell id, notebook, current cell ids,
    session UUID, notebook path, and message type (+ free-form payload)."""
    datetime: float
    type: str
    cell_id: str | None
    notebook: str
    cell_ids: tuple[str, ...]
    session: str
    path: str
    payload: dict = field(default_factory=dict)

    def __post_init__(self):
        assert self.type in ALL_TYPES, self.type


class MQBus:
    """Synchronous in-process pub/sub with full history (deterministic)."""

    def __init__(self):
        self._subs: dict[str, list[Callable[[TelemetryMessage], None]]] = defaultdict(list)
        self.history: list[tuple[str, TelemetryMessage]] = []

    def subscribe(self, topic: str, fn: Callable[[TelemetryMessage], None]) -> None:
        self._subs[topic].append(fn)

    def publish(self, topic: str, msg: TelemetryMessage) -> None:
        self.history.append((topic, msg))
        for fn in list(self._subs.get(topic, [])):
            fn(msg)

    def messages(self, topic: str = "telemetry") -> list[TelemetryMessage]:
        return [m for t, m in self.history if t == topic]


def new_session_id() -> str:
    return str(uuid.uuid4())
