"""Telemetry message types and MQ bus (paper §II-A, Table I).

The JupyterLab front-end extension of the paper emits telemetry for every
relevant user action through an authenticated endpoint onto a message-queue
bus (Redis in the paper).  Here the bus is an in-process synchronous pub/sub
with the same message schema; the interface mirrors a Redis channel so a
networked broker can be dropped in.
"""
from __future__ import annotations

import uuid
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable

# Table I — telemetry message types
SESSION_STARTED = "session-started"
SESSION_DISPOSED = "session-disposed"
CELL_EXECUTION_REQUESTED = "cell-execution-requested"
CELL_EXECUTION_STARTED = "cell-execution-started"
CELL_EXECUTION_COMPLETED = "cell-execution-completed"
CELL_MODIFIED = "cell-modified"
# fabric extensions (beyond Table I): multi-session queueing + pipelining
CELL_EXECUTION_QUEUED = "cell-execution-queued"
STATE_PREFETCHED = "state-prefetched"
STATE_PREFETCH_CANCELLED = "state-prefetch-cancelled"
# live replication: think-time delta trickling to likely targets
STATE_TRICKLED = "state-trickled"
STATE_TRICKLE_CANCELLED = "state-trickle-cancelled"
STATE_TRICKLE_CLAIMED = "state-trickle-claimed"
# fleet-plane extensions: env lifecycle, failures, checkpoint recovery
ENV_LIFECYCLE = "env-lifecycle"
ENV_FAILED = "env-failed"
SESSION_CHECKPOINTED = "session-checkpointed"
SESSION_RECOVERED = "session-recovered"
# replica plane: converged followers, zero-replay promotion, cell racing
STATE_REPLICATED = "state-replicated"
SESSION_PROMOTED = "session-promoted"
CELL_RACED = "cell-raced"
CELL_RACE_CANCELLED = "cell-race-cancelled"

ALL_TYPES = (SESSION_STARTED, SESSION_DISPOSED, CELL_EXECUTION_REQUESTED,
             CELL_EXECUTION_STARTED, CELL_EXECUTION_COMPLETED, CELL_MODIFIED,
             CELL_EXECUTION_QUEUED, STATE_PREFETCHED,
             STATE_PREFETCH_CANCELLED, STATE_TRICKLED,
             STATE_TRICKLE_CANCELLED, STATE_TRICKLE_CLAIMED,
             ENV_LIFECYCLE, ENV_FAILED,
             SESSION_CHECKPOINTED, SESSION_RECOVERED,
             STATE_REPLICATED, SESSION_PROMOTED,
             CELL_RACED, CELL_RACE_CANCELLED)


@dataclass(frozen=True)
class TelemetryMessage:
    """Schema per §II-A: datetime, cell id, notebook, current cell ids,
    session UUID, notebook path, and message type (+ free-form payload)."""
    datetime: float
    type: str
    cell_id: str | None
    notebook: str
    cell_ids: tuple[str, ...]
    session: str
    path: str
    payload: dict = field(default_factory=dict)

    def __post_init__(self):
        assert self.type in ALL_TYPES, self.type


class MQBus:
    """Synchronous in-process pub/sub with bounded history (deterministic).

    ``history`` is a ring buffer (``history_limit`` most recent messages) so
    long-lived buses don't pin every message ever published; subscribers can
    ``unsubscribe`` so sessions don't leak their handlers into later ones."""

    def __init__(self, history_limit: int = 10_000):
        self._subs: dict[str, list[Callable[[TelemetryMessage], None]]] = defaultdict(list)
        self.history: deque[tuple[str, TelemetryMessage]] = deque(
            maxlen=int(history_limit))

    def subscribe(self, topic: str, fn: Callable[[TelemetryMessage], None]) -> None:
        self._subs[topic].append(fn)

    def unsubscribe(self, topic: str,
                    fn: Callable[[TelemetryMessage], None]) -> bool:
        """Remove one subscription; returns False if it wasn't registered
        (idempotent: detaching twice is not an error)."""
        subs = self._subs.get(topic, [])
        if fn in subs:
            subs.remove(fn)
            return True
        return False

    def subscriber_count(self, topic: str | None = None) -> int:
        if topic is not None:
            return len(self._subs.get(topic, []))
        return sum(len(v) for v in self._subs.values())

    def publish(self, topic: str, msg: TelemetryMessage) -> None:
        self.history.append((topic, msg))
        for fn in list(self._subs.get(topic, [])):
            fn(msg)

    def messages(self, topic: str = "telemetry") -> list[TelemetryMessage]:
        return [m for t, m in self.history if t == topic]


def new_session_id() -> str:
    return str(uuid.uuid4())
