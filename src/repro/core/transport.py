"""Pluggable transports: how migration frames actually move.

The wire format (:mod:`repro.core.wire`) says what the bytes *are*; a
:class:`Transport` says how they *travel*:

- :class:`LoopbackTransport` — in-process, zero-copy: ``Frame`` objects
  pass through a queue without ever being encoded.  This is the default
  semantics of the engine's direct path (simulated timing, bit-identical
  paper decisions); the explicit transport exists so the full protocol can
  be exercised and benchmarked without a socket.
- :class:`SocketTransport` — real TCP.  Frames are CRC-framed on the way
  out and integrity-checked on the way in; an optional :class:`TokenBucket`
  shapes bandwidth/latency so wall-clock benchmark numbers stay controlled.
- :class:`SubprocessEnv` — an :class:`~repro.core.fabric.ExecutionEnvironment`
  whose namespace lives in a *child Python process*, reached over a
  SocketTransport: migrations stream chunks into the child's store, cells
  execute there for real, and results round-trip home.

**Timing composition**: the engine always charges the *modeled* link
seconds on the simulated clock (that is what placement decisions are made
from, and what keeps fig5/fig11 bit-identical); a real transport
additionally records measured wall seconds and frame counts on the
:class:`~repro.core.migration.MigrationResult`.
"""
from __future__ import annotations

import os
import queue
import select
import socket
import subprocess
import sys
import threading
import time
from dataclasses import dataclass, field

from repro.core import wire
from repro.core.state import ExecutionState
from repro.core.wire import Frame, FrameDecoder, WireError

TRANSPORTS = ("loopback", "socket", "subprocess")

_RECV_TIMEOUT = 60.0        # a wedged peer must fail, not hang the session


# ----------------------------------------------------------------------
# shaping
# ----------------------------------------------------------------------

class TokenBucket:
    """Classic token bucket: ``delay(n)`` returns how long the caller must
    sleep before putting ``n`` more bytes on the wire, plus a fixed per-call
    latency.  A monotonic clock is injectable so the math is unit-testable
    without sleeping."""

    def __init__(self, rate: float, *, burst: int = 1 << 16,
                 latency: float = 0.0, clock=time.monotonic):
        assert rate > 0, "shaping rate must be positive bytes/second"
        self.rate = float(rate)
        self.burst = float(burst)
        self.latency = float(latency)
        self._clock = clock
        self._tokens = self.burst
        self._t = clock()

    def delay(self, nbytes: int, *, low_priority: bool = False) -> float:
        """Two priority classes share the one bucket without starvation:

        * the **interactive** lane (default) may drive the bucket negative —
          its frame goes out after at most its own serialization time;
        * the **low** lane (trickle traffic) must *wait out* its whole
          deficit first and never leaves the bucket below zero, so an
          interactive frame arriving right behind a trickle frame sees a
          non-negative bucket and is delayed by no more than one in-flight
          frame's serialization — trickle can never starve it.

        Low frames are delayed, never starved: the refill guarantees each
        one eventually clears its deficit.
        """
        now = self._clock()
        self._tokens = min(self.burst, self._tokens + (now - self._t) * self.rate)
        self._t = now
        if low_priority:
            if self._tokens >= nbytes:
                self._tokens -= nbytes
                return self.latency
            wait = (nbytes - self._tokens) / self.rate
            self._tokens = 0.0
            return wait + self.latency
        self._tokens -= nbytes
        wait = 0.0 if self._tokens >= 0 else -self._tokens / self.rate
        return wait + self.latency


# ----------------------------------------------------------------------
# transports
# ----------------------------------------------------------------------

class Transport:
    """Bidirectional, ordered, reliable frame pipe."""

    kind = "abstract"

    def __init__(self):
        self.frames_sent = 0
        self.bytes_sent = 0
        self.frames_recv = 0
        self.bytes_recv = 0

    def send(self, frame: Frame, *, low_priority: bool = False) -> int:
        raise NotImplementedError

    def recv(self, timeout: float | None = _RECV_TIMEOUT) -> Frame:
        raise NotImplementedError

    def poll(self) -> Frame | None:
        """Non-blocking receive: a complete frame if one is available right
        now, else ``None``.  Lets an event loop service many transports
        from one timer tick without dedicating a blocked thread to each."""
        raise NotImplementedError

    def close(self) -> None:  # pragma: no cover - trivial default
        pass


class LoopbackTransport(Transport):
    """Zero-copy in-process transport: ``Frame`` objects cross a thread-safe
    queue without encoding; ``bytes_sent`` still accounts what the frame
    *would* cost on a real link (``Frame.wire_size``)."""

    kind = "loopback"

    def __init__(self, out_q: "queue.Queue[Frame]", in_q: "queue.Queue[Frame]"):
        super().__init__()
        self._out = out_q
        self._in = in_q
        self._closed = False

    @classmethod
    def pair(cls) -> tuple["LoopbackTransport", "LoopbackTransport"]:
        a_to_b: queue.Queue[Frame] = queue.Queue()
        b_to_a: queue.Queue[Frame] = queue.Queue()
        return cls(a_to_b, b_to_a), cls(b_to_a, a_to_b)

    def send(self, frame: Frame, *, low_priority: bool = False) -> int:
        if self._closed:
            raise WireError("send on closed loopback transport")
        self._out.put(frame)
        self.frames_sent += 1
        self.bytes_sent += frame.wire_size
        return frame.wire_size

    def recv(self, timeout: float | None = _RECV_TIMEOUT) -> Frame:
        try:
            frame = self._in.get(timeout=timeout)
        except queue.Empty:
            raise WireError("loopback recv timed out") from None
        self.frames_recv += 1
        self.bytes_recv += frame.wire_size
        return frame

    def poll(self) -> Frame | None:
        try:
            frame = self._in.get_nowait()
        except queue.Empty:
            return None
        self.frames_recv += 1
        self.bytes_recv += frame.wire_size
        return frame

    def close(self) -> None:
        self._closed = True


def sendmsg_all(sock: socket.socket, segments: list) -> int:
    """writev-style gathered send: push every segment without ever joining
    them into one buffer (``socket.sendmsg`` takes the list directly).
    Handles partial sends by re-gathering the unsent tail as views."""
    total = sum(len(s) for s in segments)
    segs = [s if isinstance(s, memoryview) else memoryview(s)
            for s in segments]
    sent_total = 0
    while segs:
        sent = sock.sendmsg(segs)
        sent_total += sent
        if sent_total >= total:
            break
        while sent:                      # drop/trim fully/partly sent heads
            if sent >= len(segs[0]):
                sent -= len(segs[0])
                segs.pop(0)
            else:
                segs[0] = segs[0][sent:]
                sent = 0
    return total


class SocketTransport(Transport):
    """Real TCP.  Outbound frames are encoded scatter-gather (length
    prefix, payload part(s), CRC go out as one ``sendmsg`` — chunk payloads
    are never copied into a joined buffer); inbound bytes run through the
    incremental :class:`FrameDecoder`, so corruption and truncation surface
    as :class:`WireError`.  ``shaper`` throttles outbound bytes (token
    bucket + fixed latency)."""

    kind = "socket"

    def __init__(self, sock: socket.socket, *,
                 shaper: TokenBucket | None = None):
        super().__init__()
        self._sock = sock
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:  # pragma: no cover - e.g. AF_UNIX
            pass
        self.shaper = shaper
        self._dec = FrameDecoder()
        self._closed = False

    @classmethod
    def connect(cls, host: str, port: int, *, timeout: float = 10.0,
                shaper: TokenBucket | None = None) -> "SocketTransport":
        sock = socket.create_connection((host, port), timeout=timeout)
        sock.settimeout(None)
        return cls(sock, shaper=shaper)

    def send(self, frame: Frame, *, low_priority: bool = False) -> int:
        segments = frame.segments()
        nbytes = sum(len(s) for s in segments)
        if self.shaper is not None:
            wait = self.shaper.delay(nbytes, low_priority=low_priority)
            if wait > 0:
                time.sleep(wait)
        try:
            sendmsg_all(self._sock, segments)
        except OSError as e:
            raise WireError(f"socket send failed: {e}") from None
        self.frames_sent += 1
        self.bytes_sent += nbytes
        return nbytes

    def recv(self, timeout: float | None = _RECV_TIMEOUT) -> Frame:
        for f in self._dec.frames():
            self.frames_recv += 1
            self.bytes_recv += f.wire_size
            return f
        self._sock.settimeout(timeout)
        while True:
            try:
                data = self._sock.recv(1 << 16)
            except socket.timeout:
                raise WireError("socket recv timed out") from None
            except OSError as e:
                raise WireError(f"socket recv failed: {e}") from None
            if not data:
                raise WireError("peer closed the connection mid-stream")
            self._dec.feed(data)
            for f in self._dec.frames():
                self.frames_recv += 1
                self.bytes_recv += f.wire_size
                return f

    def poll(self) -> Frame | None:
        while True:
            for f in self._dec.frames():
                self.frames_recv += 1
                self.bytes_recv += f.wire_size
                return f
            ready, _, _ = select.select([self._sock], [], [], 0)
            if not ready:
                return None
            try:
                data = self._sock.recv(1 << 16)
            except OSError as e:
                raise WireError(f"socket recv failed: {e}") from None
            if not data:
                raise WireError("peer closed the connection mid-stream")
            self._dec.feed(data)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()


# ----------------------------------------------------------------------
# receiver state machine (the server half, shared by the in-process
# EnvServer thread and the subprocess worker)
# ----------------------------------------------------------------------

def import_alias_specs(ns: dict, specs) -> None:
    """Apply ``"alias=module"`` manifest specs: import each module into
    ``ns`` under its alias (missing modules are skipped — parity with the
    loopback path's best-effort re-import)."""
    import importlib
    for spec in specs:
        alias, _, target = spec.partition("=")
        try:
            ns[alias] = importlib.import_module(target or alias)
        except ImportError:
            pass


def serve_receiver(receiver: "WireReceiver", transport: Transport,
                   timeout: float | None = _RECV_TIMEOUT) -> Exception | None:
    """Drive a receiver until BYE or disconnect.  Framing breaches
    (WireError) end the session and are returned; any *other* receiver
    exception — a failed deserialize, a poisoned unpickle — is reported to
    the sender as an ERROR frame and the receiver keeps serving (the
    sender's pending ack turns into a prompt WireError instead of a
    timeout)."""
    try:
        while True:
            frame = transport.recv(timeout=timeout)
            try:
                if not receiver.handle(frame, transport):
                    return None
            except WireError:
                raise
            except Exception as e:  # noqa: BLE001 — travels back as ERROR
                receiver._pending = None
                receiver._pending_chunks = {}
                transport.send(wire.json_frame(wire.ERROR, {
                    "error": f"{type(e).__name__}: {e}", "kind": "receiver"}))
    except WireError as e:
        return e


class WireReceiver:
    """Applies an inbound frame stream to a chunk store + namespace, and
    serves the pull/exec RPCs.  One receiver per connection; drive it with
    :func:`serve_receiver` (blocking loop) or frame-by-frame via
    :meth:`handle`."""

    def __init__(self, chunk_store, reducer, ns: dict | None = None):
        self.store = chunk_store
        self.reducer = reducer
        self.state = ExecutionState()
        if ns is not None:
            self.state.ns = ns       # share, don't copy: the env's namespace
                                     # IS the receiver's namespace
        self._pending = None          # (ser, deleted, modules, banked-only)
        self._pending_chunks: dict[int, bytes] = {}
        self._pending_trickle = False
        self.streams_applied = 0
        self.streams_cancelled = 0
        self.streams_trickled = 0
        # replica plane: convergence watermark this namespace has applied
        # (commit sequence of the last REPLICA stream that fully landed)
        self.replica_epoch = 0
        self._replica_pending_epoch: int | None = None
        self.replicas_applied = 0
        self.promotions = 0
        # first-result-wins racing: ids whose CANCEL already arrived — a
        # late "run" for a cancelled race must NOT execute (the wire-level
        # guarantee that a lost race cannot clobber committed state)
        self._races_cancelled: set[str] = set()
        self.races_run = 0
        self.races_cancelled = 0

    # -- helpers --------------------------------------------------------
    def _apply_pending(self) -> list[str]:
        ser, deleted, modules, _spec = self._pending
        ser.chunks = self._pending_chunks
        import_alias_specs(self.state.ns, modules)
        objs = self.reducer.deserialize(ser, target_ns=self.state.ns,
                                        chunk_store=self.store)
        self.state.update(objs)
        self.state.drop(deleted)
        self.streams_applied += 1
        return sorted(objs)

    # -- the state machine ----------------------------------------------
    def handle(self, frame: Frame, transport: Transport) -> bool:
        """Process one frame; returns False when the session should end."""
        t = frame.ftype
        if t == wire.HELLO:
            wire.parse_hello(frame)                 # validates magic/version
            transport.send(wire.hello_frame(self.reducer.codec))
        elif t == wire.MANIFEST:
            ser, deleted, modules, spec, trickle = wire.parse_manifest(frame)
            # a trickle stream banks exactly like a speculative one: chunks
            # land in the store, the namespace waits for a claiming stream
            self._pending = (ser, deleted, modules, spec or trickle)
            self._pending_trickle = trickle
            if trickle:
                self.streams_trickled += 1
            self._pending_chunks = {}
            referenced = {d for b in ser.blobs.values()
                          for d in b.chunk_digests()}
            need = sorted(d for d in referenced if not self.store.has(d))
            transport.send(wire.json_frame(wire.ACK, {"need": need}))
        elif t == wire.CHUNK:
            digest = self.store.ingest_frame(frame)
            self._pending_chunks[digest] = self.store.get(digest)
        elif t == wire.TOMBSTONE:
            self.state.drop(parse_list(frame))
        elif t == wire.END:
            if self._pending is None:
                raise WireError("END without a preceding MANIFEST")
            spec = self._pending[3]
            applied: list[str] = []
            if not spec:
                # speculative/trickle streams only bank chunks; the
                # namespace is touched when the claiming stream lands
                applied = self._apply_pending()
            self._pending = None
            self._pending_chunks = {}
            ack_doc: dict = {"applied": applied, "speculative": spec}
            if self._pending_trickle:
                ack_doc["trickle"] = True
            self._pending_trickle = False
            if self._replica_pending_epoch is not None:
                # the convergence delta fully landed: advance the watermark
                self.replica_epoch = self._replica_pending_epoch
                self._replica_pending_epoch = None
                self.replicas_applied += 1
            transport.send(wire.json_frame(wire.ACK, ack_doc))
        elif t == wire.CANCEL:
            # in-flight cancellation: the stream's chunks stay banked
            # (content-addressed, immutable) but nothing touches the
            # namespace and no ack is owed
            if self._pending is not None:
                self.streams_cancelled += 1
            self._pending = None
            self._pending_chunks = {}
            self._pending_trickle = False
            self._replica_pending_epoch = None
        elif t == wire.EXEC:
            req = wire.parse_json(frame)
            t0 = time.perf_counter()
            try:
                exec(compile(req["source"], "<remote>", "exec"),  # noqa: S102
                     self.state.ns)
            except Exception as e:  # noqa: BLE001 — travels back as RESULT
                transport.send(wire.json_frame(
                    wire.RESULT, {"error": f"{type(e).__name__}: {e}"}))
                return True
            transport.send(wire.json_frame(
                wire.RESULT, {"duration": time.perf_counter() - t0}))
        elif t == wire.REPLICA:
            # convergence-delta header: drop the announced tombstones now
            # (mid-stream deletions converge even when the residual delta
            # is empty) and stage the watermark — committed at END, so a
            # cancelled stream never overstates convergence
            doc = wire.parse_replica(frame)
            self.state.drop(doc["deleted"])
            self._replica_pending_epoch = doc["epoch"]
        elif t == wire.PROMOTE:
            # failover handshake: reply with the watermark this namespace
            # actually converged to — a stale promoter learns the residual
            _session, _epoch = wire.parse_promote(frame)
            self.promotions += 1
            transport.send(wire.json_frame(
                wire.RESULT, {"epoch": self.replica_epoch}))
        elif t == wire.RACE:
            doc = wire.parse_race(frame)
            if doc["action"] == "cancel":
                self._races_cancelled.add(doc["id"])
                self.races_cancelled += 1
            elif doc["id"] in self._races_cancelled:
                # the CANCEL raced ahead of the run: do NOT execute — a
                # lost race must never touch this namespace
                transport.send(wire.json_frame(
                    wire.RESULT, {"id": doc["id"], "cancelled": True}))
            else:
                # a race leg runs against an OVERLAY of the namespace and
                # the overlay is discarded: only the committing (winner)
                # path — a normal EXEC/migration — mutates real state, so
                # the committed result is bit-identical to a solo run
                self.races_run += 1
                overlay = dict(self.state.ns)
                t0 = time.perf_counter()
                try:
                    exec(compile(doc["source"], "<race>", "exec"),  # noqa: S102
                         overlay)
                except Exception as e:  # noqa: BLE001 — back as RESULT
                    transport.send(wire.json_frame(wire.RESULT, {
                        "id": doc["id"],
                        "error": f"{type(e).__name__}: {e}"}))
                    return True
                transport.send(wire.json_frame(wire.RESULT, {
                    "id": doc["id"],
                    "duration": time.perf_counter() - t0}))
        elif t == wire.FETCH:
            self._serve_fetch(wire.parse_json(frame), transport)
        elif t == wire.BYE:
            return False
        elif t == wire.ERROR:
            doc = wire.parse_json(frame)
            raise WireError(f"peer error: {doc.get('error')}")
        else:  # pragma: no cover - decoder rejects unknown types first
            raise WireError(f"unexpected frame type {t}")
        return True

    def _serve_fetch(self, req: dict, transport: Transport) -> None:
        """The pull path: this side becomes the sender of a state stream."""
        import types as _types
        from repro.core.reducer import SerializationFailure
        names = req.get("names")
        source = req.get("source")
        known = {n: int(d) for n, d in (req.get("known") or {}).items()}
        modules: set[str] = set()
        if names is None:
            if source:
                names, modules, _ = self.reducer.reduce(self.state, source)
            else:
                names = set(self.state.names())
        names = {n for n in names if n in self.state.ns
                 and not isinstance(self.state.get(n), _types.ModuleType)}
        mod_aliases = [
            f"{alias}={val.__name__}" for alias, val in self.state.ns.items()
            if isinstance(val, _types.ModuleType)
            and (alias in (req.get("names") or (alias,))
                 or val.__name__.split(".")[0] in modules)
            and not alias.startswith("__")]
        if req.get("delta", True):
            send, dead, here = self.reducer.delta_names(self.state, names,
                                                        known)
            send &= names
        else:
            send, dead, here = set(names), set(), None
        try:
            ser = self.reducer.serialize_names(
                self.state, send,
                on_error="raise" if req.get("strict", True) else "skip",
                digests=here)
        except SerializationFailure as e:
            transport.send(wire.json_frame(
                wire.ERROR, {"error": str(e), "kind": "serialization"}))
            return
        transport.send(wire.manifest_frame(ser, deleted=dead,
                                           modules=mod_aliases))
        ack = wire.parse_json(_expect(transport.recv(), wire.ACK))
        need = [int(d) for d in ack.get("need", [])]
        for f in wire.state_stream_frames(ser, need, deleted=dead):
            transport.send(f)
        _expect(transport.recv(), wire.ACK)           # done-ack


def parse_list(frame: Frame) -> list[str]:
    doc = wire.parse_json(frame)
    if not isinstance(doc, list):
        raise WireError(f"expected a JSON list payload, got {type(doc)}")
    return [str(x) for x in doc]


def _expect(frame: Frame, ftype: int) -> Frame:
    if frame.ftype == wire.ERROR:
        doc = wire.parse_json(frame)
        if doc.get("kind") == "serialization":
            from repro.core.reducer import SerializationFailure
            raise SerializationFailure(doc.get("error", "remote"))
        raise WireError(f"peer error: {doc.get('error')}")
    if frame.ftype != ftype:
        raise WireError(f"expected {wire.TYPE_NAMES[ftype]}, got "
                        f"{wire.TYPE_NAMES.get(frame.ftype, frame.ftype)}")
    return frame


# ----------------------------------------------------------------------
# sender peer (the client half the MigrationEngine drives)
# ----------------------------------------------------------------------

@dataclass
class StreamStats:
    """What one state stream actually cost on the transport."""
    frames: int = 0
    wire_bytes: int = 0
    wall_seconds: float = 0.0
    held: set = field(default_factory=set)


class MigrationPeer:
    """Sender-side protocol driver bound to one remote environment.  The
    engine calls :meth:`send_state` (push), :meth:`fetch_state` (pull),
    :meth:`execute` (run a cell remotely) and :meth:`cancel` (abort an
    in-flight speculative stream)."""

    def __init__(self, transport: Transport, *, codec: str = "zlib",
                 handshake: bool = True):
        self.transport = transport
        self.codec = codec
        self._lock = threading.Lock()
        self._closed = False
        if handshake:
            transport.send(wire.hello_frame(codec))
            wire.parse_hello(_expect(transport.recv(), wire.HELLO))

    # -- push -----------------------------------------------------------
    def send_state(self, ser, *, deleted=(), modules=(),
                   speculative: bool = False, trickle: bool = False,
                   low_priority: bool = False) -> StreamStats:
        """One full state stream: MANIFEST, need-ack, CHUNKs, TOMBSTONE,
        END, done-ack.  Returns the held set (chunks the receiver did NOT
        request) plus real frame/byte/wall accounting.  ``trickle`` marks
        a background-replication stream (banked like a speculative one);
        ``low_priority`` puts every frame on the shaper's low lane so
        interactive traffic always preempts it."""
        tr = self.transport
        t0 = time.perf_counter()
        with self._lock:
            sent0, bytes0 = tr.frames_sent, tr.bytes_sent
            tr.send(wire.manifest_frame(ser, deleted=deleted, modules=modules,
                                        speculative=speculative,
                                        trickle=trickle),
                    low_priority=low_priority)
            ack = wire.parse_json(_expect(tr.recv(), wire.ACK))
            need = [int(d) for d in ack.get("need", [])]
            for f in wire.state_stream_frames(ser, need, deleted=deleted):
                tr.send(f, low_priority=low_priority)
            _expect(tr.recv(), wire.ACK)
            referenced = {d for b in ser.blobs.values()
                          for d in b.chunk_digests()}
            return StreamStats(
                frames=tr.frames_sent - sent0,
                wire_bytes=tr.bytes_sent - bytes0,
                wall_seconds=time.perf_counter() - t0,
                held=referenced - set(need))

    # -- pull -----------------------------------------------------------
    def fetch_state(self, *, names=None, cell_source: str | None = None,
                    known: dict[str, int] | None = None, strict: bool = True,
                    delta: bool = True, store=None):
        """Ask the remote side to send a state stream; chunks the local
        ``store`` already holds are not re-requested.  Returns
        (SerializedState, deleted, modules, StreamStats)."""
        tr = self.transport
        t0 = time.perf_counter()
        with self._lock:
            sent0, bytes0 = tr.frames_recv, tr.bytes_recv
            tr.send(wire.json_frame(wire.FETCH, {
                "names": sorted(names) if names is not None else None,
                "source": cell_source, "known": known or {},
                "strict": strict, "delta": delta}))
            ser, deleted, modules, _spec, _trickle = wire.parse_manifest(
                _expect(tr.recv(), wire.MANIFEST))
            referenced = {d for b in ser.blobs.values()
                          for d in b.chunk_digests()}
            need = sorted(d for d in referenced
                          if store is None or not store.has(d))
            tr.send(wire.json_frame(wire.ACK, {"need": need}))
            chunks: dict[int, bytes] = {}
            dead: tuple[str, ...] = deleted
            while True:
                f = tr.recv()
                if f.ftype == wire.CHUNK:
                    d, enc = wire.parse_chunk(f)
                    # chunks outlive the recv loop: own the bytes here so a
                    # small chunk view cannot pin a whole recv buffer
                    chunks[d] = enc if isinstance(enc, bytes) else bytes(enc)
                elif f.ftype == wire.TOMBSTONE:
                    dead = tuple(parse_list(f))
                elif f.ftype == wire.END:
                    break
                else:
                    _expect(f, wire.END)    # raises with a useful message
            tr.send(wire.json_frame(wire.ACK, {"applied": sorted(ser.blobs)}))
            ser.chunks = chunks
            stats = StreamStats(frames=tr.frames_recv - sent0,
                                wire_bytes=tr.bytes_recv - bytes0,
                                wall_seconds=time.perf_counter() - t0,
                                held=referenced - set(need))
            return ser, dead, modules, stats

    # -- exec rpc --------------------------------------------------------
    def execute(self, source: str) -> float:
        """Run ``source`` in the remote namespace; returns remote wall
        seconds.  Remote exceptions re-raise here as RuntimeError."""
        with self._lock:
            self.transport.send(wire.json_frame(wire.EXEC, {"source": source}))
            doc = wire.parse_json(_expect(self.transport.recv(), wire.RESULT))
        if "error" in doc:
            raise RuntimeError(f"remote execution failed: {doc['error']}")
        return float(doc["duration"])

    def cancel(self) -> None:
        """Send a CANCEL frame: the receiver drops any in-flight stream
        state.  With this peer's synchronous ``send_state`` the speculative
        stream has already fully landed by the time a stale claim cancels
        it, so CANCEL is a no-op safety net here — it exists for (and is
        exercised by) receivers whose sender died mid-stream, and for
        future transports that stream asynchronously."""
        with self._lock:
            self.transport.send(Frame(wire.CANCEL))

    # -- replica plane ---------------------------------------------------
    def replicate(self, session: str, epoch: int, ser, *,
                  deleted=()) -> StreamStats:
        """Ship a convergence delta: a REPLICA header (session, commit
        epoch, tombstones) followed by a normal non-speculative state
        stream the receiver *applies* — the remote watermark advances when
        the stream's END lands."""
        with self._lock:
            self.transport.send(wire.replica_frame(session, epoch,
                                                   deleted=deleted))
        return self.send_state(ser)

    def promote(self, session: str, epoch: int) -> int:
        """Failover handshake: returns the follower's own convergence
        watermark (authoritative — a stale promoter learns the residual)."""
        with self._lock:
            self.transport.send(wire.promote_frame(session, epoch))
            doc = wire.parse_json(_expect(self.transport.recv(), wire.RESULT))
        return int(doc.get("epoch", 0))

    def race(self, race_id: str, source: str) -> int:
        """Launch the losing-capable leg of a first-result-wins race; the
        remote side executes against a discarded overlay and replies with a
        RESULT tagged by the race id (or ``cancelled`` when the CANCEL got
        there first).  Returns the wire bytes the leg cost."""
        with self._lock:
            sent0 = self.transport.bytes_sent
            self.transport.send(wire.race_frame(race_id, "run", source))
            wire.parse_json(_expect(self.transport.recv(), wire.RESULT))
            return self.transport.bytes_sent - sent0

    def race_cancel(self, race_id: str) -> None:
        """The other leg won (or the race was aborted): a late ``run`` for
        this id must not execute on the remote side."""
        with self._lock:
            self.transport.send(wire.race_frame(race_id, "cancel"))

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            with self._lock:
                self.transport.send(Frame(wire.BYE))
        except WireError:
            pass
        self.transport.close()


# ----------------------------------------------------------------------
# serving an in-process environment (socket or loopback)
# ----------------------------------------------------------------------

class EnvServer:
    """Background thread running a :class:`WireReceiver` bound to an
    environment's chunk store + namespace.  Lets the engine drive the real
    frame protocol against an env living in this very process — the
    'socket' rows of ``bench_transport`` and the transport tests."""

    def __init__(self, env, reducer, transport: Transport):
        self.env = env
        self.receiver = WireReceiver(env.chunk_store, reducer,
                                     ns=env.state.ns)
        self.transport = transport
        self.error: Exception | None = None
        self.thread = threading.Thread(target=self._run, daemon=True,
                                       name=f"envserver-{env.name}")
        self.thread.start()

    def _run(self) -> None:
        try:
            self.error = serve_receiver(self.receiver, self.transport)
        finally:
            self.transport.close()

    def join(self, timeout: float = 5.0) -> None:
        self.thread.join(timeout)


def attach_peer(env, reducer, *, kind: str = "socket",
                shaper: TokenBucket | None = None) -> MigrationPeer:
    """Bind a live transport to ``env``: frames now genuinely carry its
    migration traffic (socket = real TCP through localhost; loopback =
    zero-copy queues).  Sets ``env.peer`` (the engine's hook) and
    ``env.transport``; returns the peer (close it to tear down)."""
    if kind == "socket":
        srv = socket.create_server(("127.0.0.1", 0))
        port = srv.getsockname()[1]
        client = socket.create_connection(("127.0.0.1", port), timeout=5.0)
        conn, _addr = srv.accept()
        srv.close()
        server_tr = SocketTransport(conn)
        client_tr = SocketTransport(client, shaper=shaper)
    elif kind == "loopback":
        client_tr, server_tr = LoopbackTransport.pair()
    else:
        raise ValueError(f"unknown transport kind {kind!r} "
                         f"(expected socket|loopback)")
    env._server = EnvServer(env, reducer, server_tr)
    peer = MigrationPeer(client_tr, codec=reducer.codec)
    env.peer = peer
    env.transport = kind
    return peer


# ----------------------------------------------------------------------
# stream multiplexing: N sessions on ONE socket
# ----------------------------------------------------------------------

class MuxStream(Transport):
    """One virtual frame pipe inside a :class:`MuxPeer`.  Implements the
    full Transport interface, so a :class:`MigrationPeer` (or anything
    else that talks frames) binds to it unchanged.

    Byte accounting counts the *inner* frame's wire size — exactly what
    the same traffic would cost on a dedicated connection — so per-stream
    counters are directly comparable to (and must equal) a one-socket-per-
    session deployment's.  The envelope overhead (9-byte STREAM header +
    CRC + 4-byte stream id per frame) lives on the underlying transport's
    counters, where the sharing actually happens."""

    kind = "mux"

    def __init__(self, peer: "MuxPeer", sid: int, *,
                 bucket: TokenBucket | None = None,
                 low_priority: bool = False):
        super().__init__()
        self.peer = peer
        self.sid = sid
        self.bucket = bucket          # per-stream flow control (optional)
        self.low_priority = low_priority
        self._closed = False

    def send(self, frame: Frame, *, low_priority: bool = False) -> int:
        if self._closed:
            raise WireError(f"send on closed mux stream {self.sid}")
        self.peer._send(self.sid, frame,
                        low_priority=low_priority or self.low_priority,
                        bucket=self.bucket)
        self.frames_sent += 1
        self.bytes_sent += frame.wire_size
        return frame.wire_size

    def recv(self, timeout: float | None = _RECV_TIMEOUT) -> Frame:
        frame = self.peer._recv(self.sid, timeout)
        self.frames_recv += 1
        self.bytes_recv += frame.wire_size
        return frame

    def poll(self) -> Frame | None:
        frame = self.peer._poll(self.sid)
        if frame is not None:
            self.frames_recv += 1
            self.bytes_recv += frame.wire_size
        return frame

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.peer._close_stream(self.sid)


class MuxPeer:
    """Stream-id multiplexing over one underlying transport: each frame
    rides a STREAM envelope (u32 stream id + the complete inner frame),
    and any number of :class:`MuxStream` handles share the connection.

    * **send** is serialized on one lock; a per-stream
      :class:`TokenBucket` (``open_stream(rate=...)``) shapes that
      stream's bytes *before* the lock so one throttled stream never
      blocks the others, and ``low_priority`` streams ride the underlying
      shaper's trickle lane.
    * **recv** is demultiplexed cooperatively: whichever thread needs a
      frame pumps the shared connection (one pumper at a time) and routes
      inbound frames to per-stream inboxes; everyone else waits on their
      inbox.  Streams the remote side opened first surface through
      :meth:`accept_stream`.

    The two ends split the stream-id space odd/even (``initiator=True``
    allocates odd ids) so both may open streams without collision."""

    def __init__(self, transport: Transport, *, initiator: bool = True):
        self.transport = transport
        self._send_lock = threading.Lock()
        self._pump_lock = threading.Lock()
        self._state_lock = threading.Lock()
        self._inboxes: dict[int, queue.Queue] = {}
        self._accept_q: "queue.Queue[int]" = queue.Queue()
        self._next_sid = 1 if initiator else 2

    # -- stream lifecycle ------------------------------------------------
    def open_stream(self, *, rate: float | None = None, burst: int = 1 << 16,
                    low_priority: bool = False,
                    clock=time.monotonic) -> MuxStream:
        with self._state_lock:
            sid = self._next_sid
            self._next_sid += 2
            self._inboxes.setdefault(sid, queue.Queue())
        bucket = (TokenBucket(rate, burst=burst, clock=clock)
                  if rate else None)
        return MuxStream(self, sid, bucket=bucket, low_priority=low_priority)

    def accept_stream(self, timeout: float | None = _RECV_TIMEOUT,
                      **stream_kw) -> MuxStream:
        """A stream the remote end opened: surfaces when its first frame
        arrives (there is no explicit open handshake — the id is the
        stream)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            try:
                sid = self._accept_q.get_nowait()
                return MuxStream(self, sid, **stream_kw)
            except queue.Empty:
                pass
            self._pump(deadline, lambda: not self._accept_q.empty())

    def _close_stream(self, sid: int) -> None:
        with self._state_lock:
            self._inboxes.pop(sid, None)

    def close(self) -> None:
        self.transport.close()

    # -- send ------------------------------------------------------------
    def _send(self, sid: int, frame: Frame, *, low_priority: bool,
              bucket: TokenBucket | None) -> None:
        if bucket is not None:
            # per-stream shaping happens OUTSIDE the shared send lock: a
            # throttled stream sleeps on its own time, not the socket's
            wait = bucket.delay(frame.wire_size, low_priority=low_priority)
            if wait > 0:
                time.sleep(wait)
        env = wire.stream_frame(sid, frame)
        with self._send_lock:
            self.transport.send(env, low_priority=low_priority)

    # -- recv ------------------------------------------------------------
    def _route(self, frame: Frame) -> None:
        sid, inner = wire.parse_stream(frame)
        with self._state_lock:
            box = self._inboxes.get(sid)
            if box is None:
                box = self._inboxes[sid] = queue.Queue()
                self._accept_q.put(sid)
        box.put(inner)

    def _pump(self, deadline: float | None, done) -> None:
        """Pump the shared connection until ``done()`` or the deadline.
        Only one thread pumps at a time; the rest sleep briefly on their
        own inboxes (frames reach them as the pumper routes)."""
        remaining = (None if deadline is None
                     else max(0.0, deadline - time.monotonic()))
        if self._pump_lock.acquire(timeout=min(remaining, 0.05)
                                   if remaining is not None else 0.05):
            try:
                if done():
                    return
                self._route(self.transport.recv(timeout=remaining))
            finally:
                self._pump_lock.release()
        elif deadline is not None and time.monotonic() >= deadline:
            raise WireError("mux recv timed out waiting for the pump")
        else:
            time.sleep(0.001)

    def _recv(self, sid: int, timeout: float | None) -> Frame:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._state_lock:
                box = self._inboxes.get(sid)
            if box is None:
                raise WireError(f"recv on closed mux stream {sid}")
            try:
                return box.get_nowait()
            except queue.Empty:
                pass
            if deadline is not None and time.monotonic() >= deadline:
                raise WireError(f"mux recv timed out on stream {sid}")
            self._pump(deadline, lambda: not box.empty())

    def _poll(self, sid: int) -> Frame | None:
        with self._state_lock:
            box = self._inboxes.get(sid)
        if box is None:
            raise WireError(f"poll on closed mux stream {sid}")
        try:
            return box.get_nowait()
        except queue.Empty:
            pass
        # drain whatever the underlying transport has ready, then retry
        if self._pump_lock.acquire(blocking=False):
            try:
                while True:
                    f = self.transport.poll()
                    if f is None:
                        break
                    self._route(f)
            finally:
                self._pump_lock.release()
        try:
            return box.get_nowait()
        except queue.Empty:
            return None


class MuxEnvServer:
    """The server half of a multiplexed connection: ONE thread, one
    socket, N receiver state machines — versus :class:`EnvServer`'s
    thread-per-connection.  ``make_receiver(sid)`` builds the
    :class:`WireReceiver` for a stream the first time a frame arrives on
    it; replies ride the same stream.  A BYE inside a stream retires that
    stream's receiver; closing the underlying transport (or an envelope-
    level WireError) ends the whole connection."""

    def __init__(self, transport: Transport, make_receiver,
                 timeout: float | None = _RECV_TIMEOUT,
                 persistent: bool = False):
        self.transport = transport
        self.make_receiver = make_receiver
        self.timeout = timeout
        self.persistent = persistent      # keep serving after the last BYE
        self.error: Exception | None = None
        self.streams_served = 0
        self._receivers: dict[int, WireReceiver] = {}
        self._streams: dict[int, MuxStream] = {}
        self._mux = MuxPeer(transport, initiator=False)
        self.thread = threading.Thread(target=self._run, daemon=True,
                                       name="mux-envserver")
        self.thread.start()

    def _stream(self, sid: int) -> MuxStream:
        if sid not in self._streams:
            self._streams[sid] = MuxStream(self._mux, sid)
        return self._streams[sid]

    def _run(self) -> None:
        try:
            while True:
                frame = self.transport.recv(timeout=self.timeout)
                sid, inner = wire.parse_stream(frame)
                if sid not in self._receivers:
                    self._receivers[sid] = self.make_receiver(sid)
                    self.streams_served += 1
                stream = self._stream(sid)
                try:
                    if not self._receivers[sid].handle(inner, stream):
                        del self._receivers[sid]      # stream-level BYE
                        if not self._receivers and not self.persistent:
                            return
                except WireError:
                    raise
                except Exception as e:  # noqa: BLE001 — back as ERROR
                    rcv = self._receivers[sid]
                    rcv._pending = None
                    rcv._pending_chunks = {}
                    stream.send(wire.json_frame(wire.ERROR, {
                        "error": f"{type(e).__name__}: {e}",
                        "kind": "receiver"}))
        except WireError as e:
            self.error = e
        finally:
            self.transport.close()

    def join(self, timeout: float = 5.0) -> None:
        self.thread.join(timeout)


# ----------------------------------------------------------------------
# subprocess environment
# ----------------------------------------------------------------------

class DigestMirrorStore:
    """Parent-side view of a remote store: records *which* digests were
    delivered (so manifest exchange and prefetch banking work) without
    keeping a second copy of the bytes."""

    def __init__(self):
        self._digests: set[int] = set()

    def has(self, d: int) -> bool:
        return d in self._digests

    def put(self, d: int, data: bytes = b"") -> None:
        self._digests.add(d)

    def put_many(self, chunks) -> None:
        self._digests.update(chunks)

    def get(self, d: int) -> bytes:
        raise KeyError(f"digest mirror holds no chunk bytes ({d:016x} "
                       f"lives in the remote store)")

    def digests(self) -> set[int]:
        return set(self._digests)

    def __len__(self) -> int:
        return len(self._digests)

    @property
    def nbytes(self) -> int:
        return 0


class SubprocessEnv:
    """A real receiver ExecutionEnvironment in a child Python process.

    The child (``python -m repro.core.remote_worker``) owns the namespace
    and a real chunk store; this handle implements the environment API the
    engine and runtime expect (``execute``, ``state``, ``chunk_store``,
    lifecycle attrs) while every state movement rides SocketTransport
    frames.  ``state`` here is an empty mirror — the truth lives remotely,
    which is exactly what forces the protocol to be honest."""

    kind = "compute"

    def __init__(self, name: str, *, speedup: float = 1.0,
                 codec: str = "zlib", python: str | None = None,
                 shaper: TokenBucket | None = None,
                 spawn_timeout: float = 120.0):
        self.name = name
        self.speedup = float(speedup)
        self.mesh_ctx = None
        self.storage_dir = None
        self.status = "up"
        self.cold_start = 0.0
        self.idle_timeout = None
        self.ready_at = 0.0
        self.transport = "subprocess"
        self.chunk_store = DigestMirrorStore()
        self.state = ExecutionState({})
        srv = socket.create_server(("127.0.0.1", 0))
        srv.settimeout(spawn_timeout)
        port = srv.getsockname()[1]
        pkg_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env = dict(os.environ)
        env["PYTHONPATH"] = pkg_root + os.pathsep + env.get("PYTHONPATH", "")
        self.proc = subprocess.Popen(
            [python or sys.executable, "-m", "repro.core.remote_worker",
             "--connect", f"127.0.0.1:{port}", "--codec", codec],
            env=env, stdout=subprocess.DEVNULL)
        try:
            conn, _addr = srv.accept()
        except socket.timeout:
            self.proc.kill()
            raise WireError(
                f"subprocess env {name!r} did not connect back within "
                f"{spawn_timeout}s") from None
        finally:
            srv.close()
        conn.settimeout(None)
        self.peer = MigrationPeer(SocketTransport(conn, shaper=shaper),
                                  codec=codec)

    # -- environment API -------------------------------------------------
    def set_status(self, status: str, *, now: float = 0.0) -> str:
        old, self.status = self.status, status
        return old

    def placeable_now(self) -> bool:
        return self.status in ("up", "provisioning")

    def execute(self, source: str, cost: float | None = None) -> float:
        wall = self.peer.execute(source)
        base = cost if cost is not None else wall
        return base / self.speedup

    def close(self) -> None:
        if self.proc.poll() is None:
            self.peer.close()
            try:
                self.proc.wait(timeout=10.0)
            except subprocess.TimeoutExpired:  # pragma: no cover
                self.proc.kill()
                self.proc.wait()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"SubprocessEnv({self.name!r}, pid={self.proc.pid})"
