"""Versioned wire format for migration traffic (the transport plane's
bottom half).

Every byte that crosses a real link rides a length-prefixed, CRC-checked
**frame**:

    frame   := u32_le payload_len | u8 type | payload | u32_le crc
    crc     := crc32(type_byte + payload)

A migration is one **state stream** — the same grammar in both directions
(push via ``MANIFEST``-first, pull via ``FETCH``-first):

    session      := HELLO  (both directions, once per connection)
    state-stream := MANIFEST ack(need) CHUNK* [TOMBSTONE] END ack(done)
    exec-rpc     := EXEC RESULT
    pull         := FETCH state-stream     (remote is the sender)
    abort        := CANCEL                 (drop the in-flight stream)

``MANIFEST`` carries the chunk manifest (names, per-name content digests,
array metadata, chunk digest lists, pickle streams) as canonical JSON, so a
byte-for-byte golden vector pins the format.  ``CHUNK`` payloads are the
*store encoding* — 8-byte digest + 1-byte codec tag + compressed body — so a
received chunk frame lands in a :class:`~repro.core.chunkstore.MemoryChunkStore`
verbatim.  ``TOMBSTONE`` propagates deletions.  ``ACK`` closes each half of
the exchange (the receiver advertises which chunks it needs, then confirms
the applied names).

Corruption of any kind — truncation, bit flips in header or payload, an
unknown frame type, an absurd length — surfaces as :class:`WireError`,
never a crash or a silently wrong namespace.
"""
from __future__ import annotations

import base64
import json
import struct
import zlib
from typing import Iterable, Iterator

MAGIC = b"RWIR"
VERSION = 1

# frame types ----------------------------------------------------------
HELLO = 1        # session header: magic + version + codec + flags
MANIFEST = 2     # chunk manifest for one state stream (canonical JSON)
CHUNK = 3        # u64 digest + store-encoded chunk (codec tag + body)
ACK = 4          # JSON: {"need": [...]} after MANIFEST, {"applied": [...]} after END
TOMBSTONE = 5    # JSON: ["name", ...] deleted on the sender
END = 6          # state stream complete
CANCEL = 7       # abort the in-flight state stream (speculation went stale)
ERROR = 8        # JSON: {"error": str, "kind": str} — remote failure
EXEC = 9         # JSON: {"source": str, "cost": float|null}
RESULT = 10      # JSON: {"duration": float} or {"error": str}
FETCH = 11       # JSON: pull request — the remote becomes the sender
BYE = 12         # close the session

FRAME_TYPES = frozenset((HELLO, MANIFEST, CHUNK, ACK, TOMBSTONE, END,
                         CANCEL, ERROR, EXEC, RESULT, FETCH, BYE))
TYPE_NAMES = {HELLO: "HELLO", MANIFEST: "MANIFEST", CHUNK: "CHUNK",
              ACK: "ACK", TOMBSTONE: "TOMBSTONE", END: "END",
              CANCEL: "CANCEL", ERROR: "ERROR", EXEC: "EXEC",
              RESULT: "RESULT", FETCH: "FETCH", BYE: "BYE"}

_HEADER = struct.Struct("<IB")        # payload_len, frame_type
_CRC = struct.Struct("<I")
FRAME_OVERHEAD = _HEADER.size + _CRC.size          # 9 bytes per frame

# no legitimate frame approaches this: chunks are <= 256 KiB + codec
# overhead, manifests are metadata.  A corrupted length prefix must fail
# fast instead of asking for gigabytes.
MAX_PAYLOAD = 64 << 20


class WireError(Exception):
    """Malformed or corrupted wire traffic (bad CRC, truncation, unknown
    frame type, oversized length, invalid HELLO, undecodable payload)."""


class Frame:
    """One decoded frame.  ``wire_size`` is what it costs on a real link;
    loopback transports pass Frame objects without ever encoding them."""

    __slots__ = ("ftype", "payload")

    def __init__(self, ftype: int, payload: bytes = b""):
        self.ftype = ftype
        self.payload = payload

    @property
    def wire_size(self) -> int:
        return FRAME_OVERHEAD + len(self.payload)

    def encoded(self) -> bytes:
        return encode_frame(self.ftype, self.payload)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Frame({TYPE_NAMES.get(self.ftype, self.ftype)}, "
                f"{len(self.payload)}B)")

    def __eq__(self, other) -> bool:
        return (isinstance(other, Frame) and other.ftype == self.ftype
                and other.payload == self.payload)


def encode_frame(ftype: int, payload: bytes) -> bytes:
    crc = zlib.crc32(payload, zlib.crc32(bytes((ftype,))))
    return _HEADER.pack(len(payload), ftype) + payload + _CRC.pack(crc)


class FrameDecoder:
    """Incremental frame decoder: feed bytes as they arrive off a socket,
    iterate complete frames.  Every integrity violation is a WireError."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> None:
        self._buf.extend(data)

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)

    def frames(self) -> Iterator[Frame]:
        while True:
            f = self._next()
            if f is None:
                return
            yield f

    def _next(self) -> Frame | None:
        buf = self._buf
        if len(buf) < _HEADER.size:
            return None
        plen, ftype = _HEADER.unpack_from(buf)
        if plen > MAX_PAYLOAD:
            raise WireError(f"frame length {plen} exceeds MAX_PAYLOAD "
                            f"({MAX_PAYLOAD}) — corrupted length prefix?")
        if ftype not in FRAME_TYPES:
            raise WireError(f"unknown frame type {ftype}")
        total = _HEADER.size + plen + _CRC.size
        if len(buf) < total:
            return None
        payload = bytes(buf[_HEADER.size:_HEADER.size + plen])
        (crc,) = _CRC.unpack_from(buf, _HEADER.size + plen)
        want = zlib.crc32(payload, zlib.crc32(bytes((ftype,))))
        if crc != want:
            raise WireError(
                f"CRC mismatch on {TYPE_NAMES[ftype]} frame "
                f"(got {crc:#010x}, want {want:#010x})")
        del buf[:total]
        return Frame(ftype, payload)


def decode_frames(data: bytes) -> list[Frame]:
    """Decode a complete frame stream; trailing partial bytes are a
    WireError (a *stream* must end on a frame boundary)."""
    dec = FrameDecoder()
    dec.feed(data)
    out = list(dec.frames())
    if dec.pending_bytes:
        raise WireError(f"{dec.pending_bytes} trailing bytes after the last "
                        f"complete frame (truncated stream?)")
    return out


# ----------------------------------------------------------------------
# HELLO
# ----------------------------------------------------------------------

_HELLO = struct.Struct("<4sHBB")     # magic, version, codec_id, flags


def hello_frame(codec: str = "zlib", flags: int = 0) -> Frame:
    from repro.core.chunkstore import _CODEC_IDS
    cid = _CODEC_IDS.get(codec if codec in _CODEC_IDS else "zlib", 1)
    return Frame(HELLO, _HELLO.pack(MAGIC, VERSION, cid, flags))


def parse_hello(frame: Frame) -> dict:
    from repro.core.chunkstore import _CODEC_NAMES
    if frame.ftype != HELLO:
        raise WireError(f"expected HELLO, got {TYPE_NAMES.get(frame.ftype)}")
    try:
        magic, version, cid, flags = _HELLO.unpack(frame.payload)
    except struct.error as e:
        raise WireError(f"malformed HELLO payload: {e}") from None
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r} (want {MAGIC!r})")
    if version != VERSION:
        raise WireError(f"unsupported wire version {version} "
                        f"(this side speaks {VERSION})")
    return {"version": version, "codec": _CODEC_NAMES.get(cid, "zlib"),
            "flags": flags}


# ----------------------------------------------------------------------
# JSON control payloads (canonical: sorted keys, compact separators)
# ----------------------------------------------------------------------

def json_frame(ftype: int, obj) -> Frame:
    return Frame(ftype, json.dumps(
        obj, sort_keys=True, separators=(",", ":")).encode())


def parse_json(frame: Frame):
    try:
        return json.loads(frame.payload.decode())
    except (ValueError, UnicodeDecodeError) as e:
        raise WireError(
            f"undecodable {TYPE_NAMES.get(frame.ftype, frame.ftype)} "
            f"payload: {e}") from None


# ----------------------------------------------------------------------
# MANIFEST <-> SerializedState
# ----------------------------------------------------------------------

def _b64(data: bytes) -> str:
    return base64.b64encode(data).decode("ascii")


def _unb64(s: str) -> bytes:
    try:
        return base64.b64decode(s.encode("ascii"), validate=True)
    except Exception as e:  # noqa: BLE001 — any b64 failure is wire corruption
        raise WireError(f"bad base64 in manifest: {e}") from None


def manifest_frame(ser, *, deleted: Iterable[str] = (),
                   modules: Iterable[str] = (),
                   speculative: bool = False) -> Frame:
    """SerializedState (sans chunk payloads) -> canonical-JSON MANIFEST.
    Chunk *digests* travel here; chunk *bytes* follow in CHUNK frames."""
    blobs = {}
    for name, blob in ser.blobs.items():
        arrays = []
        for a in blob.arrays:
            meta = {"shape": list(a["shape"]), "dtype": a["dtype"],
                    "quant": bool(a["quant"]), "chunks": list(a["chunks"]),
                    "clens": list(a["clens"])}
            if a["quant"]:
                meta["block"] = int(a["block"])
                meta["scales"] = _b64(a["scales"])
            arrays.append(meta)
        blobs[name] = {"pickle": _b64(blob.pickle_bytes), "arrays": arrays}
    return json_frame(MANIFEST, {
        "codec": ser.codec, "blobs": blobs, "digests": dict(ser.digests),
        "deleted": sorted(deleted), "modules": sorted(modules),
        "skipped": sorted(ser.skipped), "speculative": bool(speculative)})


def parse_manifest(frame: Frame):
    """MANIFEST frame -> (SerializedState without chunk payloads, deleted
    names, module names, speculative flag).  Chunks arrive separately and
    are attached by the receiver."""
    from repro.core.reducer import SerializedName, SerializedState
    if frame.ftype != MANIFEST:
        raise WireError(
            f"expected MANIFEST, got {TYPE_NAMES.get(frame.ftype)}")
    doc = parse_json(frame)
    try:
        blobs = {}
        for name, b in doc["blobs"].items():
            arrays = []
            for a in b["arrays"]:
                meta = {"shape": tuple(a["shape"]), "dtype": a["dtype"],
                        "quant": bool(a["quant"]),
                        "chunks": [int(d) for d in a["chunks"]],
                        "clens": [int(c) for c in a["clens"]]}
                if meta["quant"]:
                    meta["block"] = int(a["block"])
                    meta["scales"] = _unb64(a["scales"])
                arrays.append(meta)
            blobs[name] = SerializedName(pickle_bytes=_unb64(b["pickle"]),
                                         arrays=arrays)
        ser = SerializedState(codec=doc["codec"], blobs=blobs)
        ser.digests = {n: int(d) for n, d in doc["digests"].items()}
        ser.skipped = tuple(doc.get("skipped", ()))
        deleted = tuple(doc.get("deleted", ()))
        modules = tuple(doc.get("modules", ()))
        return ser, deleted, modules, bool(doc.get("speculative", False))
    except WireError:
        raise
    except (KeyError, TypeError, ValueError, AttributeError) as e:
        raise WireError(f"malformed manifest: {e!r}") from None


# ----------------------------------------------------------------------
# CHUNK
# ----------------------------------------------------------------------

_DIGEST = struct.Struct("<Q")


def chunk_frame(digest: int, encoded: bytes) -> Frame:
    """``encoded`` is the store encoding (1-byte codec tag + body)."""
    return Frame(CHUNK, _DIGEST.pack(digest & (2**64 - 1)) + encoded)


def parse_chunk(frame: Frame) -> tuple[int, bytes]:
    if frame.ftype != CHUNK:
        raise WireError(f"expected CHUNK, got {TYPE_NAMES.get(frame.ftype)}")
    if len(frame.payload) < _DIGEST.size + 1:
        raise WireError("CHUNK payload too short for digest + codec tag")
    (digest,) = _DIGEST.unpack_from(frame.payload)
    return digest, frame.payload[_DIGEST.size:]


def state_stream_frames(ser, need: Iterable[int], *,
                        deleted: Iterable[str] = ()) -> Iterator[Frame]:
    """The sender's half of a state stream *after* the need-ack: CHUNK
    frames for the requested digests, TOMBSTONE, END.  (The MANIFEST went
    out first to elicit the ack.)"""
    for d in need:
        if d in ser.chunks:
            yield chunk_frame(d, ser.chunks[d])
    deleted = sorted(deleted)
    if deleted:
        yield json_frame(TOMBSTONE, deleted)
    yield Frame(END)
