"""Versioned wire format for migration traffic (the transport plane's
bottom half).

Every byte that crosses a real link rides a length-prefixed, CRC-checked
**frame**:

    frame   := u32_le payload_len | u8 type | payload | u32_le crc
    crc     := crc32(type_byte + payload)

A migration is one **state stream** — the same grammar in both directions
(push via ``MANIFEST``-first, pull via ``FETCH``-first):

    session      := HELLO  (both directions, once per connection)
    state-stream := MANIFEST ack(need) CHUNK* [TOMBSTONE] END ack(done)
    exec-rpc     := EXEC RESULT
    pull         := FETCH state-stream     (remote is the sender)
    abort        := CANCEL                 (drop the in-flight stream)

``MANIFEST`` carries the chunk manifest (names, per-name content digests,
array metadata, chunk digest lists, pickle streams) as canonical JSON, so a
byte-for-byte golden vector pins the format.  ``CHUNK`` payloads are the
*store encoding* — 8-byte digest + 1-byte codec tag + compressed body — so a
received chunk frame lands in a :class:`~repro.core.chunkstore.MemoryChunkStore`
verbatim.  ``TOMBSTONE`` propagates deletions.  ``ACK`` closes each half of
the exchange (the receiver advertises which chunks it needs, then confirms
the applied names).

Corruption of any kind — truncation, bit flips in header or payload, an
unknown frame type, an absurd length — surfaces as :class:`WireError`,
never a crash or a silently wrong namespace.

**Zero-copy framing**: frames are scatter-gather.  A :class:`Frame` holds
its payload as one or more buffer *parts* (``payload_parts``) and encodes
to wire segments — header, payload part(s), CRC — without ever joining
them into one ``bytes`` (:meth:`Frame.segments`; the CRC runs zlib's
streaming path over each part).  On the way in, :class:`FrameDecoder`
keeps the fed buffers as a segment queue and yields payloads as
``memoryview`` slices of them — a CHUNK payload that arrived in one
``recv`` is never copied; only the consumer that genuinely needs owned
``bytes`` (e.g. a chunk store) materializes it.
"""
from __future__ import annotations

import base64
import json
import struct
import zlib
from collections import deque
from typing import Iterable, Iterator

MAGIC = b"RWIR"
VERSION = 1

# frame types ----------------------------------------------------------
HELLO = 1        # session header: magic + version + codec + flags
MANIFEST = 2     # chunk manifest for one state stream (canonical JSON)
CHUNK = 3        # u64 digest + store-encoded chunk (codec tag + body)
ACK = 4          # JSON: {"need": [...]} after MANIFEST, {"applied": [...]} after END
TOMBSTONE = 5    # JSON: ["name", ...] deleted on the sender
END = 6          # state stream complete
CANCEL = 7       # abort the in-flight state stream (speculation went stale)
ERROR = 8        # JSON: {"error": str, "kind": str} — remote failure
EXEC = 9         # JSON: {"source": str, "cost": float|null}
RESULT = 10      # JSON: {"duration": float} or {"error": str}
FETCH = 11       # JSON: pull request — the remote becomes the sender
BYE = 12         # close the session
ATTACH = 13      # JSON: gateway session attach request (tenant, notebook)
DETACH = 14      # JSON: {"session": str, "reason": str}
STREAM = 15      # mux envelope: u32_le stream_id + one complete inner frame
REPLICA = 16     # JSON: replica-plane delta header (session, epoch, deleted)
PROMOTE = 17     # JSON: {"session": str, "epoch": int} — failover handshake
RACE = 18        # JSON: {"id": str, "action": "run"|"cancel", "source": str}

FRAME_TYPES = frozenset((HELLO, MANIFEST, CHUNK, ACK, TOMBSTONE, END,
                         CANCEL, ERROR, EXEC, RESULT, FETCH, BYE,
                         ATTACH, DETACH, STREAM, REPLICA, PROMOTE, RACE))
TYPE_NAMES = {HELLO: "HELLO", MANIFEST: "MANIFEST", CHUNK: "CHUNK",
              ACK: "ACK", TOMBSTONE: "TOMBSTONE", END: "END",
              CANCEL: "CANCEL", ERROR: "ERROR", EXEC: "EXEC",
              RESULT: "RESULT", FETCH: "FETCH", BYE: "BYE",
              ATTACH: "ATTACH", DETACH: "DETACH", STREAM: "STREAM",
              REPLICA: "REPLICA", PROMOTE: "PROMOTE", RACE: "RACE"}

_HEADER = struct.Struct("<IB")        # payload_len, frame_type
_CRC = struct.Struct("<I")
FRAME_OVERHEAD = _HEADER.size + _CRC.size          # 9 bytes per frame

# no legitimate frame approaches this: chunks are <= 256 KiB + codec
# overhead, manifests are metadata.  A corrupted length prefix must fail
# fast instead of asking for gigabytes.
MAX_PAYLOAD = 64 << 20


class WireError(Exception):
    """Malformed or corrupted wire traffic (bad CRC, truncation, unknown
    frame type, oversized length, invalid HELLO, undecodable payload)."""


class Frame:
    """One decoded frame.  ``wire_size`` is what it costs on a real link;
    loopback transports pass Frame objects without ever encoding them.

    The payload is held as a tuple of buffer *parts* (bytes or memoryview)
    so senders can frame large chunks without concatenating them; the
    :attr:`payload` property presents them as one buffer (joining lazily —
    and only when more than one part exists)."""

    __slots__ = ("ftype", "_parts", "_joined")

    def __init__(self, ftype: int, payload=b""):
        self.ftype = ftype
        self._parts = payload if isinstance(payload, tuple) else (payload,)
        self._joined = None

    @property
    def payload(self):
        """The payload as a single bytes-like buffer (bytes or memoryview)."""
        if len(self._parts) == 1:
            return self._parts[0]
        if self._joined is None:
            self._joined = b"".join(bytes(p) for p in self._parts)
        return self._joined

    @property
    def payload_parts(self) -> tuple:
        return self._parts

    @property
    def payload_len(self) -> int:
        return sum(len(p) for p in self._parts)

    @property
    def wire_size(self) -> int:
        return FRAME_OVERHEAD + self.payload_len

    def segments(self) -> list:
        """Scatter-gather wire encoding: ``[header, *payload_parts, crc]``
        — no payload bytes are copied; the CRC streams over each part."""
        crc = zlib.crc32(bytes((self.ftype,)))
        for p in self._parts:
            crc = zlib.crc32(p, crc)
        return [_HEADER.pack(self.payload_len, self.ftype),
                *self._parts, _CRC.pack(crc)]

    def encoded(self) -> bytes:
        return b"".join(bytes(s) for s in self.segments())

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Frame({TYPE_NAMES.get(self.ftype, self.ftype)}, "
                f"{self.payload_len}B)")

    def __eq__(self, other) -> bool:
        return (isinstance(other, Frame) and other.ftype == self.ftype
                and self.payload == other.payload)


def encode_frame(ftype: int, payload: bytes) -> bytes:
    crc = zlib.crc32(payload, zlib.crc32(bytes((ftype,))))
    return _HEADER.pack(len(payload), ftype) + payload + _CRC.pack(crc)


class FrameDecoder:
    """Incremental frame decoder: feed buffers as they arrive off a socket,
    iterate complete frames.  Every integrity violation is a WireError.

    Fed buffers are *kept*, not copied, in a segment queue; a decoded
    frame's payload is a ``memoryview`` slice into the fed buffer whenever
    the payload arrived within one ``feed`` (always true for
    :func:`decode_frames` and for loopback streams) — only payloads that
    straddle a feed boundary are joined.  Feed ``bytes`` for the zero-copy
    path; mutable buffers (``bytearray``) are defensively copied because
    the caller could mutate them under a live payload view.

    Long-lived connections (a persistent gateway socket) must not pin
    every buffer they ever received: fully-consumed segments are dropped
    as frames decode, and when the consumed prefix of the head segment
    comes to dominate it the remainder is compacted into a fresh buffer
    (amortized O(1) per byte), so :attr:`retained_bytes` stays
    O(unconsumed) instead of O(connection lifetime)."""

    # a consumed prefix below this is not worth a compaction copy; above
    # it, compact once consumed >= remaining (each copy moves fewer bytes
    # than were consumed since the last one — amortized O(1)/byte)
    _COMPACT_MIN = 4096

    def __init__(self):
        self._segs: deque = deque()       # unconsumed buffers (memoryview)
        self._off = 0                     # consumed prefix of _segs[0]
        self._size = 0                    # unconsumed bytes across _segs

    def feed(self, data) -> None:
        if not len(data):
            return
        if isinstance(data, (bytearray, memoryview)):
            data = bytes(data)
        self._segs.append(memoryview(data))
        self._size += len(data)

    @property
    def pending_bytes(self) -> int:
        return self._size

    @property
    def retained_bytes(self) -> int:
        """Bytes of *underlying* buffers the decoder keeps alive — the
        connection's true memory footprint, consumed prefixes included
        (a memoryview pins its whole backing buffer)."""
        total = 0
        for seg in self._segs:
            obj = seg.obj
            total += len(obj) if obj is not None else len(seg)
        return total

    def _maybe_compact(self) -> None:
        """Re-home the head segment's unconsumed tail when its consumed
        prefix dominates, releasing the original backing buffer."""
        if self._off < self._COMPACT_MIN or not self._segs:
            return
        head = self._segs[0]
        if self._off * 2 >= len(head):
            self._segs[0] = memoryview(bytes(head[self._off:]))
            self._off = 0

    def frames(self) -> Iterator[Frame]:
        while True:
            f = self._next()
            if f is None:
                return
            yield f

    # -- segment-queue primitives ---------------------------------------
    def _peek(self, n: int) -> bytes:
        """First ``n`` unconsumed bytes without consuming (n is tiny —
        header-sized — so the copy is a few bytes)."""
        head = self._segs[0]
        if len(head) - self._off >= n:
            return bytes(head[self._off:self._off + n])
        out = bytearray()
        off = self._off
        for seg in self._segs:
            out += seg[off:off + (n - len(out))]
            off = 0
            if len(out) >= n:
                break
        return bytes(out)

    def _take(self, n: int) -> memoryview:
        """Consume ``n`` bytes.  Returns a zero-copy view when they lie in
        one segment; joins into a fresh buffer only across a boundary."""
        self._size -= n
        head = self._segs[0]
        if len(head) - self._off >= n:
            out = head[self._off:self._off + n]
            self._off += n
            if self._off == len(head):
                self._segs.popleft()
                self._off = 0
            return out
        parts = bytearray()
        while n:
            head = self._segs[0]
            take = min(len(head) - self._off, n)
            parts += head[self._off:self._off + take]
            self._off += take
            n -= take
            if self._off == len(head):
                self._segs.popleft()
                self._off = 0
        return memoryview(bytes(parts))

    def _next(self) -> Frame | None:
        if self._size < _HEADER.size:
            return None
        plen, ftype = _HEADER.unpack(self._peek(_HEADER.size))
        if plen > MAX_PAYLOAD:
            raise WireError(f"frame length {plen} exceeds MAX_PAYLOAD "
                            f"({MAX_PAYLOAD}) — corrupted length prefix?")
        if ftype not in FRAME_TYPES:
            raise WireError(f"unknown frame type {ftype}")
        if self._size < _HEADER.size + plen + _CRC.size:
            return None
        self._take(_HEADER.size)
        payload = self._take(plen)
        (crc,) = _CRC.unpack(self._take(_CRC.size))
        want = zlib.crc32(payload, zlib.crc32(bytes((ftype,))))
        if crc != want:
            raise WireError(
                f"CRC mismatch on {TYPE_NAMES[ftype]} frame "
                f"(got {crc:#010x}, want {want:#010x})")
        self._maybe_compact()
        return Frame(ftype, payload)


def decode_frames(data: bytes) -> list[Frame]:
    """Decode a complete frame stream; trailing partial bytes are a
    WireError (a *stream* must end on a frame boundary)."""
    dec = FrameDecoder()
    dec.feed(data)
    out = list(dec.frames())
    if dec.pending_bytes:
        raise WireError(f"{dec.pending_bytes} trailing bytes after the last "
                        f"complete frame (truncated stream?)")
    return out


# ----------------------------------------------------------------------
# HELLO
# ----------------------------------------------------------------------

_HELLO = struct.Struct("<4sHBB")     # magic, version, codec_id, flags


def hello_frame(codec: str = "zlib", flags: int = 0) -> Frame:
    from repro.core.chunkstore import _CODEC_IDS
    cid = _CODEC_IDS.get(codec if codec in _CODEC_IDS else "zlib", 1)
    return Frame(HELLO, _HELLO.pack(MAGIC, VERSION, cid, flags))


def parse_hello(frame: Frame) -> dict:
    from repro.core.chunkstore import _CODEC_NAMES
    if frame.ftype != HELLO:
        raise WireError(f"expected HELLO, got {TYPE_NAMES.get(frame.ftype)}")
    try:
        magic, version, cid, flags = _HELLO.unpack(frame.payload)
    except struct.error as e:
        raise WireError(f"malformed HELLO payload: {e}") from None
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r} (want {MAGIC!r})")
    if version != VERSION:
        raise WireError(f"unsupported wire version {version} "
                        f"(this side speaks {VERSION})")
    return {"version": version, "codec": _CODEC_NAMES.get(cid, "zlib"),
            "flags": flags}


# ----------------------------------------------------------------------
# JSON control payloads (canonical: sorted keys, compact separators)
# ----------------------------------------------------------------------

def json_frame(ftype: int, obj) -> Frame:
    return Frame(ftype, json.dumps(
        obj, sort_keys=True, separators=(",", ":")).encode())


def parse_json(frame: Frame):
    try:
        return json.loads(str(frame.payload, "utf-8"))
    except (ValueError, UnicodeDecodeError) as e:
        raise WireError(
            f"undecodable {TYPE_NAMES.get(frame.ftype, frame.ftype)} "
            f"payload: {e}") from None


# ----------------------------------------------------------------------
# MANIFEST <-> SerializedState
# ----------------------------------------------------------------------

def _b64(data: bytes) -> str:
    return base64.b64encode(data).decode("ascii")


def _unb64(s: str) -> bytes:
    try:
        return base64.b64decode(s.encode("ascii"), validate=True)
    except Exception as e:  # noqa: BLE001 — any b64 failure is wire corruption
        raise WireError(f"bad base64 in manifest: {e}") from None


def manifest_frame(ser, *, deleted: Iterable[str] = (),
                   modules: Iterable[str] = (),
                   speculative: bool = False,
                   trickle: bool = False) -> Frame:
    """SerializedState (sans chunk payloads) -> canonical-JSON MANIFEST.
    Chunk *digests* travel here; chunk *bytes* follow in CHUNK frames.
    The ``trickle`` key is emitted only when set so default streams stay
    byte-identical to the golden vector."""
    blobs = {}
    for name, blob in ser.blobs.items():
        arrays = []
        for a in blob.arrays:
            meta = {"shape": list(a["shape"]), "dtype": a["dtype"],
                    "quant": bool(a["quant"]), "chunks": list(a["chunks"]),
                    "clens": list(a["clens"])}
            if a["quant"]:
                meta["block"] = int(a["block"])
                meta["scales"] = _b64(a["scales"])
            arrays.append(meta)
        blobs[name] = {"pickle": _b64(blob.pickle_bytes), "arrays": arrays}
    doc = {
        "codec": ser.codec, "blobs": blobs, "digests": dict(ser.digests),
        "deleted": sorted(deleted), "modules": sorted(modules),
        "skipped": sorted(ser.skipped), "speculative": bool(speculative)}
    if trickle:
        doc["trickle"] = True
    return json_frame(MANIFEST, doc)


def parse_manifest(frame: Frame):
    """MANIFEST frame -> (SerializedState without chunk payloads, deleted
    names, module names, speculative flag, trickle flag).  Chunks arrive
    separately and are attached by the receiver."""
    from repro.core.reducer import SerializedName, SerializedState
    if frame.ftype != MANIFEST:
        raise WireError(
            f"expected MANIFEST, got {TYPE_NAMES.get(frame.ftype)}")
    doc = parse_json(frame)
    try:
        blobs = {}
        for name, b in doc["blobs"].items():
            arrays = []
            for a in b["arrays"]:
                meta = {"shape": tuple(a["shape"]), "dtype": a["dtype"],
                        "quant": bool(a["quant"]),
                        "chunks": [int(d) for d in a["chunks"]],
                        "clens": [int(c) for c in a["clens"]]}
                if meta["quant"]:
                    meta["block"] = int(a["block"])
                    meta["scales"] = _unb64(a["scales"])
                arrays.append(meta)
            blobs[name] = SerializedName(pickle_bytes=_unb64(b["pickle"]),
                                         arrays=arrays)
        ser = SerializedState(codec=doc["codec"], blobs=blobs)
        ser.digests = {n: int(d) for n, d in doc["digests"].items()}
        ser.skipped = tuple(doc.get("skipped", ()))
        deleted = tuple(doc.get("deleted", ()))
        modules = tuple(doc.get("modules", ()))
        return (ser, deleted, modules, bool(doc.get("speculative", False)),
                bool(doc.get("trickle", False)))
    except WireError:
        raise
    except (KeyError, TypeError, ValueError, AttributeError) as e:
        raise WireError(f"malformed manifest: {e!r}") from None


# ----------------------------------------------------------------------
# CHUNK
# ----------------------------------------------------------------------

_DIGEST = struct.Struct("<Q")


def chunk_frame(digest: int, encoded) -> Frame:
    """``encoded`` is the store encoding (1-byte codec tag + body).  The
    chunk bytes become a payload *part*, never copied behind a digest
    prefix — the transport sends them scatter-gather."""
    return Frame(CHUNK, (_DIGEST.pack(digest & (2**64 - 1)), encoded))


def parse_chunk(frame: Frame) -> tuple[int, "bytes | memoryview"]:
    """CHUNK frame -> (digest, store-encoded chunk).  The chunk may be a
    ``memoryview`` into the frame's buffer — zero-copy; callers that need
    owned bytes (a store) materialize it themselves."""
    if frame.ftype != CHUNK:
        raise WireError(f"expected CHUNK, got {TYPE_NAMES.get(frame.ftype)}")
    parts = frame.payload_parts
    if len(parts) == 2 and len(parts[0]) == _DIGEST.size and len(parts[1]):
        # sender-built frame: digest prefix + chunk ride as separate parts
        (digest,) = _DIGEST.unpack(parts[0])
        return digest, parts[1]
    payload = frame.payload
    if len(payload) < _DIGEST.size + 1:
        raise WireError("CHUNK payload too short for digest + codec tag")
    (digest,) = _DIGEST.unpack_from(payload)
    return digest, payload[_DIGEST.size:]


def state_stream_frames(ser, need: Iterable[int], *,
                        deleted: Iterable[str] = ()) -> Iterator[Frame]:
    """The sender's half of a state stream *after* the need-ack: CHUNK
    frames for the requested digests, TOMBSTONE, END.  (The MANIFEST went
    out first to elicit the ack.)"""
    for d in need:
        if d in ser.chunks:
            yield chunk_frame(d, ser.chunks[d])
    deleted = sorted(deleted)
    if deleted:
        yield json_frame(TOMBSTONE, deleted)
    yield Frame(END)


# ----------------------------------------------------------------------
# gateway control plane: ATTACH / DETACH
# ----------------------------------------------------------------------

def attach_frame(tenant: str, notebook: str, cells, *,
                 think: Iterable[float] = (),
                 session: str | None = None) -> Frame:
    """Gateway session attach request.  ``cells`` is a list of
    ``{"source": str, "cost": float|None}`` dicts (the client ships its
    notebook inline; the gateway builds the session's Notebook from it).
    Canonical JSON, so a golden vector pins the format."""
    doc = {"tenant": str(tenant), "notebook": str(notebook),
           "cells": [{"source": str(c["source"]),
                      "cost": None if c.get("cost") is None
                      else float(c["cost"])} for c in cells],
           "think": [float(t) for t in think]}
    if session is not None:
        doc["session"] = str(session)
    return json_frame(ATTACH, doc)


def parse_attach(frame: Frame) -> dict:
    if frame.ftype != ATTACH:
        raise WireError(f"expected ATTACH, got {TYPE_NAMES.get(frame.ftype)}")
    doc = parse_json(frame)
    try:
        cells = [{"source": str(c["source"]),
                  "cost": None if c.get("cost") is None else float(c["cost"])}
                 for c in doc["cells"]]
        return {"tenant": str(doc["tenant"]),
                "notebook": str(doc["notebook"]), "cells": cells,
                "think": [float(t) for t in doc.get("think", ())],
                "session": doc.get("session")}
    except (KeyError, TypeError, ValueError) as e:
        raise WireError(f"malformed ATTACH: {e!r}") from None


def detach_frame(session: str, reason: str = "client") -> Frame:
    """Client-initiated session teardown (or the gateway's completion
    notice when it detaches a drained session)."""
    return json_frame(DETACH, {"session": str(session),
                               "reason": str(reason)})


def parse_detach(frame: Frame) -> tuple[str, str]:
    if frame.ftype != DETACH:
        raise WireError(f"expected DETACH, got {TYPE_NAMES.get(frame.ftype)}")
    doc = parse_json(frame)
    try:
        return str(doc["session"]), str(doc.get("reason", "client"))
    except (KeyError, TypeError) as e:
        raise WireError(f"malformed DETACH: {e!r}") from None


# ----------------------------------------------------------------------
# STREAM: the mux envelope (N sessions on one socket)
# ----------------------------------------------------------------------

_STREAM_ID = struct.Struct("<I")


def stream_frame(stream_id: int, inner: Frame) -> Frame:
    """Wrap ``inner`` for one multiplexed stream.  The envelope payload is
    the 4-byte stream id followed by the inner frame's *complete* wire
    encoding (its own header + CRC), carried scatter-gather — the inner
    payload bytes are never copied into the envelope."""
    if not 0 <= stream_id < 2**32:
        raise WireError(f"stream id {stream_id} out of u32 range")
    return Frame(STREAM, (_STREAM_ID.pack(stream_id), *inner.segments()))


def parse_stream(frame: Frame) -> tuple[int, Frame]:
    """STREAM envelope -> (stream_id, inner frame).  The inner frame's
    header, type and CRC are validated exactly as on a bare connection;
    its payload stays a zero-copy view into the envelope's buffer."""
    if frame.ftype != STREAM:
        raise WireError(f"expected STREAM, got {TYPE_NAMES.get(frame.ftype)}")
    buf = frame.payload
    if len(buf) < _STREAM_ID.size + FRAME_OVERHEAD:
        raise WireError("STREAM envelope too short for id + inner frame")
    (sid,) = _STREAM_ID.unpack_from(buf)
    plen, ftype = _HEADER.unpack_from(buf, _STREAM_ID.size)
    if plen > MAX_PAYLOAD:
        raise WireError(f"inner frame length {plen} exceeds MAX_PAYLOAD "
                        f"({MAX_PAYLOAD}) — corrupted envelope?")
    if ftype not in FRAME_TYPES:
        raise WireError(f"unknown inner frame type {ftype}")
    start = _STREAM_ID.size + _HEADER.size
    if len(buf) != start + plen + _CRC.size:
        raise WireError(
            f"STREAM envelope must hold exactly one inner frame "
            f"({len(buf)} bytes, want {start + plen + _CRC.size})")
    payload = buf[start:start + plen]
    (crc,) = _CRC.unpack_from(buf, start + plen)
    want = zlib.crc32(payload, zlib.crc32(bytes((ftype,))))
    if crc != want:
        raise WireError(
            f"CRC mismatch on mux'd {TYPE_NAMES[ftype]} frame "
            f"(got {crc:#010x}, want {want:#010x})")
    return sid, Frame(ftype, payload)


# ----------------------------------------------------------------------
# replica plane: REPLICA / PROMOTE / RACE (additive — v1 byte-stable)
# ----------------------------------------------------------------------

def replica_frame(session: str, epoch: int, *,
                  deleted: Iterable[str] = ()) -> Frame:
    """Replica-plane delta header: announces that the state stream which
    follows is a *convergence* delta for ``session`` up to cell ``epoch``
    (commit sequence number).  ``deleted`` carries names tombstoned since
    the follower's last watermark, so mid-stream deletions converge too."""
    return json_frame(REPLICA, {"session": str(session), "epoch": int(epoch),
                                "deleted": sorted(deleted)})


def parse_replica(frame: Frame) -> dict:
    if frame.ftype != REPLICA:
        raise WireError(f"expected REPLICA, got {TYPE_NAMES.get(frame.ftype)}")
    doc = parse_json(frame)
    try:
        return {"session": str(doc["session"]), "epoch": int(doc["epoch"]),
                "deleted": tuple(str(n) for n in doc.get("deleted", ()))}
    except (KeyError, TypeError, ValueError) as e:
        raise WireError(f"malformed REPLICA: {e!r}") from None


def promote_frame(session: str, epoch: int) -> Frame:
    """Failover handshake: the scheduler promotes this follower to primary
    for ``session``.  ``epoch`` is the commit sequence the promoter believes
    the follower has converged to; the follower replies RESULT with its own
    watermark so a stale promoter learns the real residual."""
    return json_frame(PROMOTE, {"session": str(session), "epoch": int(epoch)})


def parse_promote(frame: Frame) -> tuple[str, int]:
    if frame.ftype != PROMOTE:
        raise WireError(f"expected PROMOTE, got {TYPE_NAMES.get(frame.ftype)}")
    doc = parse_json(frame)
    try:
        return str(doc["session"]), int(doc["epoch"])
    except (KeyError, TypeError, ValueError) as e:
        raise WireError(f"malformed PROMOTE: {e!r}") from None


def race_frame(race_id: str, action: str, source: str = "") -> Frame:
    """First-result-wins cell race.  ``action`` is ``"run"`` (execute
    ``source``, reply RESULT tagged with the race id) or ``"cancel"``
    (the other leg won; drop the race — a late ``run`` for a cancelled id
    must NOT execute, which is the wire-level clobber protection)."""
    if action not in ("run", "cancel"):
        raise WireError(f"bad RACE action {action!r} (want run|cancel)")
    return json_frame(RACE, {"id": str(race_id), "action": action,
                             "source": str(source)})


def parse_race(frame: Frame) -> dict:
    if frame.ftype != RACE:
        raise WireError(f"expected RACE, got {TYPE_NAMES.get(frame.ftype)}")
    doc = parse_json(frame)
    try:
        action = str(doc["action"])
        if action not in ("run", "cancel"):
            raise ValueError(f"bad action {action!r}")
        return {"id": str(doc["id"]), "action": action,
                "source": str(doc.get("source", ""))}
    except (KeyError, TypeError, ValueError) as e:
        raise WireError(f"malformed RACE: {e!r}") from None
