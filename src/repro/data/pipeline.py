"""Deterministic synthetic data pipeline (shardable, restart-reproducible).

Tokens are generated per (seed, step, shard) on the host with a Zipf-flavored
marginal so compression/entropy behave more like text than uniform noise.
The same step always yields the same batch — checkpoint/restart resumes the
stream exactly (fault-tolerance tests rely on this).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


class TokenPipeline:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        # Zipf-ish marginal over the real vocab
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._p = p / p.sum()

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed * 1_000_003 + step) & 0xFFFFFFFF)

    # ------------------------------------------------------------------
    def train_batch(self, step: int, batch: int | None = None,
                    seq: int | None = None) -> dict:
        B = batch or self.shape.global_batch
        S = seq or self.shape.seq_len
        rng = self._rng(step)
        toks = rng.choice(self.cfg.vocab_size, size=(B, S + 1), p=self._p)
        out = {"tokens": toks.astype(np.int32)}
        self._add_frontend(out, rng, B)
        return out

    def prefill_batch(self, step: int, batch: int | None = None,
                      seq: int | None = None) -> dict:
        B = batch or self.shape.global_batch
        S = seq or self.shape.seq_len
        rng = self._rng(step)
        n_text = S - (self.cfg.num_patches if self.cfg.family == "vlm" else 0)
        toks = rng.choice(self.cfg.vocab_size, size=(B, n_text), p=self._p)
        out = {"tokens": toks.astype(np.int32)}
        self._add_frontend(out, rng, B)
        return out

    def decode_batch(self, step: int, batch: int | None = None) -> dict:
        B = batch or self.shape.global_batch
        rng = self._rng(step)
        return {"token": rng.choice(self.cfg.vocab_size, size=(B, 1),
                                    p=self._p).astype(np.int32)}

    # ------------------------------------------------------------------
    def _add_frontend(self, out: dict, rng, B: int) -> None:
        cfg = self.cfg
        if cfg.family == "vlm":
            out["vision_embeds"] = (rng.standard_normal(
                (B, cfg.num_patches, cfg.d_model)) * 0.02).astype(np.float32)
        if cfg.family == "encdec":
            out["encoder_frames"] = (rng.standard_normal(
                (B, cfg.encoder_seq, cfg.d_model)) * 0.02).astype(np.float32)
