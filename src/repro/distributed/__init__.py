from repro.distributed.context import DistContext, make_rules, shard

__all__ = ["DistContext", "make_rules", "shard"]
