"""Distribution context threaded through model code.

``DistContext`` carries the mesh and the logical->mesh axis rules; when it is
``None`` the model runs unsharded (smoke tests, single device).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS


def _divisible(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def make_rules(cfg, mesh: Mesh, *, sp_decode: bool = True,
               mode: str = "tp") -> dict[str, Any]:
    """Logical axis name -> mesh axis (or tuple of mesh axes, or None)."""
    axes = dict(mesh.shape)
    model = "model" if "model" in axes else None
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    msize = axes.get("model", 1)

    if mode == "fsdp":
        # data-parallel over EVERY mesh axis; parameters fully sharded
        # (zero-3 style) and all-gathered just-in-time by GSPMD.
        all_axes = data_axes + (("model",) if model else ())
        rules: dict[str, Any] = {k: None for k in (
            "seq", "embed", "head_dim", "heads", "kv_heads", "mlp", "vocab",
            "layers", "groups", "conv", "pos", "ssm_heads", "ssm_state",
            "ssm_inner", "lru", "lru_block", "enc_seq", "experts",
            "expert_mlp")}
        rules["batch"] = all_axes
        rules["cache_seq"] = model if sp_decode else None
        rules["expert_mode"] = "none" if not cfg.num_experts else "fsdp"
        rules["mode"] = "fsdp"
        return rules

    rules = {
        "mode": "tp",
        "batch": data_axes if data_axes else None,
        "seq": None,
        "cache_seq": model if sp_decode else None,  # decode KV cache sequence-sharded
        "embed": None,
        "head_dim": None,
        "heads": model if _divisible(cfg.num_heads or 1, msize) else None,
        "kv_heads": model if _divisible(cfg.num_kv_heads or 1, msize) else None,
        "mlp": model,
        "vocab": model,
        "layers": None,
        "groups": None,
        "conv": None,
        "pos": None,
        "ssm_heads": model if _divisible(getattr(cfg, "ssm_heads", 0) or 1, msize) else None,
        "ssm_state": None,
        "ssm_inner": model if _divisible(cfg.d_inner or 1, msize) else None,
        "lru": model if _divisible(cfg.lru_width or 1, msize) else None,
        "lru_block": None,
        "enc_seq": None,
    }
    # MoE: expert-parallel when divisible, else tensor-parallel inside experts.
    if cfg.num_experts:
        if _divisible(cfg.num_experts, msize):
            rules["experts"] = model
            # FSDP-style: expert ffn dim additionally sharded over the data
            # axes so 100B+-scale expert weights fit HBM (qwen3: 470 GB bf16)
            dsize = int(np.prod([axes[a] for a in data_axes])) if data_axes else 1
            rules["expert_mlp"] = (data_axes if len(data_axes) > 1
                                   else data_axes[0]) if (
                data_axes and _divisible(cfg.d_ff, dsize)) else None
            rules["expert_mode"] = "ep"
        else:
            rules["experts"] = None
            rules["expert_mlp"] = model
            rules["expert_mode"] = "tp"
    else:
        rules["experts"] = None
        rules["expert_mlp"] = model
        rules["expert_mode"] = "none"
    return rules


@dataclass
class DistContext:
    mesh: Mesh
    rules: dict[str, Any]
    sp_decode: bool = True          # sequence-parallel decode attention (shard_map)
    vocab_parallel: bool = False    # Megatron-style vocab-parallel embed + loss
    extra: dict = field(default_factory=dict)

    @classmethod
    def create(cls, cfg, mesh: Mesh, *, sp_decode: bool = True,
               vocab_parallel: bool = False, mode: str = "tp") -> "DistContext":
        return cls(mesh=mesh,
                   rules=make_rules(cfg, mesh, sp_decode=sp_decode, mode=mode),
                   sp_decode=sp_decode, vocab_parallel=vocab_parallel)

    @property
    def mode(self) -> str:
        return self.rules.get("mode", "tp")

    # ------------------------------------------------------------------
    def pspec(self, logical_axes: tuple) -> PS:
        spec, used = [], set()
        for ax in logical_axes:
            m = self.rules.get(ax) if ax is not None else None
            if m is None:
                spec.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(a for a in ms if a not in used)
            used.update(ms)
            spec.append(ms[0] if len(ms) == 1 else (ms if ms else None))
            if not ms:
                spec[-1] = None
        return PS(*spec)

    def sharding(self, logical_axes: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(logical_axes))

    def shard(self, x, *logical_axes):
        """with_sharding_constraint by logical axes (no-op patterns allowed)."""
        return jax.lax.with_sharding_constraint(x, self.sharding(tuple(logical_axes)))


def shard(ctx: DistContext | None, x, *logical_axes):
    if ctx is None:
        return x
    return ctx.shard(x, *logical_axes)
