"""Sequence-parallel decode attention (shard_map over the "model" axis).

Decode KV caches are sharded along the *sequence* axis over "model"
(DESIGN.md §3): each shard holds S/m cache slots, computes a partial
flash-style (m, l, o) against its slice, and the partials merge with a
log-sum-exp psum.  This is what lets 32k x 128-batch caches fit v5e HBM
(e.g. qwen3-moe: 806 GB global -> 1.6 GB/chip) without replicating KV heads.

The new token's K/V is written only on the shard owning slot ``pos``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

NEG = -1e30


def sp_decode_attention(ctx, q, k_cache, v_cache, new_k, new_v, pos):
    """q (B,1,H,hd); caches (B,KV,S,hd) seq-sharded; new_k/new_v (B,S=1,KV,hd);
    pos (B,). Returns (out (B,1,H,hd), k_cache, v_cache)."""
    mesh = ctx.mesh
    batch = ctx.rules["batch"]
    rep = PS(batch, None, None, None)
    cache_spec = PS(batch, None, "model", None)

    def shard_fn(q, k, v, nk, nv, pos):
        Bl, _, H, hd = q.shape
        KV, Sl = k.shape[1], k.shape[2]
        G = H // KV
        s_idx = jax.lax.axis_index("model")

        # ---- write the new token on the owning shard ----
        nk = jnp.swapaxes(nk, 1, 2)  # (Bl,KV,1,hd)
        nv = jnp.swapaxes(nv, 1, 2)
        tgt = pos // Sl
        off = (pos % Sl).astype(jnp.int32)

        def upd(c, n, o, w):
            u = jax.lax.dynamic_update_slice_in_dim(c, n, o, axis=1)
            return jnp.where(w, u, c)

        write = tgt == s_idx
        k = jax.vmap(upd)(k, nk, off, write)
        v = jax.vmap(upd)(v, nv, off, write)

        # ---- partial attention on the local slice ----
        qg = q.reshape(Bl, KV, G, hd)
        s = jnp.einsum("bkgh,bksh->bkgs", qg, k).astype(jnp.float32) * (hd ** -0.5)
        kpos = s_idx * Sl + jnp.arange(Sl)
        valid = kpos[None, :] <= pos[:, None]
        s = jnp.where(valid[:, None, None, :], s, NEG)

        m_l = jnp.max(s, axis=-1)                        # (Bl,KV,G)
        p = jnp.exp(s - m_l[..., None])
        l_l = jnp.sum(p, axis=-1)
        o_l = jnp.einsum("bkgs,bksh->bkgh", p.astype(v.dtype), v).astype(jnp.float32)

        # ---- merge partials across shards (flash-style LSE combine) ----
        m_g = jax.lax.pmax(m_l, "model")
        c = jnp.exp(m_l - m_g)
        l_g = jax.lax.psum(l_l * c, "model")
        o_g = jax.lax.psum(o_l * c[..., None], "model")
        out = (o_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q.dtype)
        return out.reshape(Bl, 1, H, hd), k, v

    from repro.distributed.sharding import shard_map
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(rep, cache_spec, cache_spec, rep, rep, PS(batch)),
        out_specs=(rep, cache_spec, cache_spec),
    )
    return fn(q, k_cache, v_cache, new_k, new_v, pos)
