"""Fault tolerance and elasticity runtime (simulation harness + real logic).

At 1000+ nodes, failures are routine.  The control plane here is the same
one a real deployment runs — only the transport (heartbeats over a SimClock
instead of RPC) is simulated:

* ``Coordinator``: tracks worker heartbeats; a worker missing
  ``miss_threshold`` beats is declared dead -> training pauses, the cluster
  restores from the latest delta checkpoint, and (if spares exist) resumes
  at the original scale, else *elastically rescales* to the surviving mesh.
* Straggler mitigation: per-step worker durations feed a robust z-score;
  persistent stragglers are evicted exactly like failures (re-dispatched),
  transient ones are absorbed by the synchronous barrier.
* Elastic rescale is a state migration: params/opt state move through the
  MigrationEngine with a new target sharding (DESIGN.md §1 mapping).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.simclock import SimClock


@dataclass
class WorkerState:
    name: str
    last_beat: float = 0.0
    alive: bool = True
    step_times: list[float] = field(default_factory=list)


@dataclass
class FaultEvent:
    time: float
    kind: str       # failure | straggler | restart | rescale
    worker: str
    detail: str = ""


class Coordinator:
    def __init__(self, workers: list[str], clock: SimClock | None = None, *,
                 beat_interval: float = 1.0, miss_threshold: int = 3,
                 straggler_factor: float = 2.5, straggler_patience: int = 3):
        self.clock = clock or SimClock()
        self.workers = {w: WorkerState(w, self.clock.now()) for w in workers}
        self.beat_interval = beat_interval
        self.miss_threshold = miss_threshold
        self.straggler_factor = straggler_factor
        self.straggler_patience = straggler_patience
        self._strag_count: dict[str, int] = {w: 0 for w in workers}
        self.events: list[FaultEvent] = []

    @property
    def detection_delay(self) -> float:
        """Worst-case lag between a death and its detection: the miss
        window.  The fleet scheduler charges this to a recovering session
        (same protocol, session-level instead of training-step-level)."""
        return self.beat_interval * self.miss_threshold

    # ------------------------------------------------------------------
    def heartbeat(self, worker: str) -> None:
        ws = self.workers[worker]
        ws.last_beat = self.clock.now()

    def report_step(self, worker: str, seconds: float) -> None:
        self.workers[worker].step_times.append(seconds)

    # ------------------------------------------------------------------
    def check_failures(self) -> list[str]:
        """Workers whose heartbeat lapsed; marks them dead."""
        now = self.clock.now()
        dead = []
        for ws in self.workers.values():
            if ws.alive and now - ws.last_beat > self.beat_interval * self.miss_threshold:
                ws.alive = False
                dead.append(ws.name)
                self.events.append(FaultEvent(now, "failure", ws.name,
                                              f"missed {self.miss_threshold} beats"))
        return dead

    def check_stragglers(self) -> list[str]:
        """Robust z-score on the latest step durations; persistent offenders."""
        latest = {w: ws.step_times[-1] for w, ws in self.workers.items()
                  if ws.alive and ws.step_times}
        if len(latest) < 3:
            return []
        vals = np.array(list(latest.values()))
        med = np.median(vals)
        mad = np.median(np.abs(vals - med)) + 1e-9
        out = []
        for w, t in latest.items():
            if (t - med) / (1.4826 * mad) > self.straggler_factor and t > med * 1.5:
                self._strag_count[w] += 1
                if self._strag_count[w] >= self.straggler_patience:
                    out.append(w)
                    self.events.append(FaultEvent(
                        self.clock.now(), "straggler", w,
                        f"{t:.2f}s vs median {med:.2f}s "
                        f"x{self.straggler_patience} steps"))
            else:
                self._strag_count[w] = 0
        return out

    def alive(self) -> list[str]:
        return [w for w, ws in self.workers.items() if ws.alive]

    def revive(self, worker: str) -> None:
        ws = self.workers[worker]
        ws.alive = True
        ws.last_beat = self.clock.now()
        self._strag_count[worker] = 0
        self.events.append(FaultEvent(self.clock.now(), "restart", worker))


class ElasticTrainer:
    """Drives a train loop with failure injection, checkpoint/restart and
    elastic rescale.  ``step_fn(step, world)`` does one synchronous step and
    returns per-worker durations; ``save_fn(step)``/``restore_fn()`` bind to
    a Checkpointer; ``rescale_fn(world)`` re-lowers the step for a new world
    size (a state migration + new shardings in the real runtime)."""

    def __init__(self, coord: Coordinator, *, step_fn, save_fn, restore_fn,
                 rescale_fn=None, checkpoint_every: int = 10, spares: int = 0):
        self.coord = coord
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.rescale_fn = rescale_fn
        self.checkpoint_every = checkpoint_every
        self.spares = spares
        self.restarts = 0
        self.rescales = 0

    def run(self, n_steps: int) -> dict:
        step = 0
        world = self.coord.alive()
        while step < n_steps:
            # step_fn returns per-worker durations; a worker missing from the
            # dict crashed mid-step (and therefore does not heartbeat)
            durations = self.step_fn(step, world)
            present = [w for w in world if w in durations]
            complete = len(present) == len(world)
            if complete:
                barrier = max([durations[w] for w in present], default=1.0)
            else:
                # a crashed member stalls the synchronous collective: the
                # survivors wait one beat interval, no training progress
                barrier = self.coord.beat_interval
            self.coord.clock.advance(barrier)
            for w in present:
                self.coord.heartbeat(w)        # survivors beat at the barrier
                if complete:
                    self.coord.report_step(w, durations[w])

            dead = self.coord.check_failures()
            stragglers = self.coord.check_stragglers() if complete else []
            for w in stragglers:
                self.coord.workers[w].alive = False  # evict & re-dispatch
            if dead or stragglers:
                step = self.restore_fn()
                self.restarts += 1
                if self.spares > 0:
                    for w in dead + stragglers:
                        self.spares -= 1
                        self.coord.revive(w)
                        if self.spares <= 0:
                            break
                new_world = self.coord.alive()
                if len(new_world) != len(world) and self.rescale_fn:
                    self.rescale_fn(new_world)
                    self.rescales += 1
                    self.coord.events.append(FaultEvent(
                        self.coord.clock.now(), "rescale", ",".join(new_world),
                        f"{len(world)} -> {len(new_world)} workers"))
                world = new_world
                continue

            if not complete:
                continue  # stalled barrier: no progress this round
            step += 1
            if step % self.checkpoint_every == 0:
                self.save_fn(step)
        return {"steps": n_steps, "restarts": self.restarts,
                "rescales": self.rescales, "events": self.coord.events,
                "wall": self.coord.clock.now()}
