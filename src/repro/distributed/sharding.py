"""Sharding trees for params, optimizer state (ZeRO-1), batches and caches."""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.distributed.context import DistContext
from repro.optim.optimizer import OptState


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` across jax versions: older releases only ship
    ``jax.experimental.shard_map`` and spell ``check_vma`` as ``check_rep``."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)


def fsdp_sharding(ctx: DistContext, axes: tuple, shape: tuple) -> NamedSharding:
    """Fully shard a parameter over ALL mesh axes (zero-3/FSDP): the first
    dim divisible by the full mesh size gets the flattened axes; fallbacks
    try the model axis alone; tiny leaves stay replicated."""
    mesh_axes = tuple(ctx.mesh.axis_names)
    total = int(np.prod([ctx.mesh.shape[a] for a in mesh_axes]))
    spec = [None] * len(shape)
    for i, dim in enumerate(shape):
        if dim % total == 0:
            spec[i] = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
            return NamedSharding(ctx.mesh, PS(*spec))
    msize = ctx.mesh.shape.get("model", 1)
    for i, dim in enumerate(shape):
        if msize > 1 and dim % msize == 0:
            spec[i] = "model"
            return NamedSharding(ctx.mesh, PS(*spec))
    return NamedSharding(ctx.mesh, PS(*spec))


def params_shardings(ctx: DistContext, axes_tree, abstract_params=None):
    """Map a logical-axes tree (same structure as params) to NamedShardings."""
    if ctx.mode == "fsdp":
        assert abstract_params is not None, "fsdp needs shapes"
        flat_a = jax.tree_util.tree_leaves(
            axes_tree, is_leaf=lambda x: isinstance(x, tuple))
        flat_p = jax.tree_util.tree_leaves(abstract_params)
        treedef = jax.tree_util.tree_structure(abstract_params)
        return jax.tree_util.tree_unflatten(
            treedef, [fsdp_sharding(ctx, a, p.shape)
                      for a, p in zip(flat_a, flat_p)])
    return jax.tree_util.tree_map(
        lambda axes: ctx.sharding(axes),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def _data_axes(ctx: DistContext):
    m = ctx.rules.get("batch")
    if m is None:
        return ()
    return (m,) if isinstance(m, str) else tuple(m)


def zero1_sharding(ctx: DistContext, axes: tuple, shape: tuple) -> NamedSharding:
    """Param sharding + extra data-axis sharding on the first divisible
    unsharded dim (ZeRO-1: optimizer state fully sharded)."""
    base = ctx.pspec(axes)
    data = _data_axes(ctx)
    dsize = int(np.prod([ctx.mesh.shape[a] for a in data])) if data else 1
    used = set()
    for entry in base:
        if entry is not None:
            used.update((entry,) if isinstance(entry, str) else entry)
    if dsize <= 1 or used & set(data):
        return NamedSharding(ctx.mesh, base)  # already data-sharded (e.g. EP
        # expert ffn over data) — ZeRO-1 extra sharding would collide
    spec = list(base) + [None] * (len(shape) - len(base))
    for i, dim in enumerate(shape):
        if spec[i] is None and dim % dsize == 0:
            spec[i] = data if len(data) > 1 else data[0]
            break
    return NamedSharding(ctx.mesh, PS(*spec))


def opt_shardings(ctx: DistContext, axes_tree, abstract_params) -> OptState:
    """Shardings for OptState(step, m, v, master)."""
    flat_axes = jax.tree_util.tree_leaves(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    flat_p = jax.tree_util.tree_leaves(abstract_params)
    treedef = jax.tree_util.tree_structure(abstract_params)
    if ctx.mode == "fsdp":
        shards = [fsdp_sharding(ctx, a, p.shape)
                  for a, p in zip(flat_axes, flat_p)]
    else:
        shards = [zero1_sharding(ctx, a, p.shape)
                  for a, p in zip(flat_axes, flat_p)]
    tree = jax.tree_util.tree_unflatten(treedef, shards)
    rep = NamedSharding(ctx.mesh, PS())
    return OptState(rep, tree, tree, tree)


def batch_pspec(ctx: DistContext, global_batch: int) -> PS | None:
    """Batch dim over the data axes when divisible, else replicated."""
    data = _data_axes(ctx)
    dsize = int(np.prod([ctx.mesh.shape[a] for a in data])) if data else 1
    if dsize > 1 and global_batch % dsize == 0:
        return data if len(data) > 1 else data[0]
    return None


def batch_shardings(ctx: DistContext, batch_tree, global_batch: int):
    b = batch_pspec(ctx, global_batch)

    def one(leaf):
        spec = [b] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(ctx.mesh, PS(*spec))

    return jax.tree_util.tree_map(one, batch_tree)


def cache_shardings(ctx: DistContext, cache_axes_tree, abstract_cache,
                    global_batch: int):
    """Cache axes -> shardings, with the batch rule adjusted for small B."""
    b = batch_pspec(ctx, global_batch)

    def one(axes, leaf):
        spec = []
        used = set()
        for ax in axes:
            if ax == "batch":
                val = b
            else:
                val = ctx.rules.get(ax) if ax is not None else None
            if isinstance(val, (tuple, list)):
                val = tuple(a for a in val if a not in used) or None
            if isinstance(val, str) and val in used:
                val = None
            if val is not None:
                used.update((val,) if isinstance(val, str) else val)
            spec.append(val)
        return NamedSharding(ctx.mesh, PS(*spec))

    return jax.tree_util.tree_map(
        one, cache_axes_tree, abstract_cache,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
