"""pjit-able train / prefill / decode steps with full sharding trees.

``build_train_step`` / ``build_prefill_step`` / ``build_decode_step`` return
(jitted_fn, in_shardings, out_shardings, abstract_inputs) ready for
``.lower(...).compile()`` — the dry-run consumes exactly this.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.distributed import sharding as shd
from repro.distributed.context import DistContext
from repro.models.model import LM
from repro.optim.optimizer import OptState, adamw_update, init_opt_state


# ----------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct stand-ins; no allocation)
# ----------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig, lm: LM | None = None,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs for every model input of this (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        n_text = S - (cfg.num_patches if cfg.family == "vlm" else 0)
        out = {"tokens": sds((B, n_text + 1), jnp.int32)}
    elif shape.kind == "prefill":
        n_text = S - (cfg.num_patches if cfg.family == "vlm" else 0)
        out = {"tokens": sds((B, n_text), jnp.int32)}
    else:  # decode
        out = {"token": sds((B, 1), jnp.int32)}
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            out["vision_embeds"] = sds((B, cfg.num_patches, cfg.d_model), dtype)
        if cfg.family == "encdec":
            out["encoder_frames"] = sds((B, cfg.encoder_seq, cfg.d_model), dtype)
    return out


def cache_specs(lm: LM, B: int, cache_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: lm.init_cache(B, cache_len, dtype))


# ----------------------------------------------------------------------
# train step
# ----------------------------------------------------------------------

def build_train_step(lm: LM, tc: TrainConfig, ctx: DistContext,
                     shape: ShapeConfig):
    cfg = lm.cfg
    n_micro = max(tc.microbatches, 1)

    def loss_fn(params, batch):
        return lm.loss(params, batch, ctx, remat=tc.remat)

    def train_step(params, opt: OptState, batch):
        if n_micro > 1:
            def resh(x):
                return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
            mb = jax.tree_util.tree_map(resh, batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def micro(carry, b):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
                gsum = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mb)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        opt2, params2, om = adamw_update(tc, opt, grads, params)
        return params2, opt2, {"loss": loss, **om}

    aparams = lm.abstract()
    axes = lm.axes()
    p_sh = shd.params_shardings(ctx, axes, aparams)
    o_sh = shd.opt_shardings(ctx, axes, aparams)
    binputs = input_specs(cfg, shape, lm)
    b_sh = shd.batch_shardings(ctx, binputs, shape.global_batch)
    rep = NamedSharding(ctx.mesh, PS())
    metrics_sh = {"loss": rep, "lr": rep, "grad_norm": rep}

    jf = jax.jit(train_step,
                 in_shardings=(p_sh, o_sh, b_sh),
                 out_shardings=(p_sh, o_sh, metrics_sh),
                 donate_argnums=(0, 1))
    aopt = jax.eval_shape(init_opt_state, aparams)
    return jf, (aparams, aopt, binputs)


# ----------------------------------------------------------------------
# serve steps
# ----------------------------------------------------------------------

def build_prefill_step(lm: LM, ctx: DistContext, shape: ShapeConfig,
                       cache_len: int | None = None):
    cfg = lm.cfg
    cache_len = cache_len or shape.seq_len

    def prefill(params, batch):
        return lm.prefill(params, batch, ctx, cache_len=cache_len)

    aparams = lm.abstract()
    p_sh = shd.params_shardings(ctx, lm.axes(), aparams)
    binputs = input_specs(cfg, shape, lm)
    b_sh = shd.batch_shardings(ctx, binputs, shape.global_batch)
    acache = cache_specs(lm, shape.global_batch, cache_len)
    c_sh = shd.cache_shardings(ctx, lm.cache_axes(ctx), acache,
                               shape.global_batch)
    bspec = shd.batch_pspec(ctx, shape.global_batch)
    logits_sh = NamedSharding(ctx.mesh, PS(bspec, ctx.rules.get("vocab")))

    jf = jax.jit(prefill, in_shardings=(p_sh, b_sh),
                 out_shardings=(logits_sh, c_sh))
    return jf, (aparams, binputs)


def build_decode_step(lm: LM, ctx: DistContext, shape: ShapeConfig):
    cfg = lm.cfg

    def decode(params, cache, batch):
        return lm.decode_step(params, cache, batch, ctx)

    aparams = lm.abstract()
    p_sh = shd.params_shardings(ctx, lm.axes(), aparams)
    acache = cache_specs(lm, shape.global_batch, shape.seq_len)
    c_sh = shd.cache_shardings(ctx, lm.cache_axes(ctx), acache,
                               shape.global_batch)
    binputs = input_specs(cfg, shape, lm)
    b_sh = shd.batch_shardings(ctx, binputs, shape.global_batch)
    bspec = shd.batch_pspec(ctx, shape.global_batch)
    logits_sh = NamedSharding(ctx.mesh, PS(bspec, ctx.rules.get("vocab")))

    jf = jax.jit(decode, in_shardings=(p_sh, c_sh, b_sh),
                 out_shardings=(logits_sh, c_sh), donate_argnums=(1,))
    return jf, (aparams, acache, binputs)
