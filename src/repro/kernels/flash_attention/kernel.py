"""Causal GQA flash attention as a Pallas TPU kernel.

Grid: (B, H, num_q_blocks, num_kv_blocks) — kv innermost, so the online
softmax statistics (m, l) and the output accumulator live in VMEM scratch
across the kv sweep.  Causal skip: kv blocks entirely above the diagonal are
predicated off with ``pl.when`` (real compute saving on TPU, unlike the
masked XLA path).  Block shapes default to (128, 128): MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i, *,
                  bq: int, bk: int, causal: bool, scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG)
        l_i[...] = jnp.zeros_like(l_i)

    should_run = (j * bk <= i * bq + bq - 1) if causal else True

    @pl.when(should_run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG)

        m_prev = m_i[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_i[...] = l_i[...] * alpha + jnp.sum(p, axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_i[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_i[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q (B,H,S,hd); k/v (B,KV,S,hd) -> (B,H,S,hd)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    bq, bk = min(block_q, S), min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    grid = (B, H, S // bq, S // bk)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
