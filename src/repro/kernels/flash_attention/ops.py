"""Jitted public wrapper for the flash-attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret", "impl"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False,
                    impl: str = "pallas"):
    """q (B,H,S,hd); k/v (B,KV,S,hd) -> (B,H,S,hd).

    impl="pallas": the TPU kernel (interpret=True to validate on CPU).
    impl="xla": the pure-jnp oracle.
    """
    if impl == "xla":
        return attention_ref(q, k, v, causal=causal)
    return flash_attention_kernel(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=interpret)
