"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q (B,H,S,hd); k/v (B,KV,S,hd) -> (B,H,S,hd). fp32 softmax."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, S, hd)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qg, k).astype(jnp.float32) * (hd ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bksh->bkgqh", w, v)
    return o.reshape(B, H, S, hd)
