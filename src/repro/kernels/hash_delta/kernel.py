"""On-device block hashing for delta migration (paper §II-D).

The paper detects changed objects by hashing the serialized state on the
host.  TPU adaptation (DESIGN.md §4): hash pytree leaves *on device* (one
weighted-sum hash per 1024-element block per lane) so delta detection never
pulls full tensors to the host — only (nb, 2) digests move.  Two independent
weight lanes give each block a 64-bit identity: the chunk store consumes the
per-block vector directly, and ``tensor_digest`` folds it into one leaf hash
on the host.  Position-sensitive via the per-lane weight vectors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

PRIME = np.uint32(2654435761)


def _hash_kernel(x_ref, w_ref, h_ref):
    x = x_ref[...]                               # (1, blk)
    w = w_ref[...]                               # (lanes, blk)
    prod = (x * w).astype(jnp.uint32)            # broadcast over lanes
    h = jnp.sum(prod, axis=1, dtype=jnp.uint32)  # (lanes,)
    h_ref[0, :] = (h ^ (h >> np.uint32(15))) * PRIME


def block_hash_kernel(x2d_u32, weights, *, interpret: bool = False):
    """x2d (nb, blk) uint32; weights (lanes, blk) uint32 -> (nb, lanes)."""
    nb, blk = x2d_u32.shape
    lanes = weights.shape[0]
    h = pl.pallas_call(
        _hash_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (i, 0)),
                  pl.BlockSpec((lanes, blk), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, lanes), jnp.uint32),
        interpret=interpret,
    )(x2d_u32, weights)
    return h
