"""On-device block hashing for delta migration (paper §II-D).

The paper detects changed objects by hashing the serialized state on the
host.  TPU adaptation (DESIGN.md §4): hash pytree leaves *on device* (one
weighted-sum hash per 1024-element block) so delta detection never pulls
full tensors to the host — only (nb,) digests move.  Position-sensitive via
a per-lane weight vector; digests are mixed on the host into one leaf hash.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

PRIME = np.uint32(2654435761)


def _hash_kernel(x_ref, w_ref, h_ref):
    x = x_ref[...]
    w = w_ref[...]
    prod = (x * w).astype(jnp.uint32)
    h = jnp.sum(prod, dtype=jnp.uint32)
    h_ref[0, 0] = (h ^ (h >> np.uint32(15))) * PRIME


def block_hash_kernel(x2d_u32, weights, *, interpret: bool = False):
    nb, blk = x2d_u32.shape
    h = pl.pallas_call(
        _hash_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (i, 0)),
                  pl.BlockSpec((1, blk), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 1), jnp.uint32),
        interpret=interpret,
    )(x2d_u32, weights[None, :])
    return h[:, 0]
