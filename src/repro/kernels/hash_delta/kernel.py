"""On-device block hashing for delta migration (paper §II-D).

The paper detects changed objects by hashing the serialized state on the
host.  TPU adaptation (DESIGN.md §4): hash pytree leaves *on device* (one
weighted-sum hash per 1024-element block per lane) so delta detection never
pulls full tensors to the host — only (nb, 2) digests move.  Two independent
weight lanes give each block a 64-bit identity: the chunk store consumes the
per-block vector directly, and ``tensor_digest`` folds it into one leaf hash
on the host.  Position-sensitive via the per-lane weight vectors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

PRIME = np.uint32(2654435761)


def premix(x):
    """Per-element mix before the weighted sum.

    The weighted sum alone is linear: a delta confined to one bit (e.g.
    ``conj`` flipping sign bits) contributes ``delta * sum(w)``, which
    annihilates mod 2^32 whenever the affected weights sum even.  The
    xorshift-multiply makes each element's contribution carry-dependent,
    so constant-XOR deltas no longer cancel.  ``premix(0) == 0``, which
    keeps block zero-padding invisible to the hash."""
    x = x ^ (x >> np.uint32(16))
    return x * PRIME


def _hash_kernel(x_ref, w_ref, h_ref):
    x = premix(x_ref[...])                       # (1, blk)
    w = w_ref[...]                               # (lanes, blk)
    prod = (x * w).astype(jnp.uint32)            # broadcast over lanes
    h = jnp.sum(prod, axis=1, dtype=jnp.uint32)  # (lanes,)
    h_ref[0, :] = (h ^ (h >> np.uint32(15))) * PRIME


def block_hash_kernel(x2d_u32, weights, *, interpret: bool = False):
    """x2d (nb, blk) uint32; weights (lanes, blk) uint32 -> (nb, lanes)."""
    nb, blk = x2d_u32.shape
    lanes = weights.shape[0]
    h = pl.pallas_call(
        _hash_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (i, 0)),
                  pl.BlockSpec((lanes, blk), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, lanes), jnp.uint32),
        interpret=interpret,
    )(x2d_u32, weights)
    return h


def _hash_compare_kernel(x_ref, w_ref, p_ref, hp_ref, h_ref, c_ref):
    x = premix(x_ref[...])                       # (1, blk)
    w = w_ref[...]                               # (lanes, blk)
    prod = (x * w).astype(jnp.uint32)
    h = jnp.sum(prod, axis=1, dtype=jnp.uint32)  # (lanes,)
    h = (h ^ (h >> np.uint32(15))) * PRIME
    h_ref[0, :] = h
    same = jnp.all(h == p_ref[0, :]) & (hp_ref[0, 0] != np.uint32(0))
    c_ref[0, 0] = jnp.where(same, np.uint32(0), np.uint32(1))


def block_hash_compare_kernel(x2d_u32, weights, prior, has_prior, *,
                              interpret: bool = False):
    """Fused digest+compare, one launch.

    x2d (nb, blk) uint32; weights (lanes, blk); prior (nb, lanes) is the
    previous manifest's block digest vector; has_prior (nb, 1) uint32 flags
    which rows of ``prior`` are meaningful (0 => block is new, always
    changed).  Returns ``(h, changed)``: the fresh (nb, lanes) digests —
    bit-identical to :func:`block_hash_kernel` — plus a (nb, 1) uint32
    changed flag per block, so the host never re-derives the comparison."""
    nb, blk = x2d_u32.shape
    lanes = weights.shape[0]
    h, changed = pl.pallas_call(
        _hash_compare_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (i, 0)),
                  pl.BlockSpec((lanes, blk), lambda i: (0, 0)),
                  pl.BlockSpec((1, lanes), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, lanes), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, lanes), jnp.uint32),
                   jax.ShapeDtypeStruct((nb, 1), jnp.uint32)],
        interpret=interpret,
    )(x2d_u32, weights, prior, has_prior)
    return h, changed
