"""Jitted wrappers: on-device content digests for the state plane.

``block_digests`` exposes the per-1024-element block digest vector that the
content-addressed chunk store consumes (each block carries two independent
uint32 lanes = one 64-bit identity).  ``tensor_digest`` folds that vector
into a single **64-bit** leaf digest: both lanes are reduced on device and
combined on the host as ``(hi << 32) | lo`` — Pallas/XLA arithmetic stays
uint32 throughout, so no x64 mode is required, yet the digest space is a
true 2^64 (the pre-CAS version returned a single uint32).

**Batched manifest digesting.**  ``digest_leaves`` packs every leaf of a
manifest — ragged sizes, mixed numpy/jax residency — into one block grid
and digests the whole namespace in a *single* kernel launch with a single
device->host sync, instead of one launch + one ``np.asarray`` round-trip
per leaf.  ``digest_leaves_delta`` fuses the compare against the prior
manifest's digest vector on device and gathers only the changed-leaf index
list to the host.  Both are bit-identical to the per-leaf path: each leaf
is padded to its own block boundary (so per-block digests are unchanged)
and the per-leaf fold is an unsigned 32-bit weighted sum, which is exactly
associative/commutative mod 2^32 — ``segment_sum`` over the packed grid
therefore reproduces ``tensor_digest`` bit for bit.

``HOST_SYNCS`` counts device->host materializations issued by this module
(one per ``tensor_digest``, one per batched call) so benchmarks and tests
can assert the O(leaves) -> O(1) reduction.
"""
from __future__ import annotations

import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 1024
LANES = 2
_FOLD = np.uint32(2246822519)   # per-block position weight (golden-prime)

HOST_SYNCS = 0                  # device->host syncs since reset_host_syncs()


def _note_sync(n: int = 1) -> None:
    global HOST_SYNCS
    HOST_SYNCS += n


def reset_host_syncs() -> None:
    global HOST_SYNCS
    HOST_SYNCS = 0


def note_host_sync(n: int = 1) -> None:
    """Record a device->host sync issued by a caller (e.g. the chunk
    store's batched fold pulling the packed digest vector)."""
    _note_sync(n)

# host constants (no tracer leak): one odd weight vector per lane.  Lane 0
# keeps the historical 0xD1657 stream; lane 1 is an independent stream.
_W = np.stack([
    np.random.default_rng(0xD1657).integers(1, 2**32, size=BLOCK,
                                            dtype=np.uint32) | 1,
    np.random.default_rng(0xD1658).integers(1, 2**32, size=BLOCK,
                                            dtype=np.uint32) | 1,
])


def _as_u32_blocks(x):
    if jnp.issubdtype(x.dtype, jnp.floating):
        raw = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    elif x.dtype.itemsize == 4:
        raw = jax.lax.bitcast_convert_type(x, jnp.uint32)
    else:  # narrow/wide ints, bool: value-hash via uint32 cast
        raw = x.astype(jnp.uint32)
    flat = raw.reshape(-1).astype(jnp.uint32)
    pad = (-flat.shape[0]) % BLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def block_digests(x, *, interpret: bool = False, impl: str = "pallas"):
    """Any tensor -> (nb, 2) uint32 per-block digest lanes (on device).

    One row per 1024-element block; the two lanes together are the block's
    64-bit identity.  Only this (nb, 2) vector crosses to the host — never
    the tensor itself."""
    x2d = _as_u32_blocks(x)
    if impl == "xla":
        from repro.kernels.hash_delta.ref import block_hash_ref
        return block_hash_ref(x2d, jnp.asarray(_W))
    from repro.kernels.hash_delta.kernel import block_hash_kernel
    return block_hash_kernel(x2d, jnp.asarray(_W), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def _digest_lanes(x, *, interpret: bool = False, impl: str = "pallas"):
    """Weighted fold of the per-block vector -> (2,) uint32 (host-free)."""
    h2 = block_digests(x, interpret=interpret, impl=impl)
    idx = (jnp.arange(h2.shape[0], dtype=jnp.uint32)
           * _FOLD + jnp.uint32(1))
    return jnp.sum(h2 * idx[:, None], axis=0, dtype=jnp.uint32)


def tensor_digest(x, *, interpret: bool = False, impl: str = "pallas") -> int:
    """Any tensor -> one 64-bit int digest (content hash for delta migration)."""
    lo, hi = np.asarray(_digest_lanes(x, interpret=interpret, impl=impl))
    _note_sync()
    return (int(hi) << 32) | int(lo)


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def block_digests_compare(x, prior, has_prior, *, interpret: bool = False,
                          impl: str = "pallas"):
    """Fused per-block digest + compare against a prior digest vector.

    ``prior`` is (nb, 2) uint32 (the previous manifest's block lanes for
    this tensor) and ``has_prior`` is (nb, 1) uint32 validity flags.
    Returns ``(h, changed)``: ``h`` bit-identical to :func:`block_digests`,
    ``changed`` a (nb, 1) uint32 flag per block — the comparison happens in
    the same launch as the hash, so only flags ever cross to the host."""
    x2d = _as_u32_blocks(x)
    if impl == "xla":
        from repro.kernels.hash_delta.ref import block_hash_compare_ref
        return block_hash_compare_ref(x2d, jnp.asarray(_W), prior, has_prior)
    from repro.kernels.hash_delta.kernel import block_hash_compare_kernel
    return block_hash_compare_kernel(x2d, jnp.asarray(_W), prior, has_prior,
                                     interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def packed_block_digests(u8, *, interpret: bool = False,
                         impl: str = "pallas"):
    """Pre-packed byte buffer (size % BLOCK == 0) -> (nb, 2) lanes.

    The caller has already zero-padded each constituent payload to its own
    block boundary, so every row equals the row :func:`block_digests` would
    produce for that payload standalone."""
    return _lanes_impl(u8.astype(jnp.uint32).reshape(-1, BLOCK),
                       interpret, impl)


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def packed_block_digests_compare(u8, prior, has_prior, *,
                                 interpret: bool = False,
                                 impl: str = "pallas"):
    """Fused variant of :func:`packed_block_digests`: digest + compare
    against a prior (nb, 2) lane vector in one launch; returns
    ``(h, changed)`` with ``changed`` (nb, 1) uint32."""
    x2d = u8.astype(jnp.uint32).reshape(-1, BLOCK)
    if impl == "xla":
        from repro.kernels.hash_delta.ref import block_hash_compare_ref
        return block_hash_compare_ref(x2d, jnp.asarray(_W), prior, has_prior)
    from repro.kernels.hash_delta.kernel import block_hash_compare_kernel
    return block_hash_compare_kernel(x2d, jnp.asarray(_W), prior, has_prior,
                                     interpret=interpret)


# ----------------------------------------------------------------------
# batched manifest digesting: many ragged leaves, one launch, one sync
# ----------------------------------------------------------------------

_ALIGN = 64   # XLA:CPU buffer alignment — required for zero-copy import


def aligned_empty(n: int, dtype=np.uint32) -> np.ndarray:
    """1-D ``np.empty(n, dtype)`` on a 64-byte boundary.

    numpy only guarantees 16-byte alignment, which forces jax's dlpack
    import to copy; carving the view out of an oversized uint8 buffer
    makes :func:`to_device` a true zero-copy alias."""
    itemsize = np.dtype(dtype).itemsize
    raw = np.empty(n * itemsize + _ALIGN, np.uint8)
    off = (-raw.ctypes.data) % _ALIGN
    return raw[off:off + n * itemsize].view(dtype)


def to_device(a: np.ndarray):
    """Host array -> device array, zero-copy when 64-byte aligned.

    The device array aliases the host buffer, so callers must not mutate
    ``a`` afterwards.  Falls back to a copying transfer when the buffer
    cannot be shared (misaligned, or no dlpack support)."""
    try:
        return jnp.from_dlpack(a)
    except Exception:
        return jnp.asarray(a)


_STAGING_CAP = 3 << 29          # max bytes kept alive per dtype: covers a
                                # GiB-scale manifest plus block padding
_STAGING = threading.local()


def staging_buffer(n: int, dtype=np.uint32) -> np.ndarray:
    """Aligned staging buffer, reused across calls (per thread, capped).

    First-touch page faults dominate the cost of a fresh ``np.empty`` —
    roughly 7x the price of refilling warm pages — so batch digesting
    stages through a recycled buffer.  Reuse is only safe because every
    batched entry point syncs (``device_get``) before returning: once a
    call is over, no live device array aliases the buffer.  Requests
    beyond the cap fall back to a fresh allocation rather than pinning
    manifest-sized memory forever."""
    nbytes = n * np.dtype(dtype).itemsize
    if nbytes > _STAGING_CAP:
        return aligned_empty(n, dtype)
    pool = getattr(_STAGING, "pool", None)
    if pool is None:
        pool = _STAGING.pool = {}
    key = np.dtype(dtype).str
    buf = pool.get(key)
    if buf is None or buf.size < n:
        grown = 0 if buf is None else 2 * buf.size
        cap = _STAGING_CAP // np.dtype(dtype).itemsize
        buf = pool[key] = aligned_empty(min(cap, max(n, grown)), dtype)
    return buf[:n]


def _np_u32_flat(a: np.ndarray) -> np.ndarray:
    """Host-exact mirror of :func:`_as_u32_blocks`, unpadded and flat.

    Bit-identity notes: float16/float32 -> float32 is exact in both, and
    float64 -> float32 uses the same IEEE round-to-nearest that jax's
    implicit x64 demotion applies; 4-byte dtypes are reinterpreted; narrow
    and 64-bit ints wrap mod 2^32 exactly as XLA's convert does."""
    if a.dtype.kind == "f":
        raw = np.ascontiguousarray(a, dtype=np.float32).view(np.uint32)
    elif a.dtype.itemsize == 4 and a.dtype.kind in "iu":
        raw = np.ascontiguousarray(a).view(np.uint32)
    else:
        raw = a.astype(np.uint32)
    return raw.reshape(-1)


_prep_blocks = jax.jit(_as_u32_blocks)


def _pack_leaves(leaves):
    """Ragged leaves -> one (NB, BLOCK) uint32 grid + blocks-per-leaf.

    Each leaf is padded to its own block boundary before packing, so every
    row of the grid equals the row the per-leaf path would have hashed.
    numpy-resident leaves are gathered host-side (one copy pass, slice-
    assigned into a recycled aligned staging buffer) and shipped
    zero-copy; jax-resident leaves are prepped on device and never visit
    the host.  All host runs carve disjoint slices of ONE staging buffer —
    a per-run buffer would let a later fill clobber an earlier run's
    still-pending device alias."""
    order = []       # ("host", flat_views, run_nb) | ("dev", blocks, None)
    nbs = []
    host_nb = 0
    run, run_nb = [], 0

    def _close_run():
        nonlocal run, run_nb, host_nb
        if run_nb:
            order.append(("host", run, run_nb))
            host_nb += run_nb
        run, run_nb = [], 0

    for a in leaves:
        if isinstance(a, (np.ndarray, np.generic)):
            flat = _np_u32_flat(np.asarray(a))
            nb = -(-flat.size // BLOCK)
            run.append(flat)
            run_nb += nb
            nbs.append(nb)
        else:
            _close_run()
            b = _prep_blocks(a)
            if b.shape[0]:
                order.append(("dev", b, None))
            nbs.append(int(b.shape[0]))
    _close_run()

    if host_nb:
        dst = staging_buffer(host_nb * BLOCK)
    parts, off = [], 0
    for kind, payload, _nb in order:
        if kind == "dev":
            parts.append(payload)
            continue
        lo = off
        for flat in payload:
            end = off + flat.size
            dst[off:end] = flat
            off += -(-flat.size // BLOCK) * BLOCK
            if off != end:
                dst[end:off] = 0
        # run offsets are BLOCK-row multiples, so slices stay 64B-aligned
        parts.append(to_device(dst[lo:off].reshape(-1, BLOCK)))
    if not parts:
        return jnp.zeros((0, BLOCK), jnp.uint32), nbs
    x2d = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
    return x2d, nbs


def _fold_weights(nbs):
    """Per-block fold weight + leaf segment id (host-side, shapes static)."""
    nbs_a = np.asarray(nbs, np.int64)
    total = int(nbs_a.sum())
    seg = np.repeat(np.arange(len(nbs_a), dtype=np.int32), nbs_a)
    starts = np.repeat(np.cumsum(nbs_a) - nbs_a, nbs_a)
    local = (np.arange(total, dtype=np.int64) - starts).astype(np.uint32)
    idx = local * _FOLD + np.uint32(1)
    return idx, seg


def _lanes_impl(x2d, interpret: bool, impl: str):
    if impl == "xla":
        from repro.kernels.hash_delta.ref import block_hash_ref
        return block_hash_ref(x2d, jnp.asarray(_W))
    from repro.kernels.hash_delta.kernel import block_hash_kernel
    return block_hash_kernel(x2d, jnp.asarray(_W), interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("num_leaves", "interpret", "impl"))
def _batched_lanes(x2d, idx, seg, *, num_leaves: int,
                   interpret: bool = False, impl: str = "pallas"):
    """One launch over the packed grid -> (num_leaves, 2) digest lanes.

    The per-leaf fold is a weighted uint32 sum; ``segment_sum`` reorders
    additions but unsigned add is associative/commutative mod 2^32, so the
    result is bit-identical to the per-leaf ``jnp.sum``."""
    h2 = _lanes_impl(x2d, interpret, impl)
    return jax.ops.segment_sum(h2 * idx[:, None], seg,
                               num_segments=num_leaves)


@functools.partial(jax.jit,
                   static_argnames=("num_leaves", "interpret", "impl"))
def _batched_delta(x2d, idx, seg, prior, has_prior, *, num_leaves: int,
                   interpret: bool = False, impl: str = "pallas"):
    """Fused digest -> compare -> gather, entirely on device.

    Returns (lanes, changed_idx) where changed_idx is the (num_leaves,)
    gathered index vector of changed leaves, padded with ``num_leaves``."""
    lanes = _batched_lanes(x2d, idx, seg, num_leaves=num_leaves,
                           interpret=interpret, impl=impl)
    changed = (~has_prior) | jnp.any(lanes != prior, axis=1)
    (ch_idx,) = jnp.nonzero(changed, size=num_leaves,
                            fill_value=num_leaves)
    return lanes, ch_idx


def _fold_digests(lanes: np.ndarray) -> list[int]:
    lanes = np.asarray(lanes, np.uint64)
    return ((lanes[:, 1] << np.uint64(32)) | lanes[:, 0]).tolist()


def digest_leaves(leaves, *, interpret: bool = False,
                  impl: str = "pallas") -> list[int]:
    """Digest a whole manifest of leaves in one launch + one host sync.

    Returns one 64-bit digest per leaf, in order, bit-identical to calling
    :func:`tensor_digest` on each leaf individually."""
    leaves = list(leaves)
    n = len(leaves)
    if n == 0:
        return []
    x2d, nbs = _pack_leaves(leaves)
    if x2d.shape[0] == 0:       # all leaves empty: digest of no blocks is 0
        return [0] * n
    idx, seg = _fold_weights(nbs)
    lanes = np.asarray(_batched_lanes(
        x2d, jnp.asarray(idx), jnp.asarray(seg), num_leaves=n,
        interpret=interpret, impl=impl))
    _note_sync()
    return _fold_digests(lanes)


def digest_leaves_delta(leaves, prior_digests, *, interpret: bool = False,
                        impl: str = "pallas"):
    """Digest + delta for a whole manifest: one launch, one host sync.

    ``prior_digests`` aligns with ``leaves``: the prior 64-bit digest of
    each leaf, or ``None`` when there is no prior (leaf counts as changed).
    Returns ``(digests, changed)`` — per-leaf 64-bit digests (bit-identical
    to :func:`tensor_digest`) and the sorted index list of leaves whose
    digest differs from its prior.  The compare and the changed-index
    gather both run on device; only (n, 2) lanes + (n,) indices cross."""
    leaves = list(leaves)
    n = len(leaves)
    if n == 0:
        return [], []
    prior = np.zeros((n, LANES), np.uint32)
    has_prior = np.zeros(n, bool)
    for j, d in enumerate(prior_digests):
        if d is not None:
            prior[j, 0] = np.uint32(d & 0xFFFFFFFF)
            prior[j, 1] = np.uint32((d >> 32) & 0xFFFFFFFF)
            has_prior[j] = True
    x2d, nbs = _pack_leaves(leaves)
    if x2d.shape[0] == 0:
        digests = [0] * n
        changed = [j for j in range(n)
                   if not has_prior[j] or prior_digests[j] != 0]
        return digests, changed
    idx, seg = _fold_weights(nbs)
    lanes, ch_idx = jax.device_get(_batched_delta(
        x2d, jnp.asarray(idx), jnp.asarray(seg), jnp.asarray(prior),
        jnp.asarray(has_prior), num_leaves=n, interpret=interpret,
        impl=impl))
    _note_sync()
    changed = [int(j) for j in ch_idx if j < n]
    return _fold_digests(lanes), changed
