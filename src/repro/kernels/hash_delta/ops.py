"""Jitted wrappers: on-device content digests for the state plane.

``block_digests`` exposes the per-1024-element block digest vector that the
content-addressed chunk store consumes (each block carries two independent
uint32 lanes = one 64-bit identity).  ``tensor_digest`` folds that vector
into a single **64-bit** leaf digest: both lanes are reduced on device and
combined on the host as ``(hi << 32) | lo`` — Pallas/XLA arithmetic stays
uint32 throughout, so no x64 mode is required, yet the digest space is a
true 2^64 (the pre-CAS version returned a single uint32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 1024
LANES = 2

# host constants (no tracer leak): one odd weight vector per lane.  Lane 0
# keeps the historical 0xD1657 stream; lane 1 is an independent stream.
_W = np.stack([
    np.random.default_rng(0xD1657).integers(1, 2**32, size=BLOCK,
                                            dtype=np.uint32) | 1,
    np.random.default_rng(0xD1658).integers(1, 2**32, size=BLOCK,
                                            dtype=np.uint32) | 1,
])


def _as_u32_blocks(x):
    if jnp.issubdtype(x.dtype, jnp.floating):
        raw = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    elif x.dtype.itemsize == 4:
        raw = jax.lax.bitcast_convert_type(x, jnp.uint32)
    else:  # narrow/wide ints, bool: value-hash via uint32 cast
        raw = x.astype(jnp.uint32)
    flat = raw.reshape(-1).astype(jnp.uint32)
    pad = (-flat.shape[0]) % BLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def block_digests(x, *, interpret: bool = False, impl: str = "pallas"):
    """Any tensor -> (nb, 2) uint32 per-block digest lanes (on device).

    One row per 1024-element block; the two lanes together are the block's
    64-bit identity.  Only this (nb, 2) vector crosses to the host — never
    the tensor itself."""
    x2d = _as_u32_blocks(x)
    if impl == "xla":
        from repro.kernels.hash_delta.ref import block_hash_ref
        return block_hash_ref(x2d, jnp.asarray(_W))
    from repro.kernels.hash_delta.kernel import block_hash_kernel
    return block_hash_kernel(x2d, jnp.asarray(_W), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def _digest_lanes(x, *, interpret: bool = False, impl: str = "pallas"):
    """Weighted fold of the per-block vector -> (2,) uint32 (host-free)."""
    h2 = block_digests(x, interpret=interpret, impl=impl)
    idx = (jnp.arange(h2.shape[0], dtype=jnp.uint32)
           * jnp.uint32(2246822519) + jnp.uint32(1))
    return jnp.sum(h2 * idx[:, None], axis=0, dtype=jnp.uint32)


def tensor_digest(x, *, interpret: bool = False, impl: str = "pallas") -> int:
    """Any tensor -> one 64-bit int digest (content hash for delta migration)."""
    lo, hi = np.asarray(_digest_lanes(x, interpret=interpret, impl=impl))
    return (int(hi) << 32) | int(lo)
