"""Jitted wrapper: hash any tensor into one uint64-ish digest (on device)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 1024


_W = np.random.default_rng(0xD1657).integers(
    1, 2**32, size=BLOCK, dtype=np.uint32) | 1  # host constant (no tracer leak)


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def tensor_digest(x, *, interpret: bool = False, impl: str = "pallas"):
    """Any tensor -> scalar uint32 digest (content hash for delta migration)."""
    if jnp.issubdtype(x.dtype, jnp.floating):
        raw = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    elif x.dtype.itemsize == 4:
        raw = jax.lax.bitcast_convert_type(x, jnp.uint32)
    else:  # narrow/wide ints, bool: value-hash via uint32 cast
        raw = x.astype(jnp.uint32)
    flat = raw.reshape(-1).astype(jnp.uint32)
    pad = (-flat.shape[0]) % BLOCK
    x2d = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    if impl == "xla":
        from repro.kernels.hash_delta.ref import block_hash_ref
        h = block_hash_ref(x2d, jnp.asarray(_W))
    else:
        from repro.kernels.hash_delta.kernel import block_hash_kernel
        h = block_hash_kernel(x2d, jnp.asarray(_W), interpret=interpret)
    # host-free final mix: weighted fold of block digests
    idx = jnp.arange(h.shape[0], dtype=jnp.uint32) * jnp.uint32(2246822519) + jnp.uint32(1)
    return jnp.sum(h * idx, dtype=jnp.uint32)
