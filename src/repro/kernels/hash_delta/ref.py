"""Pure-jnp oracle for the block hash."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

PRIME = np.uint32(2654435761)  # Knuth multiplicative


def block_hash_ref(x2d_u32, weights):
    """x2d (nb, blk) uint32; weights (lanes, blk) uint32 -> (nb, lanes)."""
    prod = x2d_u32[:, None, :] * weights[None, :, :]
    h = jnp.sum(prod.astype(jnp.uint32), axis=2, dtype=jnp.uint32)
    return (h ^ (h >> np.uint32(15))) * PRIME
