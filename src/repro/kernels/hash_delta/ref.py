"""Pure-jnp oracle for the block hash."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PRIME = np.uint32(2654435761)  # Knuth multiplicative


def block_hash_ref(x2d_u32, weights):
    """x2d (nb, blk) uint32; weights (lanes, blk) uint32 -> (nb, lanes).

    The weighted block sum IS a uint32 matmul (wrap-around included), and
    XLA's dot path runs it an order of magnitude faster than the
    broadcast-multiply-reduce formulation while producing identical bits —
    integer dot accumulates exactly mod 2^32.  Elements are premixed
    first (see ``kernel.premix``) so constant-XOR deltas such as sign-bit
    flips cannot cancel in the linear sum."""
    x = x2d_u32 ^ (x2d_u32 >> np.uint32(16))
    x = x * PRIME
    h = jax.lax.dot_general(x, weights.T, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.uint32)
    return (h ^ (h >> np.uint32(15))) * PRIME


def block_hash_compare_ref(x2d_u32, weights, prior, has_prior):
    """Oracle for the fused digest+compare: returns (h, changed) with the
    same shapes/dtypes as ``block_hash_compare_kernel``."""
    h = block_hash_ref(x2d_u32, weights)
    same = jnp.all(h == prior, axis=1) & (has_prior[:, 0] != np.uint32(0))
    changed = jnp.where(same, np.uint32(0), np.uint32(1))[:, None]
    return h, changed
