"""Block-wise int8 quantize/dequantize as Pallas TPU kernels.

Migration-path compression (paper §II-D "compression ... beyond the scope";
TPU adaptation in DESIGN.md §4): tensors are flattened to (nb, 1024) blocks —
(8, 128) VREG-shaped — and each block gets an absmax scale.  Runs on-device
so compression does not round-trip through the host before a migration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[0, 0] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[0, 0]).astype(x_ref.dtype)


def quantize_kernel(x2d, *, interpret: bool = False):
    nb, blk = x2d.shape
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, blk), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, blk), jnp.int8),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)],
        interpret=interpret,
    )(x2d)
    return q, s[:, 0]


def dequantize_kernel(q, scale, dtype, *, interpret: bool = False):
    nb, blk = q.shape
    return pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, blk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, blk), dtype),
        interpret=interpret,
    )(q, scale[:, None])
