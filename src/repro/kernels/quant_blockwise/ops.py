"""Jitted wrappers: quantize/dequantize arbitrary-shape tensors blockwise."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 1024


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def quantize(x, *, interpret: bool = False, impl: str = "pallas"):
    """Any-shape float tensor -> (q int8 (nb, BLOCK), scales (nb,), meta).

    Pads the flattened tensor to a BLOCK multiple (meta carries true size)."""
    n = int(np.prod(x.shape))
    pad = (-n) % BLOCK
    flat = jnp.pad(x.reshape(-1), (0, pad))
    x2d = flat.reshape(-1, BLOCK)
    if impl == "xla":
        from repro.kernels.quant_blockwise.ref import quantize_ref
        q, s = quantize_ref(x2d)
    else:
        from repro.kernels.quant_blockwise.kernel import quantize_kernel
        q, s = quantize_kernel(x2d, interpret=interpret)
    return q, s


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "interpret", "impl"))
def dequantize(q, scales, shape: tuple, dtype=jnp.bfloat16, *,
               interpret: bool = False, impl: str = "pallas"):
    if impl == "xla":
        from repro.kernels.quant_blockwise.ref import dequantize_ref
        x2d = dequantize_ref(q, scales, dtype)
    else:
        from repro.kernels.quant_blockwise.kernel import dequantize_kernel
        x2d = dequantize_kernel(q, scales, dtype, interpret=interpret)
    n = int(np.prod(shape))
    return x2d.reshape(-1)[:n].reshape(shape)
