"""Pure-jnp oracle for block-wise int8 quantization."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x2d):
    """x2d (nb, block) fp -> (q int8 (nb, block), scales fp32 (nb,))."""
    xf = x2d.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale[:, None]).astype(dtype)
