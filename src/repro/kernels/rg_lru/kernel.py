"""RG-LRU linear recurrence as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §4): Griffin's GPU kernel is a warp-parallel scan;
on TPU we run the recurrence sequentially over sequence blocks — grid
(B, W/bw, S/bs) with the sequence axis innermost — carrying the (1, bw)
hidden state in VMEM scratch.  Inside a block the recurrence over ``bs``
steps runs as a fori_loop on VREG rows (bw lanes wide), which is exactly the
shape the VPU wants; the channel axis is the parallel axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, y_ref, h_scratch, *, bs: int):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    a = a_ref[0].astype(jnp.float32)     # (bs, bw)
    b = b_ref[0].astype(jnp.float32)

    def step(t, carry):
        h = carry
        h = a[t][None, :] * h + b[t][None, :]
        y_ref[0, t, :] = h[0].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, h_scratch[...])
    h_scratch[...] = h


def rglru_scan_kernel(a, b, *, block_s: int = 256, block_w: int = 256,
                      interpret: bool = False):
    """a/b (B,S,W) -> h (B,S,W). Zero initial state (match model prefill)."""
    B, S, W = a.shape
    bs = min(block_s, S)
    bw = min(block_w, W)
    assert S % bs == 0 and W % bw == 0, (S, W, bs, bw)
    grid = (B, W // bw, S // bs)  # sequence innermost: sequential carry

    kernel = functools.partial(_rglru_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda bi, wi, si: (bi, si, wi)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, b)
