"""Jitted wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rg_lru.kernel import rglru_scan_kernel


@functools.partial(jax.jit, static_argnames=("block_s", "block_w", "interpret",
                                             "impl"))
def rglru_scan(a, b, *, block_s: int = 256, block_w: int = 256,
               interpret: bool = False, impl: str = "pallas"):
    """Linear recurrence h_t = a_t h_{t-1} + b_t over axis 1. Returns (h, h_last)."""
    if impl == "xla":
        from repro.kernels.rg_lru.ref import rglru_ref
        return rglru_ref(a, b)
    h = rglru_scan_kernel(a, b, block_s=block_s, block_w=block_w,
                          interpret=interpret)
    return h, h[:, -1, :]
