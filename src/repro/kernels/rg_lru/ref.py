"""Pure-jnp oracle for the RG-LRU linear recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t.  a/b (B,S,W) fp32; h0 (B,W) or None.

    Returns (h (B,S,W), final (B,W)).
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1, :]
