"""Mamba-2 SSD chunk scan as a Pallas TPU kernel.

TPU adaptation of the SSD algorithm (DESIGN.md §4): the GPU implementation
leans on warp-level parallel scans; on TPU we exploit the *sequential* grid
traversal instead — grid (B, H, num_chunks) with chunks innermost, carrying
the (P, N) inter-chunk state in VMEM scratch, so the whole scan is one
pallas_call with MXU matmuls for the intra-chunk quadratic term.

Inputs are pre-arranged by ops.py:
  xdt   (B, H, nc, Q, P)   x * dt
  Bm    (B, nc, Q, N)      B after conv (shared across heads, one group)
  Cm    (B, nc, Q, N)
  cums  (B, H, nc, Q)      within-chunk cumsum of dt*A
Output y (B, H, nc, Q, P) and final state (B, H, P, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, b_ref, c_ref, cums_ref, y_ref, state_out_ref, s_scratch,
                *, Q: int, P: int, N: int):
    c_idx = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        s_scratch[...] = jnp.zeros_like(s_scratch)

    xdt = xdt_ref[0, 0, 0].astype(jnp.float32)       # (Q, P)
    Bm = b_ref[0, 0].astype(jnp.float32)             # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)             # (Q, N)
    cums = cums_ref[0, 0, 0].astype(jnp.float32)     # (Q,)

    # intra-chunk: (C B^T ∘ L) @ xdt with L_ij = exp(cums_i - cums_j) tril
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    li = cums[:, None] - cums[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(iota_i >= iota_j, jnp.exp(li), 0.0)
    y_intra = jax.lax.dot(CB * L, xdt, preferred_element_type=jnp.float32)

    # inter-chunk: C_i exp(cums_i) @ S_prev^T
    s_prev = s_scratch[...]                          # (P, N)
    Cexp = Cm * jnp.exp(cums)[:, None]
    y_inter = jax.lax.dot_general(Cexp, s_prev, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (Q,P)

    y_ref[0, 0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S_new = exp(cums_last) * S_prev + xdt^T @ (B * dec_end)
    last = cums[Q - 1]
    dec_end = jnp.exp(last - cums)                   # (Q,)
    delta = jax.lax.dot_general(xdt, Bm * dec_end[:, None],
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (P,N)
    s_new = jnp.exp(last) * s_prev + delta
    s_scratch[...] = s_new

    @pl.when(c_idx == nc - 1)
    def _final():
        state_out_ref[0, 0] = s_new.astype(state_out_ref.dtype)


def ssd_scan_kernel(xdt, Bm, Cm, cums, *, interpret: bool = False):
    B, H, nc, Q, P = xdt.shape
    N = Bm.shape[-1]
    grid = (B, H, nc)
    kernel = functools.partial(_ssd_kernel, Q=Q, P=P, N=N)
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, P), xdt.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xdt, Bm, Cm, cums)
    return y, state
