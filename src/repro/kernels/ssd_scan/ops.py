"""Jitted wrapper: same signature as the model's ssd_chunked reference."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "impl"))
def ssd_scan(x, dt, A, B_in, C_in, *, chunk: int = 256, interpret: bool = False,
             impl: str = "pallas"):
    """x (B,S,H,P); dt (B,S,H) post-softplus; A (H,)<0; B_in/C_in (B,S,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)). Requires S % chunk == 0
    (ops-level padding is the caller's job; the model path handles it).
    """
    if impl == "xla":
        from repro.kernels.ssd_scan.ref import ssd_ref
        return ssd_ref(x, dt, A, B_in, C_in, chunk)

    B, S, H, P = x.shape
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    dtf = dt.astype(jnp.float32)
    dA = dtf * A.astype(jnp.float32)                       # (B,S,H)
    cums = jnp.cumsum(dA.reshape(B, nc, Q, H), axis=2)     # (B,nc,Q,H)
    cums = jnp.transpose(cums, (0, 3, 1, 2))               # (B,H,nc,Q)

    xdt = (x * dt[..., None].astype(x.dtype))              # (B,S,H,P)
    xdt = jnp.transpose(xdt.reshape(B, nc, Q, H, P), (0, 3, 1, 2, 4))

    Bm = B_in.reshape(B, nc, Q, -1)
    Cm = C_in.reshape(B, nc, Q, -1)

    y, state = ssd_scan_kernel(xdt, Bm, Cm, cums, interpret=interpret)
    y = jnp.transpose(y, (0, 2, 3, 1, 4)).reshape(B, S, H, P)
    return y, state.astype(x.dtype)
