"""Pure-jnp oracle for the SSD chunked scan: re-exports the model's
reference implementation (itself pure jnp and validated against decode)."""
from repro.models.ssm import ssd_chunked as ssd_ref  # noqa: F401
