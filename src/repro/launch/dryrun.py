import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()
# ^ MUST run before any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and record memory/cost/collective analysis (DESIGN.md,
EXPERIMENTS.md §Dry-run).

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
import traceback

import jax

jax.config.update("jax_compilation_cache_dir", "/root/repo/.jax_cache")
jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)

from repro.configs import SHAPES, TrainConfig, get_config, shape_applicable
from repro.configs.registry import ASSIGNED_ARCHS
from repro.distributed.context import DistContext
from repro.distributed.steps import (
    build_decode_step, build_prefill_step, build_train_step,
)
from repro.launch.memmodel import model_memory
from repro.launch.mesh import make_production_mesh
from repro.models import LM

HW = {
    "peak_flops": 197e12,   # bf16 per chip (TPU v5e-class)
    "hbm_bw": 819e9,        # bytes/s per chip
    "link_bw": 50e9,        # bytes/s per ICI link
    "hbm_bytes": 16e9,      # per chip
}

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(?P<rtype>\(?[a-z0-9\[\],\s{}]*?\)?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_SET_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective op; estimate wire bytes with a
    ring model (documented in EXPERIMENTS.md §Roofline)."""
    per_op: dict[str, dict] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op").lower()
        nbytes = _shape_bytes(m.group("rtype"))
        gm = _GROUP_RE.search(line)
        if gm:
            gsize = int(gm.group(2))
        else:
            gs = _GROUP_SET_RE.search(line)
            gsize = len(gs.group(1).split(",")) if gs else 2
        if op == "all-reduce":
            w = 2.0 * (gsize - 1) / gsize * nbytes
        elif op == "reduce-scatter":
            w = (gsize - 1) * nbytes           # result is the scattered shard
        elif op in ("all-gather", "all-to-all"):
            w = (gsize - 1) / gsize * nbytes
        else:  # collective-permute
            w = float(nbytes)
        d = per_op.setdefault(op, {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["result_bytes"] += nbytes
        d["wire_bytes"] += w
        wire += w
    return {"per_op": per_op, "wire_bytes_per_device": wire}


def _combine(base: dict, body: dict, units: float) -> dict:
    """total = nonloop + units * per-layer-body (clamped at >= body levels)."""
    out = {}
    for k in ("flops", "bytes", "wire"):
        delta = max(body[k] - base[k], 0.0)
        nonloop = max(base[k] - delta, 0.0)
        out[k] = nonloop + units * delta
    per_op = {}
    ops = set(base["per_op"]) | set(body["per_op"])
    for op in ops:
        b0 = base["per_op"].get(op, {"count": 0, "wire_bytes": 0.0})
        b1 = body["per_op"].get(op, {"count": 0, "wire_bytes": 0.0})
        dc = max(b1["count"] - b0["count"], 0)
        dw = max(b1["wire_bytes"] - b0["wire_bytes"], 0.0)
        per_op[op] = {"count": (b0["count"] - dc) + units * dc,
                      "wire_bytes": (b0["wire_bytes"] - dw) + units * dw}
    out["per_op"] = per_op
    return out


def _make_mesh(multi_pod: bool):
    dbg = os.environ.get("REPRO_DEBUG_MESH")
    if dbg:  # e.g. "4,8" or "2,2,8" — development-only shrink
        dims = tuple(int(x) for x in dbg.split(","))
        axes = ("pod", "data", "model")[3 - len(dims):]
        return jax.make_mesh(dims, axes), f"debug_{dbg.replace(',', 'x')}"
    mesh = make_production_mesh(multi_pod=multi_pod)
    return mesh, ("multipod_2x16x16" if multi_pod else "pod_16x16")


def _lower_compile(cfg, shape, mesh, tc, sp_decode, mode="tp", moe_impl="gspmd"):
    ctx = DistContext.create(cfg, mesh, sp_decode=sp_decode, mode=mode)
    ctx.extra["moe_impl"] = moe_impl
    lm = LM(cfg, max_seq=shape.seq_len)
    t0 = time.perf_counter()
    with mesh:
        if shape.kind == "train":
            jf, args = build_train_step(lm, tc, ctx, shape)
        elif shape.kind == "prefill":
            jf, args = build_prefill_step(lm, ctx, shape)
        else:
            jf, args = build_decode_step(lm, ctx, shape)
        lowered = jf.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
    return compiled, t_lower, t_compile


def _collect_costs(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    out = {"flops": float(ca.get("flops", 0.0)),
           "bytes": float(ca.get("bytes accessed", 0.0)),
           "wire": float(coll["wire_bytes_per_device"]),
           "per_op": coll["per_op"]}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             tc: TrainConfig | None = None, sp_decode: bool = True,
             save_hlo: bool = False, out_dir: str = "experiments/dryrun",
             tag: str = "", skip_cost_pass: bool = False,
             mode: str = "tp", moe_impl: str = "gspmd") -> dict:
    import dataclasses

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh, mesh_name = _make_mesh(multi_pod)
    res = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "applicable": ok}
    if not ok:
        res["skip_reason"] = why
        _write(res, out_dir, arch, shape_name, mesh_name, tag)
        return res

    tc = tc or TrainConfig()
    n_dev = mesh.size

    # ---- pass 1: full scanned program (proves compile; memory truth) ----
    compiled, t_lower, t_compile = _lower_compile(cfg, shape, mesh, tc,
                                                  sp_decode, mode, moe_impl)
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        mem = {"argument": int(ma.argument_size_in_bytes),
               "output": int(ma.output_size_in_bytes),
               "temp": int(ma.temp_size_in_bytes),
               "alias": int(ma.alias_size_in_bytes)}
        mem["peak"] = mem["argument"] + mem["output"] + mem["temp"] - mem["alias"]
        mem["fits_hbm"] = mem["peak"] <= HW["hbm_bytes"]
    scanned_costs = _collect_costs(compiled)
    hlo_text = compiled.as_text() if save_hlo else None
    del compiled

    # ---- pass 2: exact-cost extrapolation (XLA counts loop bodies once) ----
    if skip_cost_pass:
        costs = scanned_costs
        units = 1.0
    else:
        pat = len(cfg.block_pattern) or 1
        tc1 = dataclasses.replace(tc, microbatches=1)
        cfg1 = dataclasses.replace(cfg, num_layers=pat, exact_costs=True)
        cfg2 = dataclasses.replace(cfg, num_layers=2 * pat, exact_costs=True)
        c1, _, s1 = _lower_compile(cfg1, shape, mesh, tc1, sp_decode, mode,
                                   moe_impl)
        r1 = _collect_costs(c1)
        del c1
        c2, _, s2 = _lower_compile(cfg2, shape, mesh, tc1, sp_decode, mode,
                                   moe_impl)
        r2 = _collect_costs(c2)
        del c2
        units = cfg.num_layers / pat
        costs = _combine(r1, r2, units)
        costs["cost_pass_compile_s"] = round(s1 + s2, 2)

    ctx_mm = DistContext.create(cfg, mesh, sp_decode=sp_decode, mode=mode)
    try:
        mm = model_memory(cfg, shape, ctx_mm, tc, LM(cfg, max_seq=shape.seq_len))
    except Exception as e:  # noqa: BLE001
        mm = {"error": str(e)}
    res.update({
        "n_devices": n_dev,
        "kind": shape.kind,
        "mem_model": mm,
        "mode": mode,
        "flops_per_device": costs["flops"],
        "bytes_accessed_per_device": costs["bytes"],
        "wire_bytes_per_device": costs["wire"],
        "collectives": costs["per_op"],
        "scanned_raw": {k: scanned_costs[k] for k in ("flops", "bytes", "wire")},
        "memory": mem,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost_pass_compile_s": costs.get("cost_pass_compile_s", 0.0),
        "params": cfg.count_params(),
        "active_params": cfg.count_params(active_only=True),
        "tokens_per_step": shape.tokens_per_step,
        "tag": tag or "baseline",
        "config": {"remat": tc.remat, "microbatches": tc.microbatches,
                   "sp_decode": sp_decode},
    })
    _write(res, out_dir, arch, shape_name, mesh_name, tag)
    if save_hlo and hlo_text:
        fn = _fname(arch, shape_name, mesh_name, tag).replace(".json", ".hlo.txt")
        with open(os.path.join(out_dir, fn), "w") as f:
            f.write(hlo_text)
    return res


def _fname(arch, shape_name, mesh_name, tag):
    return f"{arch}__{shape_name}__{mesh_name}{('__' + tag) if tag else ''}.json"


def _write(res, out_dir, arch, shape_name, mesh_name, tag):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, _fname(arch, shape_name, mesh_name, tag)),
              "w") as f:
        json.dump(res, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--no-sp-decode", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--mode", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--moe-impl", default="gspmd", choices=["gspmd", "shardmap"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    tc = TrainConfig(remat=args.remat, microbatches=args.microbatches)
    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch} x {shape} x {'multipod' if mp else 'pod'}"
                try:
                    r = run_cell(arch, shape, mp, tc=tc,
                                 sp_decode=not args.no_sp_decode,
                                 save_hlo=args.save_hlo, out_dir=args.out,
                                 tag=args.tag, mode=args.mode,
                                 moe_impl=args.moe_impl)
                except Exception as e:  # noqa: BLE001
                    failures.append(label)
                    print(f"[FAIL] {label}: {e}")
                    traceback.print_exc()
                    continue
                if not r.get("applicable", True):
                    print(f"[SKIP] {label}: {r['skip_reason']}")
                    continue
                mem = r.get("memory", {})
                print(f"[OK]   {label}: {r['flops_per_device']/1e9:.1f} GF/dev, "
                      f"mem {mem.get('peak', 0)/1e9:.2f} GB "
                      f"(fits={mem.get('fits_hbm')}), "
                      f"wire {r['wire_bytes_per_device']/1e6:.1f} MB/dev, "
                      f"compile {r['compile_s']:.0f}s")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  -", f)
        raise SystemExit(1)
    print("\nDry-run complete: all cells lowered + compiled.")


if __name__ == "__main__":
    main()
