"""Analytic per-device memory model (TPU bf16 semantics).

The XLA ``memory_analysis()`` on the CPU backend overstates HBM: CPU lacks
native bf16 compute, so the backend inserts f32 promotions of weights and
caches that a TPU build never materializes (verified in the phi3 decode HLO:
f32 copies of the bf16 KV cache and of replicated attention weights).  This
model computes what the SAME sharded program needs on TPU:

    params(bf16, sharded) + optimizer(fp32 m/v/master, ZeRO)        [train]
    + activation working set (scan carries per microbatch, logits)  [train]
    + KV/state caches (bf16, sharded) + decode transients           [serve]

Used by the roofline table as ``mem_model``; the XLA number is retained as
``mem_xla`` (the compile-proof upper bound).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig


def _shard_factor(pspec, mesh_shape: dict) -> int:
    f = 1
    for entry in pspec:
        if entry is None:
            continue
        for ax in ((entry,) if isinstance(entry, str) else entry):
            f *= mesh_shape[ax]
    return f


def _tree_bytes(spec_tree, ctx_like, mesh_shape, bytes_per_el: float,
                zero1: bool = False) -> float:
    """Sum sharded bytes over a P-spec tree."""
    import jax

    from repro.models.layers import is_p

    leaves = jax.tree_util.tree_leaves(spec_tree, is_leaf=is_p)
    total = 0.0
    data_axes = ctx_like.rules.get("batch") or ()
    if isinstance(data_axes, str):
        data_axes = (data_axes,)
    dsize = int(np.prod([mesh_shape[a] for a in data_axes])) if data_axes else 1
    for p in leaves:
        n = float(np.prod(p.shape))
        ps = list(ctx_like.pspec(p.axes))
        f = _shard_factor(ps, mesh_shape)
        if zero1 and dsize > 1:
            # extra data-axis sharding on the first divisible unsharded dim
            ps_padded = ps + [None] * (len(p.shape) - len(ps))
            for i, dim in enumerate(p.shape):
                if ps_padded[i] is None and dim % dsize == 0:
                    f *= dsize
                    break
        total += n / f * bytes_per_el
    return total


def model_memory(cfg: ModelConfig, shape: ShapeConfig, ctx, tc: TrainConfig,
                 lm) -> dict:
    mesh_shape = dict(ctx.mesh.shape)
    n_data = int(np.prod([mesh_shape[a] for a in
                          (ctx.rules.get("batch") or ())])) or 1
    spec = lm.spec()
    params = _tree_bytes(spec, ctx, mesh_shape, 2.0)          # bf16
    out = {"params": params}

    d, L, Vp = cfg.d_model, cfg.num_layers, cfg.padded_vocab
    vshard = mesh_shape.get("model", 1)

    if shape.kind == "train":
        if ctx.mode == "fsdp":
            # params/opt/grads all share the fully-sharded layout
            from repro.distributed.sharding import fsdp_sharding
            from repro.models.layers import is_p
            import jax as _j
            leaves = _j.tree_util.tree_leaves(spec, is_leaf=is_p)
            tot = 0.0
            for p in leaves:
                f = _shard_factor(
                    fsdp_sharding(ctx, p.axes, p.shape).spec, mesh_shape)
                tot += float(np.prod(p.shape)) / f
            out["params"] = tot * 2.0
            out["opt"] = tot * 12.0
            out["grads"] = tot * 4.0
        else:
            out["opt"] = _tree_bytes(spec, ctx, mesh_shape, 12.0, zero1=True)
            out["grads"] = out["params"] * 2.0  # fp32, sharded like params
        mb_tokens = shape.tokens_per_step / max(tc.microbatches, 1) / n_data
        resid = L * mb_tokens * d * 2.0                        # scan carries
        logits = mb_tokens * Vp / vshard * 4.0                 # fp32 xent
        layer_ws = mb_tokens * max(cfg.d_ff or d, 4 * d) * 4.0 * 4
        out["activations"] = resid + logits + layer_ws
    else:
        import jax as _jax
        B = shape.global_batch
        cache = 0.0
        acache = _jax.eval_shape(lambda: lm.init_cache(B, shape.seq_len))
        ax_tree = lm.cache_axes(ctx)
        flat_c = _jax.tree_util.tree_leaves(acache)
        flat_a = _jax.tree_util.tree_leaves(
            ax_tree, is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        from repro.distributed.sharding import batch_pspec
        b = batch_pspec(ctx, B)
        for leaf, axes in zip(flat_c, flat_a):
            ps = []
            used = set()
            for ax in axes:
                v = b if ax == "batch" else (ctx.rules.get(ax) if ax else None)
                if isinstance(v, (tuple, list)):
                    v = tuple(a for a in v if a not in used) or None
                if isinstance(v, str) and v in used:
                    v = None
                if v is not None:
                    used.update((v,) if isinstance(v, str) else v)
                ps.append(v)
            f = _shard_factor(ps, mesh_shape)
            cache += float(np.prod(leaf.shape)) * leaf.dtype.itemsize / f
        out["cache"] = cache * 2.0  # in+out buffers (donation halves on TPU)
        toks = (shape.seq_len if shape.kind == "prefill" else 1)
        out["activations"] = (B / n_data) * toks * max(d * 6, 1) * 2.0 + \
            (B / n_data) * Vp / vshard * 4.0

    out["total"] = float(sum(out.values()))
    out["fits_hbm"] = out["total"] <= 16e9
    return out
