"""Production mesh builders (TPU v5e target: 16x16 = 256 chips per pod)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for subprocess-based sharding tests (8 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
