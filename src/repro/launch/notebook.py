"""Run a Jupyter notebook file under the context-aware migration runtime.

    PYTHONPATH=src python -m repro.launch.notebook path/to/nb.ipynb \
        --sessions 3 --remote-speedup 10 --policy block \
        [--bandwidth 1e9] [--latency 0.5] [--codec zlib] [--report out.json]

Cells execute for real (exec against the session namespace); timing follows
the paper's forced-speedup protocol when cells carry a
``metadata.repro.cost``, else measured wall time scaled by the env speedup.
Prints the decision/migration report and writes the annotated notebook back
(explainability annotations land in ``metadata.repro.annotations``).
"""
from __future__ import annotations

import argparse
import json

from repro.core import (
    ExecutionEnvironment, HybridRuntime, Notebook, StateReducer,
)


def run_notebook(path: str, *, sessions: int = 3, remote_speedup: float = 10.0,
                 policy: str = "block", use_knowledge: bool = True,
                 bandwidth: float = 1e9, latency: float = 0.5,
                 codec: str = "zlib") -> dict:
    with open(path) as f:
        nb = Notebook.from_ipynb(json.load(f))
    rt = HybridRuntime(
        nb,
        envs={"local": ExecutionEnvironment("local"),
              "remote": ExecutionEnvironment("remote", speedup=remote_speedup)},
        reducer=StateReducer(codec=codec),
        policy=policy, use_knowledge=use_knowledge,
        bandwidth=bandwidth, latency=latency)

    code = [c for c in nb.cells if c.cell_type == "code"]
    for _ in range(sessions):
        for cell in code:
            rt.run_cell(cell.cell_id)
    rt.close()

    local_only = sessions * sum(
        c.cost if c.cost is not None else 0.0 for c in code)
    report = {
        "notebook": nb.name,
        "sessions": sessions,
        "policy": policy,
        "modeled_seconds": rt.clock.now(),
        "local_only_seconds": local_only or None,
        "speedup_vs_local": (local_only / rt.clock.now()
                             if local_only and rt.clock.now() else None),
        "migrations": rt.migrations,
        "migrated_bytes": sum(m.nbytes for m in rt.engine.log),
        "decisions": {c.cell_id: c.annotations[-1] if c.annotations else None
                      for c in code},
        "provenance_records": len(rt.kb.provenance),
    }
    return report, nb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("notebook")
    ap.add_argument("--sessions", type=int, default=3)
    ap.add_argument("--remote-speedup", type=float, default=10.0)
    ap.add_argument("--policy", choices=["single", "block"], default="block")
    ap.add_argument("--no-knowledge", action="store_true")
    ap.add_argument("--bandwidth", type=float, default=1e9)
    ap.add_argument("--latency", type=float, default=0.5)
    ap.add_argument("--codec", default="zlib")
    ap.add_argument("--report", default=None)
    ap.add_argument("--write-annotated", default=None,
                    help="write the notebook back with decision annotations")
    args = ap.parse_args()

    report, nb = run_notebook(
        args.notebook, sessions=args.sessions,
        remote_speedup=args.remote_speedup, policy=args.policy,
        use_knowledge=not args.no_knowledge, bandwidth=args.bandwidth,
        latency=args.latency, codec=args.codec)

    print(json.dumps({k: v for k, v in report.items() if k != "decisions"},
                     indent=2))
    print("\nper-cell decisions:")
    for cid, note in report["decisions"].items():
        print(f"  {cid[:8]}: {note}")
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2)
    if args.write_annotated:
        with open(args.write_annotated, "w") as f:
            json.dump(nb.to_ipynb(), f, indent=1)


if __name__ == "__main__":
    main()
