"""Run a Jupyter notebook file under the context-aware migration runtime.

    PYTHONPATH=src python -m repro.launch.notebook path/to/nb.ipynb \
        --sessions 3 --remote-speedup 10 --policy block \
        [--model frequency|markov|recency|ensemble] \
        [--bandwidth 1e9] [--latency 0.5] [--codec zlib] [--report out.json] \
        [--env tpu-mesh:40:1] [--link local:tpu-mesh:1e8:1.0] [--pipeline] \
        [--fleet 4]

Cells execute for real (exec against the session namespace); timing follows
the paper's forced-speedup protocol when cells carry a
``metadata.repro.cost``, else measured wall time scaled by the env speedup.

By default this is the paper's local/remote dyad.  ``--env name:speedup[:cap]``
(repeatable) registers extra environments and ``--link a:b:bw:lat`` gives a
pair its own transfer cost; ``--policy cost`` scores every env per cell.
``--fleet N`` replays N concurrent sessions of the notebook through the
SessionScheduler over the shared fabric (per-env capacity, queueing stats).

Prints the decision/migration report and writes the annotated notebook back
(explainability annotations land in ``metadata.repro.annotations``).
"""
from __future__ import annotations

import argparse
import json

from repro.core import (
    EnvironmentRegistry, ExecutionEnvironment, HybridRuntime, Notebook,
    SessionScheduler, StateReducer,
)


def build_registry(*, remote_speedup: float = 10.0, bandwidth: float = 1e9,
                   latency: float = 0.5, extra_envs=(), links=()) -> EnvironmentRegistry:
    """Two-env default plus any ``name:speedup[:capacity]`` extras and
    ``a:b:bandwidth:latency`` link overrides."""
    reg = EnvironmentRegistry(default_bandwidth=bandwidth,
                              default_latency=latency)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    reg.register(ExecutionEnvironment("remote", speedup=remote_speedup),
                 capacity=4)
    for spec in extra_envs:
        parts = spec.split(":")
        name = parts[0]
        speedup = float(parts[1]) if len(parts) > 1 else 1.0
        cap = int(parts[2]) if len(parts) > 2 else 1
        reg.register(ExecutionEnvironment(name, speedup=speedup), capacity=cap)
    for spec in links:
        a, b, bw, lat = spec.split(":")
        for end in (a, b):
            if end not in reg:
                raise ValueError(
                    f"--link {spec!r}: unknown environment {end!r} "
                    f"(registered: {', '.join(reg.names())})")
        reg.connect(a, b, bandwidth=float(bw), latency=float(lat))
    return reg


def run_notebook(path: str, *, sessions: int = 3, remote_speedup: float = 10.0,
                 policy: str = "block", use_knowledge: bool = True,
                 bandwidth: float = 1e9, latency: float = 0.5,
                 codec: str = "zlib", extra_envs=(), links=(),
                 pipeline: bool = False, fleet: int = 0,
                 model: str | None = None) -> dict:
    with open(path) as f:
        nb = Notebook.from_ipynb(json.load(f))
    registry = build_registry(remote_speedup=remote_speedup,
                              bandwidth=bandwidth, latency=latency,
                              extra_envs=extra_envs, links=links)
    code = [c for c in nb.cells if c.cell_type == "code"]

    if fleet:
        sched = SessionScheduler(registry)
        # plan by index: re-parsed notebooks regenerate ids for cells that
        # have none in the file, so cell_ids don't survive a second parse
        plan = [i for i, c in enumerate(nb.cells)
                if c.cell_type == "code"] * sessions
        for _ in range(fleet):
            with open(path) as f:
                session_nb = Notebook.from_ipynb(json.load(f))
            sched.add_notebook(session_nb, plan=plan,
                               reducer=StateReducer(codec=codec),
                               policy=policy, use_knowledge=use_knowledge,
                               pipeline=pipeline, model=model)
        rep = sched.run()
        report = {
            "notebook": nb.name,
            "fleet": fleet,
            "sessions_each": sessions,
            "policy": policy,
            "model": model or "frequency",
            "makespan": rep.makespan,
            "total_queue_wait": rep.total_queue_wait,
            "queue_events": rep.queue_events,
            "env_utilization": rep.env_utilization,
            "prediction_hit_rate": rep.prediction_hit_rate,
            "predicted_env_seconds": rep.predicted_env_seconds,
            "actual_env_seconds": rep.actual_env_seconds,
            "per_session": [
                {"session": s.session[:8], "makespan": s.makespan,
                 "queue_wait": s.queue_wait, "migrations": s.migrations,
                 "prediction_hit_rate": s.prediction_hit_rate}
                for s in rep.sessions],
        }
        return report, nb

    rt = HybridRuntime(
        nb, registry=registry, reducer=StateReducer(codec=codec),
        policy=policy, use_knowledge=use_knowledge, pipeline=pipeline,
        model=model)

    for _ in range(sessions):
        for cell in code:
            rt.run_cell(cell.cell_id)
    rt.close()

    local_only = sessions * sum(
        c.cost if c.cost is not None else 0.0 for c in code)
    report = {
        "notebook": nb.name,
        "sessions": sessions,
        "policy": policy,
        "environments": registry.names(),
        "modeled_seconds": rt.clock.now(),
        "local_only_seconds": local_only or None,
        "speedup_vs_local": (local_only / rt.clock.now()
                             if local_only and rt.clock.now() else None),
        "model": rt.context.model.name,
        "migrations": rt.migrations,
        "migrated_bytes": sum(m.nbytes for m in rt.engine.log),
        "prefetch_hits": getattr(rt.engine, "prefetch_hits", 0),
        "prefetch_wasted_bytes": getattr(rt.engine,
                                         "prefetch_wasted_bytes", 0),
        "prediction_hit_rate": rt.prediction_hit_rate,
        "decisions": {c.cell_id: c.annotations[-1] if c.annotations else None
                      for c in code},
        "provenance_records": len(rt.kb.provenance),
    }
    return report, nb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("notebook")
    ap.add_argument("--sessions", type=int, default=3)
    ap.add_argument("--remote-speedup", type=float, default=10.0)
    ap.add_argument("--policy",
                    choices=["single", "block", "cost", "horizon"],
                    default="block")
    ap.add_argument("--model",
                    choices=["frequency", "markov", "recency", "ensemble"],
                    default=None,
                    help="interaction model (default: the paper's "
                         "Algorithm-1 frequency miner)")
    ap.add_argument("--no-knowledge", action="store_true")
    ap.add_argument("--bandwidth", type=float, default=1e9)
    ap.add_argument("--latency", type=float, default=0.5)
    ap.add_argument("--codec", default="zlib")
    ap.add_argument("--env", action="append", default=[],
                    help="extra environment: name:speedup[:capacity]")
    ap.add_argument("--link", action="append", default=[],
                    help="pair link override: a:b:bandwidth:latency")
    ap.add_argument("--pipeline", action="store_true",
                    help="pipelined engine (prefetch overlaps execution)")
    ap.add_argument("--fleet", type=int, default=0,
                    help="run N concurrent sessions through the scheduler")
    ap.add_argument("--report", default=None)
    ap.add_argument("--write-annotated", default=None,
                    help="write the notebook back with decision annotations")
    args = ap.parse_args()

    report, nb = run_notebook(
        args.notebook, sessions=args.sessions,
        remote_speedup=args.remote_speedup, policy=args.policy,
        use_knowledge=not args.no_knowledge, bandwidth=args.bandwidth,
        latency=args.latency, codec=args.codec, extra_envs=args.env,
        links=args.link, pipeline=args.pipeline, fleet=args.fleet,
        model=args.model)

    print(json.dumps({k: v for k, v in report.items() if k != "decisions"},
                     indent=2))
    if "decisions" in report:
        print("\nper-cell decisions:")
        for cid, note in report["decisions"].items():
            print(f"  {cid[:8]}: {note}")
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2)
    if args.write_annotated:
        with open(args.write_annotated, "w") as f:
            json.dump(nb.to_ipynb(), f, indent=1)


if __name__ == "__main__":
    main()
