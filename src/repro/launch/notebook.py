"""Run a Jupyter notebook file under the context-aware migration runtime.

    PYTHONPATH=src python -m repro.launch.notebook path/to/nb.ipynb \
        --sessions 3 --remote-speedup 10 --policy block \
        [--model frequency|markov|recency|ensemble] \
        [--bandwidth 1e9] [--latency 0.5] [--codec zlib] [--report out.json] \
        [--env tpu-mesh:40:1] [--link local:tpu-mesh:1e8:1.0] [--pipeline] \
        [--fleet 4] [--arrivals 0.2] [--think-time 5] [--seed 0] \
        [--fail-env remote:30] [--autoscale] [--recovery checkpoint] \
        [--transport loopback|socket] \
        [--replicate] [--trickle-rate 50MB/s] [--liveness on|off] \
        [--replicas K] [--race on|off] \
        [--price remote:3.0] [--hazard spot:6/h] [--egress remote:local:0.09] \
        [--slo 30] [--workload gpu-training|remote-sensing]

``--transport socket`` is the two-process demo: the remote env runs as a
child Python process and every migration genuinely streams CRC-framed
chunk traffic over TCP (cells execute in the child; results round-trip
home).  The default ``loopback`` keeps the paper's in-process simulated
movement.

``--replicate`` (fleet only) turns on background delta replication: while
the user "thinks" between cells, each session trickles its dirty state to
the most likely next environments at ``--trickle-rate`` bytes/second, so a
later migration ships only the residual delta.  ``--liveness off`` disables
the dead-name pruning that otherwise bounds what trickles and what
full-state return trips carry.

Cost plane: ``--price env:dollars_per_hour`` (repeatable) puts a price tag
on an env, ``--hazard env:rate[/h|/s]`` (fleet only, repeatable) marks it
as spot capacity with a seeded preemption hazard, ``--egress a:b:$per_gb``
(repeatable) prices data leaving a link, and ``--slo seconds`` states the
per-cell latency SLO.  Giving any of ``--price``/``--egress``/``--slo``
switches the horizon policy's DP to minimize *expected dollars subject to
the SLO* instead of seconds (``--hazard`` alone keeps the seconds
objective, so a spot fleet can be measured under both).  ``--workload
gpu-training|remote-sensing`` runs a built-in synthetic notebook family
instead of an .ipynb file.

``--replicas K`` (fleet only) turns on the replica plane: each session
keeps K follower namespaces converged during think time, so a primary
failure *promotes* the most-converged follower and replays only the
unconverged tail — zero cells when it had caught up — instead of paying a
checkpoint restore or a full rerun.  ``--race on`` adds first-result-wins
cell racing on top of the converged followers.  ``--replicas 0`` (the
default) is today's behavior exactly.

Cells execute for real (exec against the session namespace); timing follows
the paper's forced-speedup protocol when cells carry a
``metadata.repro.cost``, else measured wall time scaled by the env speedup.

By default this is the paper's local/remote dyad.  ``--env
name:speedup[:capacity[:down]]`` (repeatable) registers extra environments
(``down`` marks burst capacity the autoscaler may bring up) and ``--link
a:b:bw:lat`` gives a pair its own transfer cost; ``--policy cost`` scores
every env per cell.  ``--fleet N`` replays N concurrent sessions of the
notebook through the event-driven SessionScheduler over the shared fabric;
``--arrivals``/``--think-time`` draw a seeded Poisson workload trace,
``--fail-env name:time[:recover_after]`` kills an env mid-run (recovery via
``--recovery checkpoint|rerun``), and ``--autoscale`` lets the fleet
provision/cull the ``down`` envs from queue telemetry.

Prints the decision/migration report and writes the annotated notebook back
(explainability annotations land in ``metadata.repro.annotations``).
"""
from __future__ import annotations

import argparse
import json

from repro.core import (
    AutoscalePolicy, EnvironmentRegistry, ExecutionEnvironment, HybridRuntime,
    Notebook, SessionScheduler, StateReducer, WorkloadTrace,
)


def parse_env_spec(spec: str) -> tuple[str, float, int, str]:
    """``name:speedup[:capacity[:down]]`` -> (name, speedup, capacity,
    status); raises ValueError with a user-facing message on bad input."""
    parts = spec.split(":")
    name = parts[0]
    if not name:
        raise ValueError(f"--env {spec!r}: empty environment name")
    try:
        speedup = float(parts[1]) if len(parts) > 1 else 1.0
    except ValueError:
        raise ValueError(
            f"--env {spec!r}: speedup {parts[1]!r} is not a number "
            f"(expected name:speedup[:capacity[:down]])") from None
    try:
        cap = int(parts[2]) if len(parts) > 2 else 1
    except ValueError:
        raise ValueError(
            f"--env {spec!r}: capacity {parts[2]!r} is not an integer "
            f"(expected name:speedup[:capacity[:down]])") from None
    status = "up"
    if len(parts) > 3:
        if parts[3] not in ("up", "down"):
            raise ValueError(
                f"--env {spec!r}: status {parts[3]!r} must be 'up' or "
                f"'down' (down = burst capacity for --autoscale)")
        status = parts[3]
    return name, speedup, cap, status


def parse_link_spec(spec: str) -> tuple[str, str, float, float]:
    """``a:b:bandwidth:latency`` -> parts; friendly errors on bad shape."""
    parts = spec.split(":")
    if len(parts) != 4:
        raise ValueError(
            f"--link {spec!r}: expected a:b:bandwidth:latency "
            f"(got {len(parts)} field(s))")
    a, b, bw, lat = parts
    try:
        return a, b, float(bw), float(lat)
    except ValueError:
        raise ValueError(
            f"--link {spec!r}: bandwidth/latency must be numbers "
            f"(got {bw!r}, {lat!r})") from None


def parse_fail_spec(spec: str) -> tuple[str, float, float | None]:
    """``env:time[:recover_after]`` -> (env, at, recover_after|None)."""
    parts = spec.split(":")
    if len(parts) not in (2, 3):
        raise ValueError(
            f"--fail-env {spec!r}: expected env:time[:recover_after]")
    try:
        at = float(parts[1])
        rec = float(parts[2]) if len(parts) == 3 else None
    except ValueError:
        raise ValueError(
            f"--fail-env {spec!r}: time/recover_after must be numbers") \
            from None
    return parts[0], at, rec


_RATE_UNITS = {"": 1.0, "B": 1.0, "KB": 1e3, "MB": 1e6, "GB": 1e9}


def parse_rate_spec(spec: str) -> float:
    """``--trickle-rate`` value -> bytes/second.  Accepts a plain number
    (bytes/s) or a number with a KB/MB/GB suffix and an optional ``/s``
    (``50MB/s``, ``1.5GB``); friendly errors on anything else."""
    s = spec.strip()
    body = s[:-2] if s.upper().endswith("/S") else s
    num = body.rstrip("BKMGbkmg")
    unit = body[len(num):].upper()
    if unit not in _RATE_UNITS:
        raise ValueError(
            f"--trickle-rate {spec!r}: unknown unit {unit!r} "
            f"(expected B, KB, MB or GB, e.g. 50MB/s)")
    try:
        rate = float(num) * _RATE_UNITS[unit]
    except ValueError:
        raise ValueError(
            f"--trickle-rate {spec!r}: {num!r} is not a number "
            f"(expected e.g. 50MB/s, 1e6, 200KB)") from None
    if rate <= 0:
        raise ValueError(
            f"--trickle-rate {spec!r}: rate must be positive")
    return rate


def parse_price_spec(spec: str) -> tuple[str, float]:
    """``env:dollars_per_hour`` -> (env, price); friendly errors."""
    parts = spec.split(":")
    if len(parts) != 2 or not parts[0]:
        raise ValueError(
            f"--price {spec!r}: expected env:dollars_per_hour "
            f"(e.g. remote:3.0)")
    try:
        price = float(parts[1])
    except ValueError:
        raise ValueError(
            f"--price {spec!r}: {parts[1]!r} is not a number "
            f"(dollars per hour, e.g. remote:3.0)") from None
    if price < 0:
        raise ValueError(f"--price {spec!r}: price must be >= 0")
    return parts[0], price


def parse_hazard_spec(spec: str) -> tuple[str, float]:
    """``env:rate[/h|/s]`` -> (env, preemptions per *second*).  The rate
    defaults to per-hour — ``spot:6/h`` (or just ``spot:6``) is one
    expected preemption every 10 minutes; ``/s`` gives it per-second."""
    parts = spec.split(":")
    if len(parts) != 2 or not parts[0]:
        raise ValueError(
            f"--hazard {spec!r}: expected env:rate[/h|/s] (e.g. spot:6/h)")
    body = parts[1].strip()
    per_second = False
    if body.lower().endswith("/s"):
        per_second, body = True, body[:-2]
    elif body.lower().endswith("/h"):
        body = body[:-2]
    try:
        rate = float(body)
    except ValueError:
        raise ValueError(
            f"--hazard {spec!r}: {body!r} is not a number "
            f"(preemption rate, e.g. spot:6/h or spot:0.002/s)") from None
    if rate < 0:
        raise ValueError(f"--hazard {spec!r}: rate must be >= 0")
    return parts[0], rate if per_second else rate / 3600.0


def parse_egress_spec(spec: str) -> tuple[str, str, float]:
    """``a:b:dollars_per_gb`` -> (src, dst, per_gb); friendly errors."""
    parts = spec.split(":")
    if len(parts) != 3 or not parts[0] or not parts[1]:
        raise ValueError(
            f"--egress {spec!r}: expected src:dst:dollars_per_gb "
            f"(e.g. remote:local:0.09)")
    try:
        per_gb = float(parts[2])
    except ValueError:
        raise ValueError(
            f"--egress {spec!r}: {parts[2]!r} is not a number "
            f"(dollars per GB, e.g. remote:local:0.09)") from None
    if per_gb < 0:
        raise ValueError(f"--egress {spec!r}: egress price must be >= 0")
    return parts[0], parts[1], per_gb


def build_registry(*, remote_speedup: float = 10.0, bandwidth: float = 1e9,
                   latency: float = 0.5, extra_envs=(), links=(),
                   cold_start: float = 5.0,
                   idle_timeout: float = 60.0,
                   transport: str = "loopback",
                   prices=(), hazards=(), egress=()) -> EnvironmentRegistry:
    """Two-env default plus any ``name:speedup[:capacity[:down]]`` extras
    and ``a:b:bandwidth:latency`` link overrides.  ``down`` envs get the
    fleet ``cold_start``/``idle_timeout`` knobs — they're the autoscaler's
    burst pool.  ``transport="socket"`` is the two-process demo: the remote
    env becomes a real child Python process (SubprocessEnv) and every
    migration streams wire frames over TCP."""
    reg = EnvironmentRegistry(default_bandwidth=bandwidth,
                              default_latency=latency)
    reg.register(ExecutionEnvironment("local"), home=True, capacity=8)
    if transport == "socket":
        from repro.core.transport import SubprocessEnv
        reg.register(SubprocessEnv("remote", speedup=remote_speedup),
                     capacity=4)
    else:
        reg.register(ExecutionEnvironment("remote", speedup=remote_speedup),
                     capacity=4)
    for spec in extra_envs:
        name, speedup, cap, status = parse_env_spec(spec)
        if name in reg:
            raise ValueError(
                f"--env {spec!r}: duplicate environment name {name!r} "
                f"(registered: {', '.join(reg.names())})")
        kw = {}
        if status == "down":
            kw = {"status": "down", "cold_start": cold_start,
                  "idle_timeout": idle_timeout}
        reg.register(ExecutionEnvironment(name, speedup=speedup, **kw),
                     capacity=cap)
    for spec in links:
        a, b, bw, lat = parse_link_spec(spec)
        for end in (a, b):
            if end not in reg:
                raise ValueError(
                    f"--link {spec!r}: unknown environment {end!r} "
                    f"(registered: {', '.join(reg.names())})")
        reg.connect(a, b, bandwidth=bw, latency=lat)
    # cost plane: env price tags, spot preemption hazards, link egress
    for spec in prices:
        name, price = parse_price_spec(spec)
        if name not in reg:
            raise ValueError(
                f"--price {spec!r}: unknown environment {name!r} "
                f"(registered: {', '.join(reg.names())})")
        reg[name].price_per_hour = price
    for spec in hazards:
        name, rate = parse_hazard_spec(spec)
        if name not in reg:
            raise ValueError(
                f"--hazard {spec!r}: unknown environment {name!r} "
                f"(registered: {', '.join(reg.names())})")
        if name == reg.home:
            raise ValueError(
                f"--hazard {spec!r}: the home environment cannot be "
                f"preempted (sessions live there)")
        reg[name].hazard_rate = rate
    for spec in egress:
        a, b, per_gb = parse_egress_spec(spec)
        for end in (a, b):
            if end not in reg:
                raise ValueError(
                    f"--egress {spec!r}: unknown environment {end!r} "
                    f"(registered: {', '.join(reg.names())})")
        reg.set_egress(a, b, per_gb)
    return reg


def run_notebook(path: str, *, sessions: int = 3, remote_speedup: float = 10.0,
                 policy: str = "block", use_knowledge: bool = True,
                 bandwidth: float = 1e9, latency: float = 0.5,
                 codec: str = "zlib", extra_envs=(), links=(),
                 pipeline: bool = False, fleet: int = 0,
                 model: str | None = None,
                 arrivals: float = 0.0, think_time: float = 0.0,
                 seed: int = 0, fail_envs=(), autoscale: bool = False,
                 recovery: str | None = None,
                 checkpoint_interval: float = 30.0,
                 transport: str = "loopback",
                 replicate: bool = False, trickle_rate: float = 50e6,
                 liveness: bool = True, replicas: int = 0,
                 race: bool = False, prices=(), hazards=(), egress=(),
                 slo: float | None = None,
                 workload: str | None = None) -> dict:
    def load_notebook() -> Notebook:
        if workload is not None:
            from repro.core import (gpu_training_notebook,
                                    remote_sensing_notebook)
            factory = {"gpu-training": gpu_training_notebook,
                       "remote-sensing": remote_sensing_notebook}[workload]
            return factory()
        with open(path) as f:
            return Notebook.from_ipynb(json.load(f))

    nb = load_notebook()
    # any priced dimension switches the placement objective to expected
    # dollars under the SLO; --hazard alone keeps the seconds objective so
    # a spot fleet can be measured under both
    objective = "dollars" if (prices or egress or slo is not None) \
        else "seconds"
    if transport == "socket":
        if fleet:
            raise ValueError(
                "--transport socket is the two-process demo mode and is "
                "incompatible with --fleet (the fleet plane marks env "
                "transports declaratively instead)")
        # Algorithm-2 probing snapshots the env namespace, which for a
        # subprocess env lives in the child — knowledge probing stays off
        use_knowledge = False
    registry = build_registry(remote_speedup=remote_speedup,
                              bandwidth=bandwidth, latency=latency,
                              extra_envs=extra_envs, links=links,
                              transport=transport, prices=prices,
                              hazards=hazards, egress=egress)
    code = [c for c in nb.cells if c.cell_type == "code"]

    if hazards and not fleet:
        raise ValueError(
            "--hazard needs --fleet: preemptions are injected through the "
            "scheduler's failure machinery (try --fleet 2 --recovery "
            "checkpoint)")

    if replicate and not fleet:
        raise ValueError(
            "--replicate needs --fleet: think-time trickling runs as a "
            "background process on the scheduler's event loop")
    if replicas and not fleet:
        raise ValueError(
            "--replicas needs --fleet: follower convergence runs as a "
            "background process on the scheduler's event loop")

    if fleet:
        sched = SessionScheduler(registry)
        if replicate:
            sched.enable_replication(rate=trickle_rate, liveness=liveness)
        if replicas:
            sched.enable_replicas(replicas, race=race)
        if recovery:
            sched.enable_recovery(recovery, interval=checkpoint_interval)
        if autoscale:
            pool = [n for n, e in registry.envs().items()
                    if e.status == "down"]
            if not pool:
                raise ValueError(
                    "--autoscale needs at least one burst env "
                    "(--env name:speedup:capacity:down)")
            sched.enable_autoscale(AutoscalePolicy(pool))
        for env, at, rec in fail_envs:
            sched.inject_failure(env, at, recover_after=rec)
        # plan by index: re-parsed notebooks regenerate ids for cells that
        # have none in the file, so cell_ids don't survive a second parse
        plan = [i for i, c in enumerate(nb.cells)
                if c.cell_type == "code"] * sessions
        for _ in range(fleet):
            session_nb = load_notebook()
            sched.add_notebook(session_nb, plan=plan,
                               reducer=StateReducer(codec=codec),
                               policy=policy, use_knowledge=use_knowledge,
                               pipeline=pipeline, model=model,
                               objective=objective, slo=slo)
        if any(e.hazard_rate > 0 for e in registry.envs().values()):
            sched.enable_spot_hazards(seed=seed)
        if arrivals or think_time:
            sched.set_workload(WorkloadTrace.poisson(
                fleet, rate=arrivals, think_mean=think_time,
                cells_per_session=len(plan), seed=seed))
        rep = sched.run()
        report = {
            "notebook": nb.name,
            "fleet": fleet,
            "sessions_each": sessions,
            "policy": policy,
            "model": model or "frequency",
            "makespan": rep.makespan,
            "total_queue_wait": rep.total_queue_wait,
            "total_think_time": rep.total_think_time,
            "queue_events": rep.queue_events,
            "env_utilization": rep.env_utilization,
            "prediction_hit_rate": rep.prediction_hit_rate,
            "predicted_env_seconds": rep.predicted_env_seconds,
            "actual_env_seconds": rep.actual_env_seconds,
            "failures": rep.failures,
            "recoveries": rep.recoveries,
            "checkpoints": rep.checkpoints,
            "checkpoint_bytes": rep.checkpoint_bytes,
            "restored_bytes": rep.restored_bytes,
            "scale_events": rep.scale_events,
            "lifecycle_events": rep.lifecycle_events,
            "replicate": replicate,
            "trickled_bytes": rep.trickled_bytes,
            "trickle_claimed_bytes": rep.trickle_claimed_bytes,
            "wasted_speculation_bytes": rep.wasted_speculation_bytes,
            "replicas": replicas,
            "replicated_bytes": rep.replicated_bytes,
            "replica_shared_bytes": rep.replica_shared_bytes,
            "promotions": rep.promotions,
            "races": rep.races,
            "race_waste_seconds": rep.race_waste_seconds,
            "objective": objective,
            "slo": slo,
            "total_dollars": rep.total_dollars,
            "compute_dollars": rep.compute_dollars,
            "egress_dollars": rep.egress_dollars,
            "preemptions": rep.preemptions,
            "slo_attainment": rep.slo_attainment,
            "per_session": [
                {"session": s.session[:12], "makespan": s.makespan,
                 "arrival": s.arrival, "think_time": s.think_time,
                 "queue_wait": s.queue_wait, "migrations": s.migrations,
                 "recoveries": s.recoveries,
                 "dollars": s.dollars,
                 "slo_attainment": s.slo_attainment,
                 "trickled_bytes": s.trickled_bytes,
                 "trickle_claimed_bytes": s.trickle_claimed_bytes,
                 "replica_lag": s.replica_lag,
                 "promotions": s.promotions,
                 "races": s.races, "race_wins": s.race_wins,
                 "race_waste_seconds": s.race_waste_seconds,
                 "prediction_hit_rate": s.prediction_hit_rate}
                for s in rep.sessions],
        }
        return report, nb

    rt = HybridRuntime(
        nb, registry=registry, reducer=StateReducer(codec=codec),
        policy=policy, use_knowledge=use_knowledge, pipeline=pipeline,
        model=model, objective=objective, slo=slo)

    try:
        for _ in range(sessions):
            for cell in code:
                rt.run_cell(cell.cell_id)
    finally:
        rt.close()
        for env in registry.envs().values():
            if hasattr(env, "close"):      # tear down subprocess envs
                env.close()

    local_only = sessions * sum(
        c.cost if c.cost is not None else 0.0 for c in code)
    report = {
        "notebook": nb.name,
        "sessions": sessions,
        "policy": policy,
        "environments": registry.names(),
        "modeled_seconds": rt.clock.now(),
        "local_only_seconds": local_only or None,
        "speedup_vs_local": (local_only / rt.clock.now()
                             if local_only and rt.clock.now() else None),
        "model": rt.context.model.name,
        "migrations": rt.migrations,
        "migrated_bytes": sum(m.nbytes for m in rt.engine.log),
        "transport": transport,
        "wire_frames": sum(m.wire_frames for m in rt.engine.log),
        "transfer_wall_seconds": sum(m.wall_seconds for m in rt.engine.log),
        "prefetch_hits": getattr(rt.engine, "prefetch_hits", 0),
        "prefetch_wasted_bytes": getattr(rt.engine,
                                         "prefetch_wasted_bytes", 0),
        "prediction_hit_rate": rt.prediction_hit_rate,
        "objective": objective,
        "slo": slo,
        "compute_dollars": sum(
            getattr(registry[e], "price_per_hour", 0.0) * sec / 3600.0
            for e, sec in rt.exec_env_seconds.items() if e in registry),
        "egress_dollars": sum(
            registry.transfer_dollars(m.src, m.dst, m.nbytes)
            for m in rt.engine.log),
        "slo_attainment": (
            sum(1 for lat in rt.cell_latencies if lat <= slo + 1e-9)
            / len(rt.cell_latencies)
            if slo is not None and rt.cell_latencies else 1.0),
        "decisions": {c.cell_id: c.annotations[-1] if c.annotations else None
                      for c in code},
        "provenance_records": len(rt.kb.provenance),
    }
    return report, nb


class _OnceAction(argparse.Action):
    """Reject a flag given more than once (a silently-overridden repeat of
    ``--replicas`` is almost always a typo in a long fleet command line)."""

    def __call__(self, parser, namespace, values, option_string=None):
        if getattr(namespace, f"_seen_{self.dest}", False):
            parser.error(f"{option_string} given more than once "
                         f"(got {getattr(namespace, self.dest)!r} "
                         f"then {values!r})")
        setattr(namespace, f"_seen_{self.dest}", True)
        setattr(namespace, self.dest, values)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("notebook", nargs="?", default=None,
                    help=".ipynb path (omit when using --workload)")
    ap.add_argument("--workload",
                    choices=["gpu-training", "remote-sensing"],
                    default=None,
                    help="built-in notebook family instead of an .ipynb "
                         "path: gpu-training (GPU-heavy train loop) or "
                         "remote-sensing (data-gravity pipeline)")
    ap.add_argument("--sessions", type=int, default=3)
    ap.add_argument("--remote-speedup", type=float, default=10.0)
    ap.add_argument("--policy",
                    choices=["single", "block", "cost", "horizon"],
                    default="block")
    ap.add_argument("--model",
                    choices=["frequency", "markov", "recency", "ensemble"],
                    default=None,
                    help="interaction model (default: the paper's "
                         "Algorithm-1 frequency miner)")
    ap.add_argument("--no-knowledge", action="store_true")
    ap.add_argument("--bandwidth", type=float, default=1e9)
    ap.add_argument("--latency", type=float, default=0.5)
    ap.add_argument("--codec", default="zlib")
    ap.add_argument("--env", action="append", default=[],
                    help="extra environment: name:speedup[:capacity[:down]] "
                         "(down = burst pool for --autoscale)")
    ap.add_argument("--link", action="append", default=[],
                    help="pair link override: a:b:bandwidth:latency")
    ap.add_argument("--pipeline", action="store_true",
                    help="pipelined engine (prefetch overlaps execution)")
    ap.add_argument("--transport", choices=["loopback", "socket"],
                    default="loopback",
                    help="how migration traffic moves: loopback = "
                         "in-process, zero-copy, simulated timing (the "
                         "paper's setup, default); socket = two-process "
                         "demo — the remote env is a child Python process "
                         "and every migration streams CRC-framed chunks "
                         "over real TCP (incompatible with --fleet)")
    ap.add_argument("--fleet", type=int, default=0,
                    help="run N concurrent sessions through the scheduler")
    ap.add_argument("--arrivals", type=float, default=0.0,
                    help="fleet: Poisson session-arrival rate per second")
    ap.add_argument("--think-time", type=float, default=0.0,
                    help="fleet: mean think-time gap between cells (s)")
    ap.add_argument("--seed", type=int, default=0,
                    help="fleet: workload-trace seed (determinism)")
    ap.add_argument("--fail-env", action="append", default=[],
                    help="fleet: kill env mid-run: env:time[:recover_after]")
    ap.add_argument("--recovery", choices=["checkpoint", "rerun"],
                    default=None,
                    help="fleet: failure-recovery mode (checkpoint = "
                         "periodic CAS checkpoints + restore)")
    ap.add_argument("--checkpoint-interval", type=float, default=30.0)
    ap.add_argument("--autoscale", action="store_true",
                    help="fleet: provision/cull 'down' burst envs from "
                         "queue telemetry")
    ap.add_argument("--replicate", action="store_true",
                    help="fleet: trickle dirty state to likely targets "
                         "during think time (background delta replication; "
                         "decision-time migrations ship only the residual)")
    ap.add_argument("--trickle-rate", default=None, metavar="RATE",
                    help="replication rate limit, e.g. 50MB/s, 1e6, 200KB "
                         "(default 50MB/s; requires --replicate)")
    ap.add_argument("--liveness", choices=["on", "off"], default="on",
                    help="prune provably-dead names from trickle and "
                         "full-state moves (live-variable analysis over "
                         "the remaining cells; default on)")
    ap.add_argument("--replicas", type=int, default=0, metavar="K",
                    action=_OnceAction,
                    help="fleet: keep K follower namespaces converged "
                         "during think time; a primary failure promotes "
                         "the most-converged follower with zero replay "
                         "(0 = off, today's behavior)")
    ap.add_argument("--race", choices=["on", "off"], default="off",
                    help="first-result-wins cell racing on converged "
                         "followers (requires --replicas >= 1)")
    ap.add_argument("--price", action="append", default=[],
                    metavar="ENV:DOLLARS_PER_HOUR",
                    help="cost plane: hourly compute price for an env, "
                         "e.g. remote:3.0 (any price switches the horizon "
                         "DP to minimize expected dollars)")
    ap.add_argument("--hazard", action="append", default=[],
                    metavar="ENV:RATE[/h|/s]",
                    help="cost plane: spot-preemption hazard rate, e.g. "
                         "spot:6/h (requires --fleet; preemptions are "
                         "seeded and deterministic)")
    ap.add_argument("--egress", action="append", default=[],
                    metavar="SRC:DST:DOLLARS_PER_GB",
                    help="cost plane: per-GB egress price on a directed "
                         "link, e.g. remote:local:0.09")
    ap.add_argument("--slo", type=float, default=None, metavar="SECONDS",
                    help="cost plane: per-cell latency SLO; the dollars DP "
                         "only considers placements whose expected per-cell "
                         "latency stays within this bound")
    ap.add_argument("--report", default=None)
    ap.add_argument("--write-annotated", default=None,
                    help="write the notebook back with decision annotations")
    args = ap.parse_args()

    try:
        # validate every spec up front (duplicate env names, malformed
        # floats, unknown envs) so mistakes die as friendly argparse
        # errors — runtime failures below keep their real tracebacks
        if args.notebook is None and args.workload is None:
            raise ValueError(
                "give a notebook path or pick a built-in family with "
                "--workload gpu-training|remote-sensing")
        if args.notebook is not None and args.workload is not None:
            raise ValueError(
                "--workload replaces the notebook path; give one or the "
                "other, not both")
        if args.slo is not None and args.slo <= 0:
            raise ValueError(
                f"--slo must be a positive number of seconds "
                f"(got {args.slo})")
        fail_envs = [parse_fail_spec(s) for s in args.fail_env]
        reg = build_registry(remote_speedup=args.remote_speedup,
                             bandwidth=args.bandwidth, latency=args.latency,
                             extra_envs=args.env, links=args.link,
                             prices=args.price, hazards=args.hazard,
                             egress=args.egress)
        if args.hazard and not args.fleet:
            raise ValueError(
                "--hazard needs --fleet: seeded preemptions run on the "
                "scheduler's event loop (try --fleet 2)")
        for env, _at, _rec in fail_envs:
            if env not in reg:
                raise ValueError(
                    f"--fail-env: unknown environment {env!r} "
                    f"(registered: {', '.join(reg.names())})")
        if args.autoscale and args.fleet \
                and not any(e.status == "down" for e in reg.envs().values()):
            raise ValueError(
                "--autoscale needs at least one burst env "
                "(--env name:speedup:capacity:down)")
        if args.transport == "socket" and args.fleet:
            raise ValueError(
                "--transport socket (two-process demo) is incompatible "
                "with --fleet")
        if args.trickle_rate is not None and not args.replicate:
            raise ValueError(
                "--trickle-rate only applies with --replicate")
        trickle_rate = (parse_rate_spec(args.trickle_rate)
                        if args.trickle_rate is not None else 50e6)
        if args.replicate and not args.fleet:
            raise ValueError(
                "--replicate needs --fleet: think-time trickling runs on "
                "the scheduler's event loop (try --fleet 2 --think-time 5)")
        if args.replicate and args.transport == "socket":
            raise ValueError(
                "--replicate rides the fleet plane and is incompatible "
                "with --transport socket (the two-process demo)")
        if args.replicas < 0:
            raise ValueError(
                f"--replicas must be >= 0 (got {args.replicas})")
        if args.replicas and not args.fleet:
            raise ValueError(
                "--replicas needs --fleet: follower convergence runs on "
                "the scheduler's event loop (try --fleet 2 --think-time 5)")
        if args.race == "on" and not args.replicas:
            raise ValueError(
                "--race on races cells against converged followers and "
                "needs --replicas >= 1")
    except ValueError as e:
        ap.error(str(e))

    report, nb = run_notebook(
        args.notebook, sessions=args.sessions,
        remote_speedup=args.remote_speedup, policy=args.policy,
        use_knowledge=not args.no_knowledge, bandwidth=args.bandwidth,
        latency=args.latency, codec=args.codec, extra_envs=args.env,
        links=args.link, pipeline=args.pipeline, fleet=args.fleet,
        model=args.model, arrivals=args.arrivals,
        think_time=args.think_time, seed=args.seed, fail_envs=fail_envs,
        autoscale=args.autoscale, recovery=args.recovery,
        checkpoint_interval=args.checkpoint_interval,
        transport=args.transport, replicate=args.replicate,
        trickle_rate=trickle_rate, liveness=args.liveness == "on",
        replicas=args.replicas, race=args.race == "on",
        prices=args.price, hazards=args.hazard, egress=args.egress,
        slo=args.slo, workload=args.workload)

    print(json.dumps({k: v for k, v in report.items() if k != "decisions"},
                     indent=2))
    if "decisions" in report:
        print("\nper-cell decisions:")
        for cid, note in report["decisions"].items():
            print(f"  {cid[:8]}: {note}")
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2)
    if args.write_annotated:
        with open(args.write_annotated, "w") as f:
            json.dump(nb.to_ipynb(), f, indent=1)


if __name__ == "__main__":
    main()
