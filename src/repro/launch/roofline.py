"""Roofline analysis over dry-run JSONs (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, from the compiled artifact:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s          [197 TF/s bf16]
    memory     = HLO_bytes_per_device / HBM_bw               [819 GB/s]
    collective = wire_bytes_per_device / link_bw             [~50 GB/s ICI]

plus MODEL_FLOPS (6·N_active·tokens for train, 2·N_active·tokens for
inference), the useful-compute ratio, the dominant term, and a one-line
recommendation.  ``python -m repro.launch.roofline [--dir experiments/dryrun]``
prints the full table in markdown.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9,
      "hbm_bytes": 16e9}


def analyze(rec: dict) -> dict | None:
    if not rec.get("applicable", True):
        return {"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                "skip": rec.get("skip_reason", "n/a"), "tag": rec.get("tag", "")}
    t_comp = rec["flops_per_device"] / HW["peak_flops"]
    t_mem = rec["bytes_accessed_per_device"] / HW["hbm_bw"]
    t_coll = rec["wire_bytes_per_device"] / HW["link_bw"]
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mult = 6 if rec["kind"] == "train" else 2
    model_flops = mult * rec["active_params"] * rec["tokens_per_step"]
    hlo_total = rec["flops_per_device"] * rec["n_devices"]
    useful = model_flops / hlo_total if hlo_total else 0.0
    bound = max(terms.values())
    # fraction of the ideal: time if compute ran at peak vs the bounding term
    frac = t_comp / bound if bound > 0 else 0.0
    rec_txt = {
        "compute": "raise MODEL_FLOPS ratio (remat policy, causal-skip kernel, "
                   "MoE capacity factor)",
        "memory": "improve arithmetic intensity (fusion, larger microbatch, "
                  "bf16 spills)",
        "collective": "cut wire bytes (bf16 collectives, FSDP-style resharding, "
                      "sequence-parallel residual, EP dispatch locality)",
    }[dom]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", "baseline"),
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom, "roofline_frac": frac,
        "model_flops": model_flops, "hlo_flops_total": hlo_total,
        "useful_ratio": useful,
        "mem_peak_gb": rec.get("memory", {}).get("peak", 0) / 1e9,
        "mem_model_gb": rec.get("mem_model", {}).get("total", 0) / 1e9,
        "fits_hbm": rec.get("mem_model", {}).get(
            "fits_hbm", rec.get("memory", {}).get("fits_hbm")),
        "recommendation": rec_txt,
    }


def load_all(d: str) -> list[dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(fn) as f:
            out.append(json.load(f))
    return out


def table(rows: list[dict], mesh_filter: str | None = None) -> str:
    hdr = ("| arch | shape | mesh | tag | compute s | memory s | coll s | "
           "dominant | frac | useful | memXLA GB | memTPU GB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        a = analyze(r)
        if a is None:
            continue
        if mesh_filter and mesh_filter not in a["mesh"]:
            continue
        if "skip" in a:
            lines.append(f"| {a['arch']} | {a['shape']} | {a['mesh']} | "
                         f"{a.get('tag','')} | — | — | — | SKIP: {a['skip']} "
                         f"| — | — | — | — | — |")
            continue
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | {a['tag']} "
            f"| {a['compute_s']:.3f} | {a['memory_s']:.3f} "
            f"| {a['collective_s']:.3f} | {a['dominant']} "
            f"| {a['roofline_frac']:.2f} | {a['useful_ratio']:.2f} "
            f"| {a['mem_peak_gb']:.1f} | {a['mem_model_gb']:.1f} "
            f"| {a['fits_hbm']} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = load_all(args.dir)
    print(table(rows, args.mesh))


if __name__ == "__main__":
    main()
