"""Batched serving driver: prefill a prompt batch, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --batch 4 --prompt-len 48 --gen 16

Notebook-fleet mode serves many concurrent notebook *sessions* instead of
token batches — the migration subsystem's serving story: N users' sessions
multiplexed by the SessionScheduler over a shared accelerator fabric.

    PYTHONPATH=src python -m repro.launch.serve --notebook-fleet 8 \
        [--fleet-gpu-capacity 2] [--fleet-tpu-capacity 1]
"""
from __future__ import annotations

import argparse
import json
import time


def serve_notebook_fleet(n_sessions: int, *, gpu_capacity: int = 2,
                         tpu_capacity: int = 1) -> dict:
    """N synthetic data-science sessions over a shared 3-env fabric."""
    from repro.core import (
        EnvironmentRegistry, ExecutionEnvironment, Notebook, SessionScheduler,
    )
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.5)
    reg.register(ExecutionEnvironment("local"), home=True,
                 capacity=max(8, n_sessions))
    reg.register(ExecutionEnvironment("gpu-cloud", speedup=8.0),
                 capacity=gpu_capacity)
    reg.register(ExecutionEnvironment("tpu-mesh", speedup=40.0),
                 capacity=tpu_capacity)
    reg.connect("local", "gpu-cloud", bandwidth=5e8, latency=0.3)
    reg.connect("local", "tpu-mesh", bandwidth=1e8, latency=1.0)
    sched = SessionScheduler(reg)
    for i in range(n_sessions):
        nb = Notebook(f"user-{i}")
        nb.add_cell("import numpy as np\n"
                    "data = np.arange(200_000, dtype=np.float64)", cost=0.5)
        nb.add_cell("model = float(((data - data.mean()) ** 2).sum())",
                    cost=60.0)
        nb.add_cell("report = model / len(data)", cost=0.2)
        sched.add_notebook(nb, policy="cost", use_knowledge=False)
    rep = sched.run()
    return {
        "sessions": n_sessions,
        "makespan": rep.makespan,
        "queue_events": rep.queue_events,
        "total_queue_wait": rep.total_queue_wait,
        "env_utilization": rep.env_utilization,
        "sessions_per_modeled_hour": (
            n_sessions / rep.makespan * 3600 if rep.makespan else 0.0),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="demo-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--notebook-fleet", type=int, default=0,
                    help="serve N concurrent notebook sessions instead of "
                         "an LM token batch")
    ap.add_argument("--fleet-gpu-capacity", type=int, default=2)
    ap.add_argument("--fleet-tpu-capacity", type=int, default=1)
    args = ap.parse_args()

    if args.notebook_fleet:
        report = serve_notebook_fleet(
            args.notebook_fleet, gpu_capacity=args.fleet_gpu_capacity,
            tpu_capacity=args.fleet_tpu_capacity)
        print(json.dumps(report, indent=2))
        print("ok")
        return

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.data import TokenPipeline
    from repro.models import LM

    cfg = get_config(args.arch, reduced=args.reduced)
    total = args.prompt_len + args.gen
    lm = LM(cfg, max_seq=total)
    shape = ShapeConfig("cli", "prefill", args.prompt_len, args.batch)
    pipe = TokenPipeline(cfg, shape, seed=args.seed)

    hb = pipe.prefill_batch(0)
    batch = {k: jnp.asarray(v) for k, v in hb.items()}

    prefill = jax.jit(lambda p, b: lm.prefill(p, b, cache_len=total))
    decode = jax.jit(lm.decode_step)

    params = lm.init(jax.random.PRNGKey(args.seed))
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(args.gen):
        toks.append(tok)
        logits, cache = decode(params, cache, {"token": tok})
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(toks[-1])
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(toks, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill:.2f}s; "
          f"decode {args.gen} tokens: {t_decode:.2f}s "
          f"({args.gen*args.batch/t_decode:.1f} tok/s)")
    print("sample generated ids:", out[0, :12].tolist())
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.padded_vocab))
    print("ok")


if __name__ == "__main__":
    main()
