"""Batched serving driver: prefill a prompt batch, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --batch 4 --prompt-len 48 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import TokenPipeline
from repro.models import LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="demo-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    total = args.prompt_len + args.gen
    lm = LM(cfg, max_seq=total)
    shape = ShapeConfig("cli", "prefill", args.prompt_len, args.batch)
    pipe = TokenPipeline(cfg, shape, seed=args.seed)

    hb = pipe.prefill_batch(0)
    batch = {k: jnp.asarray(v) for k, v in hb.items()}

    prefill = jax.jit(lambda p, b: lm.prefill(p, b, cache_len=total))
    decode = jax.jit(lm.decode_step)

    params = lm.init(jax.random.PRNGKey(args.seed))
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(args.gen):
        toks.append(tok)
        logits, cache = decode(params, cache, {"token": tok})
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(toks[-1])
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(toks, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill:.2f}s; "
          f"decode {args.gen} tokens: {t_decode:.2f}s "
          f"({args.gen*args.batch/t_decode:.1f} tok/s)")
    print("sample generated ids:", out[0, :12].tolist())
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.padded_vocab))
    print("ok")


if __name__ == "__main__":
    main()
