"""Batched serving driver: prefill a prompt batch, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --batch 4 --prompt-len 48 --gen 16

Notebook-fleet mode serves many concurrent notebook *sessions* instead of
token batches — the migration subsystem's serving story: N users' sessions
multiplexed by the SessionScheduler over a shared accelerator fabric.

    PYTHONPATH=src python -m repro.launch.serve --notebook-fleet 8 \
        [--fleet-gpu-capacity 2] [--fleet-tpu-capacity 1]

Gateway mode runs the persistent multi-tenant GatewayService instead of a
batch schedule: sessions attach/detach at will, a warm pool absorbs cold
starts, and deficit-round-robin admission divides capacity by tenant
weight.  ``--stress N`` drives a Poisson attach storm of N sessions
end-to-end over the wire protocol (real ATTACH/DETACH frames through a
WireFrontend):

    PYTHONPATH=src python -m repro.launch.serve --gateway 32 \
        --tenants alice:2,bob:1 --quota 16 --warm-pool 8 \
        --max-sessions 64
    PYTHONPATH=src python -m repro.launch.serve --gateway 0 --stress 2000

``--replicas K`` (gateway or notebook-fleet mode) keeps K follower
namespaces converged per session — failures promote instead of replaying —
and ``--race on`` adds first-result-wins cell racing on top.
"""
from __future__ import annotations

import argparse
import json
import time


def parse_tenant_spec(spec: str) -> list[tuple[str, float, int | None]]:
    """``name[:weight[:quota]],...`` -> [(name, weight, quota|None)];
    raises ValueError with a user-facing message on bad input."""
    out = []
    for item in spec.split(","):
        parts = item.split(":")
        name = parts[0].strip()
        if not name:
            raise ValueError(f"--tenants {spec!r}: empty tenant name "
                             f"(expected name[:weight[:quota]],...)")
        try:
            weight = float(parts[1]) if len(parts) > 1 else 1.0
        except ValueError:
            raise ValueError(
                f"--tenants {spec!r}: weight {parts[1]!r} for {name!r} is "
                f"not a number (expected name[:weight[:quota]])") from None
        if weight <= 0:
            raise ValueError(
                f"--tenants {spec!r}: weight for {name!r} must be positive "
                f"(got {weight})")
        quota: int | None = None
        if len(parts) > 2 and parts[2] not in ("", "none"):
            try:
                quota = int(parts[2])
            except ValueError:
                raise ValueError(
                    f"--tenants {spec!r}: quota {parts[2]!r} for {name!r} "
                    f"is not an integer (use 'none' for unlimited)") \
                    from None
            if quota < 1:
                raise ValueError(
                    f"--tenants {spec!r}: quota for {name!r} must be >= 1 "
                    f"(got {quota}; use 'none' for unlimited)")
        out.append((name, weight, quota))
    return out


def positive_int(flag: str, value: int, *, allow_zero: bool = False) -> int:
    floor = 0 if allow_zero else 1
    if value < floor:
        raise ValueError(f"{flag} must be >= {floor} (got {value})")
    return value


def serve_gateway(n_sessions: int, *, tenants=None, quota: int | None = None,
                  warm_pool: int = 8, max_sessions: int | None = None,
                  stress: int = 0, rate: float = 50.0,
                  think_mean: float = 20.0, cold_start: float = 5.0,
                  gpu_capacity: int = 16, seed: int = 0,
                  replicas: int = 0, race: bool = False) -> dict:
    """Run the persistent gateway over the 3-env fabric.  Plain mode
    attaches ``n_sessions`` programmatically; ``stress`` > 0 additionally
    drives that many sessions as real ATTACH frames over a wire frontend
    (the end-to-end decode → admit → ack → DETACH-complete path)."""
    from repro.core import (
        EnvironmentRegistry, ExecutionEnvironment, GatewayService,
        LoopbackTransport, Notebook, poisson_attach_storm,
    )
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.5)
    reg.register(ExecutionEnvironment("local"), home=True,
                 capacity=max(64, n_sessions + stress))
    reg.register(ExecutionEnvironment("gpu-cloud", speedup=8.0),
                 capacity=gpu_capacity)
    reg.register(ExecutionEnvironment("tpu-mesh", speedup=40.0), capacity=4)
    reg.connect("local", "gpu-cloud", bandwidth=5e8, latency=0.3)
    reg.connect("local", "tpu-mesh", bandwidth=1e8, latency=1.0)
    gw = GatewayService(reg, warm_pool=warm_pool, cold_start=cold_start,
                        max_sessions=max_sessions, replicas=replicas,
                        race=race, policy="cost", use_knowledge=False)
    names = []
    for name, weight, tquota in (tenants or [("default", 1.0, None)]):
        gw.add_tenant(name, weight=weight,
                      quota=tquota if tquota is not None else quota)
        names.append(name)

    def make_nb(i: int) -> Notebook:
        nb = Notebook(f"user-{i % 8}")
        nb.add_cell("import numpy as np\n"
                    "data = np.arange(200_000, dtype=np.float64)", cost=0.5)
        nb.add_cell("model = float(((data - data.mean()) ** 2).sum())",
                    cost=60.0)
        nb.add_cell("report = model / len(data)", cost=0.2)
        return nb

    if n_sessions:
        poisson_attach_storm(gw, n_sessions=n_sessions, rate=rate,
                             think_mean=think_mean, make_notebook=make_nb,
                             tenants=tuple(names), seed=seed)
    if stress:
        client, server = LoopbackTransport.pair()
        gw.add_frontend(server)
        poisson_attach_storm(gw, n_sessions=stress, rate=rate,
                             think_mean=think_mean, make_notebook=make_nb,
                             tenants=tuple(names), seed=seed + 1,
                             client=client)
    rep = gw.run()
    return {
        "sessions": rep.sessions, "completed": rep.completed,
        "errors": rep.errors, "peak_concurrent": rep.peak_concurrent,
        "makespan": rep.makespan,
        "attach_wait_p50": rep.attach_wait_p50,
        "attach_wait_p99": rep.attach_wait_p99,
        "queue_wait_p99": rep.queue_wait_p99,
        "decision_ms_p99": rep.decision_ms_p99,
        "pool": {"hits": rep.pool_hits, "misses": rep.pool_misses,
                 "refills": rep.pool_refills},
        "tenants": rep.tenants,
        "env_utilization": rep.env_utilization,
        "wire_sessions": stress,
        "replicas": replicas,
        "promotions": rep.promotions,
        "races": rep.races,
        "race_waste_seconds": rep.race_waste_seconds,
        "replica_lag_max": max(
            (r.replica_lag for r in rep.session_reports), default=0),
    }


def serve_notebook_fleet(n_sessions: int, *, gpu_capacity: int = 2,
                         tpu_capacity: int = 1, replicas: int = 0,
                         race: bool = False) -> dict:
    """N synthetic data-science sessions over a shared 3-env fabric."""
    from repro.core import (
        EnvironmentRegistry, ExecutionEnvironment, Notebook, SessionScheduler,
    )
    reg = EnvironmentRegistry(default_bandwidth=2e8, default_latency=0.5)
    reg.register(ExecutionEnvironment("local"), home=True,
                 capacity=max(8, n_sessions))
    reg.register(ExecutionEnvironment("gpu-cloud", speedup=8.0),
                 capacity=gpu_capacity)
    reg.register(ExecutionEnvironment("tpu-mesh", speedup=40.0),
                 capacity=tpu_capacity)
    reg.connect("local", "gpu-cloud", bandwidth=5e8, latency=0.3)
    reg.connect("local", "tpu-mesh", bandwidth=1e8, latency=1.0)
    sched = SessionScheduler(reg)
    if replicas:
        sched.enable_replicas(replicas, race=race)
    for i in range(n_sessions):
        nb = Notebook(f"user-{i}")
        nb.add_cell("import numpy as np\n"
                    "data = np.arange(200_000, dtype=np.float64)", cost=0.5)
        nb.add_cell("model = float(((data - data.mean()) ** 2).sum())",
                    cost=60.0)
        nb.add_cell("report = model / len(data)", cost=0.2)
        sched.add_notebook(nb, policy="cost", use_knowledge=False)
    rep = sched.run()
    return {
        "sessions": n_sessions,
        "makespan": rep.makespan,
        "queue_events": rep.queue_events,
        "total_queue_wait": rep.total_queue_wait,
        "env_utilization": rep.env_utilization,
        "replicas": replicas,
        "replicated_bytes": rep.replicated_bytes,
        "promotions": rep.promotions,
        "races": rep.races,
        "race_waste_seconds": rep.race_waste_seconds,
        "replica_lag": {s.session: s.replica_lag for s in rep.sessions
                        if s.replica_lag},
        "sessions_per_modeled_hour": (
            n_sessions / rep.makespan * 3600 if rep.makespan else 0.0),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="demo-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--notebook-fleet", type=int, default=0,
                    help="serve N concurrent notebook sessions instead of "
                         "an LM token batch")
    ap.add_argument("--fleet-gpu-capacity", type=int, default=2)
    ap.add_argument("--fleet-tpu-capacity", type=int, default=1)
    ap.add_argument("--gateway", type=int, default=None, metavar="N",
                    help="run the persistent multi-tenant gateway with N "
                         "programmatic sessions (0 = wire-only, see "
                         "--stress)")
    ap.add_argument("--tenants", default=None, metavar="SPEC",
                    help="comma list of name[:weight[:quota]] "
                         "(e.g. alice:2,bob:1:10)")
    ap.add_argument("--quota", type=int, default=None, metavar="N",
                    help="default per-tenant max concurrent sessions "
                         "(tenant spec quota overrides)")
    ap.add_argument("--warm-pool", type=int, default=8, metavar="K",
                    help="pre-provisioned workers held hot (0 = every "
                         "attach pays the cold start)")
    ap.add_argument("--max-sessions", type=int, default=None, metavar="N",
                    help="gateway-wide concurrent session cap")
    ap.add_argument("--stress", type=int, default=0, metavar="N",
                    help="drive N extra sessions as a Poisson attach storm "
                         "of real ATTACH frames over a wire frontend")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="gateway storm arrival rate (sessions/s)")
    ap.add_argument("--replicas", type=int, default=0, metavar="K",
                    help="keep K follower namespaces converged per session "
                         "(fleet/gateway modes; 0 = off)")
    ap.add_argument("--race", choices=["on", "off"], default="off",
                    help="first-result-wins cell racing on converged "
                         "followers (requires --replicas >= 1)")
    args = ap.parse_args()

    try:
        positive_int("--replicas", args.replicas, allow_zero=True)
        if args.race == "on" and not args.replicas:
            raise ValueError(
                "--race on races cells against converged followers and "
                "needs --replicas >= 1")
        if args.replicas and args.gateway is None \
                and not args.notebook_fleet:
            raise ValueError(
                "--replicas applies to --gateway or --notebook-fleet "
                "serving modes only")
    except ValueError as e:
        ap.error(str(e))

    if args.gateway is not None:
        try:
            tenants = (parse_tenant_spec(args.tenants)
                       if args.tenants else None)
            positive_int("--gateway", args.gateway, allow_zero=True)
            positive_int("--warm-pool", args.warm_pool, allow_zero=True)
            positive_int("--stress", args.stress, allow_zero=True)
            if args.quota is not None:
                positive_int("--quota", args.quota)
            if args.max_sessions is not None:
                positive_int("--max-sessions", args.max_sessions)
            if args.gateway == 0 and args.stress == 0:
                raise ValueError(
                    "--gateway 0 serves no one: give it N sessions or "
                    "add --stress N for a wire-borne storm")
        except ValueError as e:
            ap.error(str(e))
        report = serve_gateway(
            args.gateway, tenants=tenants, quota=args.quota,
            warm_pool=args.warm_pool, max_sessions=args.max_sessions,
            stress=args.stress, rate=args.rate, seed=args.seed,
            replicas=args.replicas, race=args.race == "on")
        print(json.dumps(report, indent=2))
        print("ok")
        return

    if args.notebook_fleet:
        report = serve_notebook_fleet(
            args.notebook_fleet, gpu_capacity=args.fleet_gpu_capacity,
            tpu_capacity=args.fleet_tpu_capacity,
            replicas=args.replicas, race=args.race == "on")
        print(json.dumps(report, indent=2))
        print("ok")
        return

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.data import TokenPipeline
    from repro.models import LM

    cfg = get_config(args.arch, reduced=args.reduced)
    total = args.prompt_len + args.gen
    lm = LM(cfg, max_seq=total)
    shape = ShapeConfig("cli", "prefill", args.prompt_len, args.batch)
    pipe = TokenPipeline(cfg, shape, seed=args.seed)

    hb = pipe.prefill_batch(0)
    batch = {k: jnp.asarray(v) for k, v in hb.items()}

    prefill = jax.jit(lambda p, b: lm.prefill(p, b, cache_len=total))
    decode = jax.jit(lm.decode_step)

    params = lm.init(jax.random.PRNGKey(args.seed))
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(args.gen):
        toks.append(tok)
        logits, cache = decode(params, cache, {"token": tok})
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(toks[-1])
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(toks, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill:.2f}s; "
          f"decode {args.gen} tokens: {t_decode:.2f}s "
          f"({args.gen*args.batch/t_decode:.1f} tok/s)")
    print("sample generated ids:", out[0, :12].tolist())
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.padded_vocab))
    print("ok")


if __name__ == "__main__":
    main()
