"""End-to-end training driver (CPU-runnable at reduced scale).

    PYTHONPATH=src python -m repro.launch.train --arch demo-100m --steps 20 \
        --batch 8 --seq 128 [--reduced] [--ckpt-dir ckpts] [--resume]

On TPU pods the same driver runs with --mesh pod/multipod (shardings come
from the identical build_train_step used by the dry-run).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import TrainConfig, get_config
from repro.configs.base import ShapeConfig
from repro.data import TokenPipeline
from repro.models import LM
from repro.optim import adamw_update, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="demo-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1),
                     schedule=cfg.schedule, microbatches=args.microbatches)
    lm = LM(cfg, max_seq=args.seq)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    pipe = TokenPipeline(cfg, shape, seed=args.seed)

    params = lm.init(jax.random.PRNGKey(tc.seed))
    opt = init_opt_state(params)
    start = 0

    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ck and args.resume and ck.latest_step() is not None:
        restored, start = ck.restore({"params": params,
                                      "opt": opt._asdict()})
        params = restored["params"]
        from repro.optim.optimizer import OptState
        opt = OptState(**restored["opt"])
        print(f"resumed from step {start}")

    @jax.jit
    def train_step(params, opt, batch):
        (loss, m), grads = jax.value_and_grad(lm.loss, has_aux=True)(params, batch)
        opt2, params2, om = adamw_update(tc, opt, grads, params)
        return params2, opt2, {"loss": loss, **om}

    for step in range(start, args.steps):
        hb = pipe.train_batch(step)
        batch = {k: jnp.asarray(v) for k, v in hb.items()}
        t0 = time.perf_counter()
        params, opt, metrics = train_step(params, opt, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        print(f"step {step:4d} loss {loss:.4f} lr {float(metrics['lr']):.2e} "
              f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s")
        if ck and (step + 1) % args.ckpt_every == 0:
            info = ck.save(step + 1, {"params": params, "opt": opt._asdict()})
            print(f"  ckpt@{step+1}: {info.nbytes/1e6:.1f} MB "
                  f"({info.n_leaves_written}/{info.n_leaves_total} leaves, "
                  f"{info.seconds:.2f}s)")
    print("done")


if __name__ == "__main__":
    main()
