from repro.models.model import LM

__all__ = ["LM"]
