"""GQA attention: train/prefill paths (full causal, chunked-causal, banded
local window) and decode paths (plain, and sequence-parallel via shard_map in
``repro.distributed.decode_attn``).

All softmax arithmetic is fp32; masks use -1e30 (never -inf) so that empty
rows stay NaN-free.  A Pallas flash-attention kernel
(:mod:`repro.kernels.flash_attention`) is selectable with ``impl="pallas"``
on real TPUs; the XLA paths below are what the CPU dry-run lowers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import P, apply_rope, head_rms_norm

NEG = -1e30


def attn_spec(cfg):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    spec = {
        "wq": P((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        spec["q_norm"] = P((hd,), ("head_dim",), init="zeros")
        spec["k_norm"] = P((hd,), ("head_dim",), init="zeros")
    return spec


def qkv_project(p, x, cfg, positions):
    """x (B,S,d) -> q (B,S,H,hd), k/v (B,S,KV,hd) with rope + optional qk-norm."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, rope_pct=cfg.rope_pct, theta=cfg.rope_theta)
        k = apply_rope(k, positions, rope_pct=cfg.rope_pct, theta=cfg.rope_theta)
    return q, k, v


def _group(q, KV):
    """(B,S,H,hd) -> (B,S,KV,G,hd)."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, KV, H // KV, hd)


def full_causal_attention(q, k, v, *, chunk_q: int = 1024):
    """Chunked causal attention (flash-style at the XLA level).

    Scans over query chunks so the (chunk, S) score block is the only
    transient — keeps prefill_32k within HBM without a kernel.  Note: each
    chunk still computes scores against all S keys (masked), i.e. ~2x the
    causal-ideal FLOPs; the Pallas kernel closes that gap on real TPUs
    (EXPERIMENTS.md §Perf).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    qg = _group(q, KV)                      # (B,S,KV,G,hd)
    scale = hd ** -0.5
    nq = max(S // min(chunk_q, S), 1)
    cq = S // nq
    qb = qg.reshape(B, nq, cq, KV, H // KV, hd)
    kpos = jnp.arange(S)

    def body(_, qi_i):
        qi, i = qi_i
        qpos = i * cq + jnp.arange(cq)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qi, k).astype(jnp.float32) * scale
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None, None], s, NEG)
        w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return None, jnp.einsum("bkgqs,bskh->bqkgh", w, v)

    _, ob = jax.lax.scan(body, None, (jnp.moveaxis(qb, 1, 0), jnp.arange(nq)))
    out = jnp.moveaxis(ob, 0, 1).reshape(B, S, H, hd)
    return out


def banded_local_attention(q, k, v, *, window: int):
    """Sliding-window causal attention, O(S*W): block i attends {i-1, i}.

    Requires S % window == 0.  Used by recurrentgemma's local-attention
    layers (train/prefill); FLOPs stay linear in S (long_500k viability).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    W = window
    assert S % W == 0, (S, W)
    nb = S // W
    qg = _group(q, KV).reshape(B, nb, W, KV, H // KV, hd)
    kb = k.reshape(B, nb, W, KV, hd)
    vb = v.reshape(B, nb, W, KV, hd)
    zpad = jnp.zeros_like(kb[:, :1])
    kcat = jnp.concatenate([jnp.concatenate([zpad, kb[:, :-1]], 1), kb], axis=2)  # (B,nb,2W,KV,hd)
    vcat = jnp.concatenate([jnp.concatenate([zpad, vb[:, :-1]], 1), vb], axis=2)
    scale = hd ** -0.5
    s = jnp.einsum("bnqkgh,bnskh->bnkgqs", qg, kcat).astype(jnp.float32) * scale
    iq = jnp.arange(W)[:, None]
    j = jnp.arange(2 * W)[None, :]
    diff = (W + iq) - j
    win = (diff >= 0) & (diff < W)                      # causal window
    blk = jnp.arange(nb)[:, None, None]
    valid = win[None] & ((blk > 0) | (j[None] >= W))    # block 0 has no prev
    s = jnp.where(valid[None, :, None, None], s, NEG)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnkgqs,bnskh->bnqkgh", w, vcat)
    return out.reshape(B, S, H, hd)


# ----------------------------------------------------------------------
# Decode (one token, cache) — plain path.  SP path: distributed/decode_attn.
# ----------------------------------------------------------------------

def decode_attention_plain(q, k_cache, v_cache, pos):
    """q (B,1,H,hd); caches (B,KV,S,hd); pos (B,) index of the CURRENT token
    (caches already contain the current token at ``pos``)."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[1]
    S = k_cache.shape[2]
    qg = q.reshape(B, KV, H // KV, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, k_cache).astype(jnp.float32) * (hd ** -0.5)
    valid = jnp.arange(S)[None, :] <= pos[:, None]      # (B,S)
    s = jnp.where(valid[:, None, None, :], s, NEG)
    w = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bksh->bkgh", w, v_cache)
    return out.reshape(B, 1, H, hd)


def cache_write_plain(k_cache, v_cache, new_k, new_v, pos):
    """Write (B,KV,1,hd) new entries at per-sequence position ``pos`` (B,)."""
    def upd(c, n, p):
        return jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=1)
    k2 = jax.vmap(upd)(k_cache, jnp.swapaxes(new_k, 1, 2), pos)
    v2 = jax.vmap(upd)(v_cache, jnp.swapaxes(new_v, 1, 2), pos)
    return k2, v2


def decode_attention_window(q, k_cache, v_cache, pos, *, window: int):
    """Ring-buffer sliding-window decode (recurrentgemma local-attn layers).

    Caches (B,KV,W,hd); slot of token p is p % W; valid keys are the last
    ``window`` positions <= pos.
    """
    B, _, H, hd = q.shape
    KV, W = k_cache.shape[1], k_cache.shape[2]
    qg = q.reshape(B, KV, H // KV, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, k_cache).astype(jnp.float32) * (hd ** -0.5)
    slot = jnp.arange(W)[None, :]
    p = pos[:, None]
    # global position stored in slot j: the largest q <= pos with q % W == j
    gpos = p - ((p - slot) % W)
    valid = (gpos >= 0) & (gpos >= p - (window - 1))
    s = jnp.where(valid[:, None, None, :], s, NEG)
    w = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    return jnp.einsum("bkgs,bksh->bkgh", w, v_cache).reshape(B, 1, H, hd)


def cache_write_window(k_cache, v_cache, new_k, new_v, pos):
    W = k_cache.shape[2]
    return cache_write_plain(k_cache, v_cache, new_k, new_v, pos % W)


# ----------------------------------------------------------------------
# Cross attention (whisper decoder): static memory, no cache writes.
# ----------------------------------------------------------------------

def cross_attention(q, k_mem, v_mem):
    B, S, H, hd = q.shape
    KV = k_mem.shape[2]
    qg = _group(q, KV)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_mem).astype(jnp.float32) * (hd ** -0.5)
    w = jax.nn.softmax(s, axis=-1).astype(v_mem.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", w, v_mem).reshape(B, S, H, hd)
