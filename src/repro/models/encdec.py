"""Whisper-style encoder-decoder backbone (audio frontend is a stub:
``input_specs`` feeds precomputed frame embeddings (B, enc_seq, d_model)).

Learned absolute positions, bidirectional encoder, causal decoder with
cross-attention; decode uses a self-attn cache + precomputed cross K/V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import shard
from repro.models import attention as attn
from repro.models.layers import P, embed_spec, rms_norm, stack_spec, swiglu
from repro.models.transformer import mlp_spec, _o_proj


def enc_layer_spec(cfg):
    ln = lambda: P((cfg.d_model,), ("embed",), init="zeros")
    return {"ln1": ln(), "attn": attn.attn_spec(cfg), "ln2": ln(),
            "mlp": mlp_spec(cfg)}


def dec_layer_spec(cfg):
    ln = lambda: P((cfg.d_model,), ("embed",), init="zeros")
    return {"ln1": ln(), "attn": attn.attn_spec(cfg),
            "lnx": ln(), "xattn": attn.attn_spec(cfg),
            "ln2": ln(), "mlp": mlp_spec(cfg)}


def encdec_spec(cfg, max_seq: int):
    d = cfg.d_model
    return {
        "embed": embed_spec(cfg),
        "enc_pos": P((cfg.encoder_seq, d), ("enc_seq", "embed"), scale=0.02),
        "dec_pos": P((max_seq, d), ("pos", "embed"), scale=0.02),
        "encoder": stack_spec(enc_layer_spec(cfg), cfg.encoder_layers),
        "decoder": stack_spec(dec_layer_spec(cfg), cfg.num_layers),
        "ln_enc": P((d,), ("embed",), init="zeros"),
        "ln_f": P((d,), ("embed",), init="zeros"),
        "w_out": P((cfg.padded_vocab, d), ("vocab", "embed")),
    }


def encoder_forward(params, frames, cfg, ctx=None):
    """frames (B, Senc, d) -> (B, Senc, d)."""
    Senc = frames.shape[1]
    frames = frames.astype(params["embed"].dtype)  # stub frontend may emit f32
    x = frames + params["enc_pos"][:Senc].astype(frames.dtype)

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn.qkv_project(lp["attn"], h, cfg, jnp.zeros(h.shape[:2], jnp.int32))
        o = attn.cross_attention(q, k, v)  # unmasked bidirectional
        x = x + _o_proj(o, lp["attn"]["wo"])
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        x = shard(ctx, x, "batch", "seq", None)
        return x, None

    if cfg.exact_costs:
        for i in range(cfg.encoder_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["encoder"])
            x, _ = body(x, lp)
    else:
        x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _cross_kv(lp, enc_out, cfg):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
    return k, v


def decoder_forward(params, x, enc_out, cfg, ctx=None, positions=None, *,
                    want_cache: bool = False, cache_len: int | None = None):
    """x (B,S,d) decoder stream; enc_out (B,Senc,d). Returns (x, cache|None)."""

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn.qkv_project(lp["attn"], h, cfg, positions)
        o = attn.full_causal_attention(q, k, v)
        x = x + _o_proj(o, lp["attn"]["wo"])

        hx = rms_norm(x, lp["lnx"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", hx, lp["xattn"]["wq"])
        xk, xv = _cross_kv(lp, enc_out, cfg)
        ox = attn.cross_attention(qx, xk, xv)
        x = x + _o_proj(ox, lp["xattn"]["wo"])

        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        x = shard(ctx, x, "batch", "seq", None)
        entry = None
        if want_cache:
            sk, sv = jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)
            target = cache_len or sk.shape[2]
            if target > sk.shape[2]:
                pad = ((0, 0), (0, 0), (0, target - sk.shape[2]), (0, 0))
                sk, sv = jnp.pad(sk, pad), jnp.pad(sv, pad)
            entry = {"self_k": sk, "self_v": sv,
                     "cross_k": jnp.swapaxes(xk, 1, 2), "cross_v": jnp.swapaxes(xv, 1, 2)}
        return x, entry

    if cfg.exact_costs:
        el = []
        for i in range(cfg.num_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["decoder"])
            x, e = body(x, lp)
            el.append(e)
        entries = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *el)
                   if want_cache else None)
        return x, ({"stack": entries} if want_cache else None)

    x, entries = jax.lax.scan(body, x, params["decoder"])
    return x, ({"stack": entries} if want_cache else None)


def decoder_decode(params, x, cfg, ctx, pos, cache):
    """One-token decode. cache entries per layer: self_k/self_v (B,KV,S,hd),
    cross_k/cross_v (B,KV,Senc,hd)."""
    from repro.distributed.decode_attn import sp_decode_attention

    def body(x, lp_c):
        lp, c = lp_c
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn.qkv_project(lp["attn"], h, cfg, pos[:, None])
        if ctx is not None and ctx.sp_decode:
            o, kc, vc = sp_decode_attention(ctx, q, c["self_k"], c["self_v"], k, v, pos)
        else:
            kc, vc = attn.cache_write_plain(c["self_k"], c["self_v"], k, v, pos)
            o = attn.decode_attention_plain(q, kc, vc, pos)
        x = x + _o_proj(o, lp["attn"]["wo"])

        hx = rms_norm(x, lp["lnx"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", hx, lp["xattn"]["wq"])
        ox = attn.decode_attention_plain(
            qx, c["cross_k"], c["cross_v"],
            jnp.full((x.shape[0],), c["cross_k"].shape[2] - 1, jnp.int32))
        x = x + _o_proj(ox, lp["xattn"]["wo"])

        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        return x, {"self_k": kc, "self_v": vc,
                   "cross_k": c["cross_k"], "cross_v": c["cross_v"]}

    if cfg.exact_costs:
        outs = []
        for i in range(cfg.num_layers):
            sl = jax.tree_util.tree_map(lambda a: a[i],
                                        (params["decoder"], cache["stack"]))
            x, nc = body(x, sl)
            outs.append(nc)
        new_entries = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
        return x, {"stack": new_entries, "pos": cache["pos"]}

    x, new_entries = jax.lax.scan(body, x, (params["decoder"], cache["stack"]))
    return x, {"stack": new_entries, "pos": cache["pos"]}


def init_cache(cfg, B: int, cache_len: int, dtype=jnp.bfloat16):
    KV, hd, L = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_layers
    return {"stack": {
        "self_k": jnp.zeros((L, B, KV, cache_len, hd), dtype),
        "self_v": jnp.zeros((L, B, KV, cache_len, hd), dtype),
        "cross_k": jnp.zeros((L, B, KV, cfg.encoder_seq, hd), dtype),
        "cross_v": jnp.zeros((L, B, KV, cfg.encoder_seq, hd), dtype),
    }, "pos": jnp.zeros((B,), jnp.int32)}


def cache_axes(cfg, ctx):
    sp = ctx is not None and ctx.sp_decode
    self_ax = ("layers", "batch", None, "cache_seq" if sp else None, None)
    cross_ax = ("layers", "batch", None, None, None)
    return {"stack": {"self_k": self_ax, "self_v": self_ax,
                      "cross_k": cross_ax, "cross_v": cross_ax},
            "pos": ("batch",)}
