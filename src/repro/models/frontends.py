"""Stub modality frontends.

Per the assignment, [audio]/[vlm] architectures specify the transformer
backbone only; the modality frontend is a STUB — ``input_specs()`` supplies
precomputed frame/patch embeddings.  These helpers generate deterministic
synthetic embeddings for smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def synthetic_patch_embeds(key, batch: int, num_patches: int, d_model: int,
                           dtype=jnp.bfloat16):
    """Stand-in for an InternViT patch encoder output."""
    return (jax.random.normal(key, (batch, num_patches, d_model), jnp.float32)
            * 0.02).astype(dtype)


def synthetic_frame_embeds(key, batch: int, num_frames: int, d_model: int,
                           dtype=jnp.bfloat16):
    """Stand-in for whisper's conv mel-spectrogram frontend output."""
    return (jax.random.normal(key, (batch, num_frames, d_model), jnp.float32)
            * 0.02).astype(dtype)
