"""RecurrentGemma / Griffin recurrent block: RG-LRU [arXiv:2402.19427].

Temporal mixing: x -> linear -> causal conv1d (linear) -> RG-LRU, gated by a
GeLU branch.  Training/prefill use ``jax.lax.associative_scan`` in fp32 (the
Pallas kernel in :mod:`repro.kernels.rg_lru` is the TPU sequential-scan
version); decode is a single recurrence step on O(1) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import shard
from repro.models.layers import P, causal_conv1d

LRU_C = 8.0          # RG-LRU exponent constant
NUM_BLOCKS = 8       # block-diagonal gate projections


def rglru_spec(cfg):
    d, w = cfg.d_model, cfg.lru_width
    k = w // NUM_BLOCKS
    return {
        "w_gate": P((d, w), ("embed", "lru")),
        "w_x": P((d, w), ("embed", "lru")),
        "conv_w": P((w, cfg.conv_width), ("lru", None)),
        "gate_a_w": P((NUM_BLOCKS, k, k), ("lru_block", None, None)),
        "gate_a_b": P((w,), ("lru",), init="zeros"),
        "gate_x_w": P((NUM_BLOCKS, k, k), ("lru_block", None, None)),
        "gate_x_b": P((w,), ("lru",), init="zeros"),
        "lambda_p": P((w,), ("lru",), init="lambda"),
        "w_out": P((w, d), ("lru", "embed")),
    }


def _block_diag(x, w, b):
    """x (B,S,w) through block-diagonal projection (nb,k,k)."""
    B, S, W = x.shape
    nb, k, _ = w.shape
    xr = x.reshape(B, S, nb, k)
    y = jnp.einsum("bsnk,nkj->bsnj", xr, w).reshape(B, S, W)
    return y + b.astype(y.dtype)


def _rglru_coeffs(p, x):
    """Gates/coefficients. Returns (a, gated_input) both fp32, shapes (B,S,w)."""
    r = jax.nn.sigmoid(_block_diag(x, p["gate_a_w"], p["gate_a_b"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(x, p["gate_x_w"], p["gate_x_b"]).astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(p["lambda_p"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12))
    return a, beta * i * x.astype(jnp.float32)


def rglru_scan(p, x, init_state=None):
    """Associative scan over S. x (B,S,w) -> (y (B,S,w), final_state (B,w))."""
    a, bx = _rglru_coeffs(p, x)
    if init_state is not None:
        # fold h_{-1} into the first step: b_0 += a_0 * h_init
        bx = bx.at[:, 0, :].add(a[:, 0, :] * init_state.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h.astype(x.dtype), h[:, -1, :].astype(x.dtype)


def rglru_step(p, x, state):
    """One decode step. x (B,1,w), state (B,w)."""
    a, bx = _rglru_coeffs(p, x)
    h = a[:, 0] * state.astype(jnp.float32) + bx[:, 0]
    return h[:, None, :].astype(x.dtype), h.astype(x.dtype)


def recurrent_forward(p, x_res, cfg, ctx=None, conv_state=None, lru_state=None,
                      decode: bool = False):
    """Full griffin recurrent mixer. x_res (B,S,d) -> (y, (conv_state, lru_state))."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x_res, p["w_gate"]))
    xl = jnp.einsum("bsd,dw->bsw", x_res, p["w_x"])
    xl = shard(ctx, xl, "batch", "seq", "lru")
    xl, new_conv = causal_conv1d(xl, p["conv_w"], conv_state, activation=False)
    if decode:
        h, new_state = rglru_step(p, xl, lru_state)
    else:
        h, new_state = rglru_scan(p, xl, lru_state)
    y = jnp.einsum("bsw,wd->bsd", gate * h, p["w_out"])
    return y, (new_conv, new_state)
