"""Parameter-spec system and shared layer primitives.

A model is described once as a tree of :class:`P` leaves (shape + logical
axes + init rule).  From that single source of truth we derive:

- real parameters (``init_params``) for smoke tests / examples,
- abstract ``ShapeDtypeStruct`` trees (``abstract_params``) for the dry-run
  (never allocates),
- logical-axis trees (``axes_tree``) feeding the sharding rules.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """Declarative parameter leaf."""
    shape: tuple
    axes: tuple                 # logical axis names, len == len(shape)
    init: str = "normal"        # normal | zeros | ones | lecun | dt_bias | a_log | lambda
    scale: float | None = None  # stddev override for normal init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_p(x) -> bool:
    return isinstance(x, P)


def _leaves(spec) -> list[tuple[str, P]]:
    flat = jax.tree_util.tree_flatten_with_path(spec, is_leaf=is_p)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _materialize(p: P, key, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "dt_bias":  # mamba2 dt bias: log-uniform dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, p.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)  # inverse softplus
    if p.init == "a_log":    # mamba2 A in [1, 16]
        return jnp.log(jax.random.uniform(key, p.shape, jnp.float32, 1.0, 16.0)).astype(dtype)
    if p.init == "lambda":   # RG-LRU Lambda parameter: a in [0.9, 0.999]
        a = jax.random.uniform(key, p.shape, jnp.float32, 0.9, 0.999)
        # a = sigmoid(L)^c with c=8 -> L = logit(a**(1/8))
        r = a ** (1.0 / 8.0)
        return jnp.log(r / (1 - r)).astype(dtype)
    fan_in = p.shape[0] if len(p.shape) == 1 else int(np.prod(p.shape[:-1]))
    if len(p.shape) >= 3 and p.axes[0] in ("layers", "groups", "experts"):
        fan_in = int(np.prod(p.shape[1:-1])) or 1
    std = p.scale if p.scale is not None else 1.0 / max(np.sqrt(fan_in), 1.0)
    return (jax.random.truncated_normal(key, -2.0, 2.0, p.shape, jnp.float32) * std).astype(dtype)


def init_params(spec, key, dtype=jnp.bfloat16):
    """Materialize a spec tree into real arrays (deterministic per path)."""
    named = _leaves(spec)
    keys = jax.random.split(key, max(len(named), 1))
    table = {name: _materialize(p, k, dtype) for (name, p), k in zip(named, keys)}
    it = iter(range(len(named)))
    return jax.tree_util.tree_map(
        lambda p: table[named[next(it)][0]], spec, is_leaf=is_p)


def abstract_params(spec, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec, is_leaf=is_p)


def axes_tree(spec):
    return jax.tree_util.tree_map(lambda p: p.axes, spec, is_leaf=is_p)


def param_count(spec) -> int:
    return sum(int(np.prod(p.shape)) for _, p in _leaves(spec))


def stack_spec(spec, n: int, axis_name: str = "layers"):
    """Prepend a stacking dim (for scan-over-layers parameter stacks)."""
    return jax.tree_util.tree_map(
        lambda p: P((n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale),
        spec, is_leaf=is_p)


# ======================================================================
# Numerics primitives
# ======================================================================

def rms_norm(x, weight, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(x.dtype)


def head_rms_norm(x, weight, eps: float):
    """Per-head q/k norm (qwen3): x (..., hd), weight (hd,)."""
    return rms_norm(x, weight, eps)


def silu(x):
    return x * jax.nn.sigmoid(x)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", silu(g) * u, w_down)


# ----------------------------------------------------------------------
# RoPE (supports partial rotary: stablelm rope_pct=0.25)
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, rope_pct: float, theta: float):
    rot = int(head_dim * rope_pct)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, *, rope_pct: float = 1.0, theta: float = 10_000.0):
    """x: (..., S, H, hd) or (..., S, hd); positions: (..., S)."""
    hd = x.shape[-1]
    inv, rot = rope_freqs(hd, rope_pct, theta)
    if rot == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == positions.ndim + 2:  # head axis present
        cos, sin = cos[..., None, :], sin[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------------
# Depthwise causal conv1d (mamba2 / RG-LRU frontends)
# ----------------------------------------------------------------------

def causal_conv1d(x, w, state=None, activation: bool = True):
    """x: (B, S, C); w: (C, W) depthwise causal filter.

    Returns (y, new_state) where state (B, W-1, C) carries the last W-1 inputs
    (used for decode).  Training path pads with zeros (state None).
    ``activation=True`` applies SiLU (mamba2 convention); RG-LRU convs are
    linear (``activation=False``).
    """
    B, S, C = x.shape
    W = w.shape[-1]
    if state is None:
        pad = jnp.zeros((B, W - 1, C), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, C)
    # stack W shifted views: y_t = sum_k w[:, k] * x_{t-W+1+k}
    y = jnp.zeros_like(x)
    for k in range(W):
        y = y + xp[:, k:k + S, :] * w[:, k].astype(x.dtype)
    new_state = xp[:, S:, :] if W > 1 else jnp.zeros((B, 0, C), x.dtype)
    return (silu(y) if activation else y), new_state


# ----------------------------------------------------------------------
# Embedding / logits / loss (with vocab padding + optional vocab-parallel)
# ----------------------------------------------------------------------

def embed_spec(cfg):
    return P((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), scale=0.02)


def embed_lookup(table, ids):
    return jnp.take(table, ids, axis=0)


def logits_from_embed(x, table):
    return jnp.einsum("...d,vd->...v", x, table)


def softmax_xent(logits, labels, vocab_size: int):
    """Mean CE over tokens; padded-vocab columns masked out. fp32 internally."""
    lf = logits.astype(jnp.float32)
    V = lf.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    lf = jnp.where(col < vocab_size, lf, -1e30)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return jnp.mean(lse - gold)
