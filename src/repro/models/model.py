"""Model facade: one API over every assigned architecture family.

    lm = LM(cfg, max_seq=4096)
    params = lm.init(key)                          # real (smoke tests)
    aparams = lm.abstract()                        # ShapeDtypeStruct (dry-run)
    loss, metrics = lm.loss(params, batch, ctx)
    logits, cache = lm.prefill(params, batch, ctx)
    logits, cache = lm.decode_step(params, cache, batch, ctx)
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import DistContext, shard
from repro.models import encdec, transformer
from repro.models.layers import (
    P, abstract_params, axes_tree, embed_lookup, embed_spec, init_params,
    logits_from_embed, rms_norm, softmax_xent,
)


class LM:
    def __init__(self, cfg: ModelConfig, max_seq: int = 4096):
        self.cfg = cfg
        self.max_seq = max_seq
        self._spec = self._build_spec()

    # ------------------------------------------------------------------
    def _build_spec(self):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.encdec_spec(cfg, self.max_seq)
        spec: dict[str, Any] = {
            "embed": embed_spec(cfg),
            "decoder": transformer.decoder_spec(cfg),
            "ln_f": P((cfg.d_model,), ("embed",), init="zeros"),
        }
        if not cfg.tie_embeddings:
            spec["w_out"] = P((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"))
        return spec

    def spec(self):
        return self._spec

    def init(self, key, dtype=jnp.bfloat16):
        return init_params(self._spec, key, dtype)

    def abstract(self, dtype=jnp.bfloat16):
        return abstract_params(self._spec, dtype)

    def axes(self):
        return axes_tree(self._spec)

    # ------------------------------------------------------------------
    def _embed_in(self, params, tokens, ctx):
        x = embed_lookup(params["embed"], tokens)
        return shard(ctx, x, "batch", "seq", None)

    def _logits(self, params, x, ctx):
        table = params["embed"] if self.cfg.tie_embeddings else params["w_out"]
        out = logits_from_embed(x, table)
        return shard(ctx, out, "batch", "seq", "vocab")

    # ------------------------------------------------------------------
    def forward(self, params, batch, ctx: DistContext | None = None, *,
                remat: str = "none", want_cache: bool = False,
                cache_len: int | None = None):
        """Teacher-forced forward over full sequences.

        Returns (logits, aux, cache_or_None). ``batch["tokens"]`` is the
        decoder input (B, S); extra modality inputs per family.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape

        if cfg.family == "encdec":
            enc_out = encdec.encoder_forward(params, batch["encoder_frames"], cfg, ctx)
            x = self._embed_in(params, tokens, ctx)
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
            x = x + params["dec_pos"][:S][None].astype(x.dtype)
            x, cache = encdec.decoder_forward(params, x, enc_out, cfg, ctx,
                                              positions, want_cache=want_cache,
                                              cache_len=cache_len)
            x = rms_norm(x, params["ln_f"], cfg.norm_eps)
            if want_cache:
                cache["pos"] = jnp.full((B,), S, jnp.int32)
            return self._logits(params, x, ctx), 0.0, cache

        x = self._embed_in(params, tokens, ctx)
        if cfg.family == "vlm":
            ve = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([ve, x], axis=1)
        S_tot = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S_tot)[None, :], (B, S_tot))
        x, aux, cache = transformer.decoder_forward(
            params["decoder"], x, cfg, ctx, positions, remat=remat,
            want_cache=want_cache, cache_len=cache_len)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        if want_cache:
            cache["pos"] = jnp.full((B,), S_tot, jnp.int32)
        return self._logits(params, x, ctx), aux, cache

    # ------------------------------------------------------------------
    def loss(self, params, batch, ctx: DistContext | None = None, *,
             remat: str = "none"):
        """batch["tokens"]: (B, S+1) -> next-token CE (+ MoE aux)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        fwd_batch = dict(batch, tokens=inp)
        logits, aux, _ = self.forward(params, fwd_batch, ctx, remat=remat)
        if cfg.family == "vlm":
            logits = logits[:, cfg.num_patches:, :]
        ce = softmax_xent(logits, labels, cfg.vocab_size)
        metrics = {"ce": ce, "aux": aux}
        return ce + aux, metrics

    # ------------------------------------------------------------------
    def prefill(self, params, batch, ctx: DistContext | None = None,
                cache_len: int | None = None):
        """Process a prompt; returns (last-position logits (B,V), cache)."""
        logits, _, cache = self.forward(params, batch, ctx, want_cache=True,
                                        cache_len=cache_len)
        return logits[:, -1, :], cache

    def decode_step(self, params, cache, batch, ctx: DistContext | None = None):
        """One new token. batch["token"]: (B,1). Returns (logits (B,V), cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        x = self._embed_in(params, batch["token"], ctx)
        if cfg.family == "encdec":
            x = x + jnp.take(params["dec_pos"], jnp.clip(pos, 0, self.max_seq - 1),
                             axis=0)[:, None, :].astype(x.dtype)
            x, new_cache = encdec.decoder_decode(params, x, cfg, ctx, pos, cache)
        else:
            x, new_cache = transformer.decoder_decode(
                params["decoder"], x, cfg, ctx, pos, cache)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = self._logits(params, x, ctx)[:, 0, :]
        new_cache["pos"] = pos + 1
        return logits, new_cache

    # ------------------------------------------------------------------
    def init_cache(self, B: int, cache_len: int, dtype=jnp.bfloat16):
        if self.cfg.family == "encdec":
            return encdec.init_cache(self.cfg, B, cache_len, dtype)
        return transformer.init_cache(self.cfg, B, cache_len, dtype)

    def cache_axes(self, ctx: DistContext | None = None):
        if self.cfg.family == "encdec":
            return encdec.cache_axes(self.cfg, ctx)
        return transformer.cache_axes(self.cfg, ctx)
