"""Mixture-of-Experts FFN with gather-based capacity dispatch.

Design (DESIGN.md §3): instead of GShard one-hot dispatch einsums (whose
dispatch matmul is quadratic in tokens) we sort token->expert assignments,
``take`` tokens into an (E, C, d) buffer (gather: zero FLOPs), run the grouped
expert einsum (the only real FLOPs, ~= active-param FLOPs x capacity factor),
and combine with router weights.  Tokens beyond an expert's capacity are
dropped (standard practice; aux loss keeps the router balanced).

Sharding: EP mode shards the E axis over "model" (qwen3: 128 experts);
expert-TP mode shards each expert's hidden dim (qwen2: 60 experts, 60 % 16 != 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import shard
from repro.models.layers import P, silu, swiglu


def moe_spec(cfg):
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.d_ff
    spec = {
        "router": P((d, E), ("embed", "experts"), scale=0.02),
        "w_gate": P((E, d, ff), ("experts", "embed", "expert_mlp")),
        "w_up": P((E, d, ff), ("experts", "embed", "expert_mlp")),
        "w_down": P((E, ff, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.shared_expert_d_ff:
        sf = cfg.shared_expert_d_ff
        spec["shared"] = {
            "w_gate": P((d, sf), ("embed", "mlp")),
            "w_up": P((d, sf), ("embed", "mlp")),
            "w_down": P((sf, d), ("mlp", "embed")),
            "gate": P((d, 1), ("embed", None), scale=0.02),
        }
    return spec


def _capacity(tokens: int, cfg) -> int:
    c = int(tokens * cfg.experts_per_tok * cfg.capacity_factor / cfg.num_experts)
    return max(8, (c + 7) // 8 * 8)


def moe_ffn_shardmap(p, x, cfg, ctx):
    """MoE with *local* token routing inside shard_map (DESIGN §3).

    Tokens stay on their data shard — GSPMD's global-sort collectives vanish.
    Two expert layouts:

    * **EP** (E %% model == 0, e.g. qwen3/128): each model shard owns E/m
      experts and buffers only slots routed to them; traffic = per-layer
      ff-sharded weight gather over the data axes + ONE (T_local, d) psum.
    * **expert-TP** (e.g. qwen2/60): every shard holds all experts with a
      1/m slice of the ffn dim (row-parallel); traffic = ONE (T_local, d)
      psum of the combined output (the combine is linear, so it commutes
      with the cross-shard sum).
    """
    import numpy as np
    from jax.sharding import PartitionSpec as PS

    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_tok
    mesh = ctx.mesh
    msize = mesh.shape["model"]
    ep = ctx.rules.get("expert_mode") == "ep"
    Eloc = E // msize if ep else E
    batch = ctx.rules["batch"]
    ff_shard = ctx.rules.get("expert_mlp")  # EP: data axes; TP: "model"
    ff_axes = (() if not ep else
               ((ff_shard,) if isinstance(ff_shard, str) else tuple(ff_shard or ())))

    e_spec = "model" if ep else None
    w_spec = PS(e_spec, None, ff_shard)
    wd_spec = PS(e_spec, ff_shard, None)

    def local(xb, router, wg, wu, wd):
        Bl, Sl, _ = xb.shape
        Tl = Bl * Sl
        xt = xb.reshape(Tl, d)
        C = max(8, int(np.ceil(Tl * K * cfg.capacity_factor / E / 8)) * 8)

        logits = jnp.einsum("td,de->te", xt, router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, K)
        if cfg.norm_topk_prob:
            gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

        e_flat = idx.reshape(-1)
        order = jnp.argsort(e_flat, stable=True)
        counts = jnp.bincount(e_flat, length=E)
        starts = jnp.cumsum(counts) - counts
        rank_sorted = jnp.arange(Tl * K, dtype=jnp.int32) - starts[e_flat[order]]
        rank = jnp.zeros((Tl * K,), jnp.int32).at[order].set(rank_sorted)
        keep = (rank < C).reshape(Tl, K)

        if ep:  # only slots owned by THIS model shard get buffered
            e0 = jax.lax.axis_index("model").astype(jnp.int32) * Eloc
            local_e = idx - e0
            mine = keep & (local_e >= 0) & (local_e < Eloc)
            slot = jnp.where(mine, local_e * C + rank.reshape(Tl, K), Eloc * C)
        else:   # expert-TP: all experts local (ffn dim row-parallel)
            mine = keep
            slot = jnp.where(mine, idx * C + rank.reshape(Tl, K), Eloc * C)

        buf = jnp.zeros((Eloc * C + 1, d), x.dtype)
        for k in range(K):
            buf = buf.at[slot[:, k]].set(xt)
        buf = buf[: Eloc * C].reshape(Eloc, C, d)

        # EP: gather the ff-sharded weights of the local experts (fsdp-style)
        for ax in ff_axes:
            wg = jax.lax.all_gather(wg, ax, axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, ax, axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, ax, axis=1, tiled=True)

        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        eo = jnp.einsum("ecf,efd->ecd", silu(g) * u, wd)

        eo_flat = jnp.concatenate(
            [eo.reshape(Eloc * C, d), jnp.zeros((1, d), eo.dtype)], axis=0)
        w = (gate * mine).astype(x.dtype)
        out = jnp.zeros((Tl, d), x.dtype)
        for k in range(K):
            out = out + eo_flat[slot[:, k]] * w[:, k:k + 1]
        # EP: sum expert-shard partials; TP: sum row-parallel ffn partials —
        # either way exactly one (T_local, d) psum over the model axis.
        out = jax.lax.psum(out, "model")
        return out.reshape(Bl, Sl, d)

    from repro.distributed.sharding import shard_map
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(PS(batch, None, None), PS(None, None), w_spec, w_spec, wd_spec),
        out_specs=PS(batch, None, None),
        check_vma=False,
    )
    out = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    # aux load-balance loss on the global routing (router matmul is tiny)
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1).reshape(-1, E)
    idx = jnp.argmax(probs, axis=-1)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    if cfg.shared_expert_d_ff:
        sp = p["shared"]
        xt = x.reshape(-1, d)
        sgate = jax.nn.sigmoid(jnp.einsum("td,do->to", xt, sp["gate"]
                                          ).astype(jnp.float32))
        out = out + (sgate.astype(x.dtype) *
                     swiglu(xt, sp["w_gate"], sp["w_up"], sp["w_down"])
                     ).reshape(B, S, d)
    return out, aux


def moe_ffn(p, x, cfg, ctx=None):
    """x: (B, S, d) -> (out, aux_loss)."""
    if (ctx is not None and ctx.extra.get("moe_impl") == "shardmap"
            and ctx.rules.get("expert_mode") in ("ep", "tp")):
        return moe_ffn_shardmap(p, x, cfg, ctx)
    B, S, d = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_tok
    C = _capacity(T, cfg)
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                       # (T,K)
    if cfg.norm_topk_prob:
        gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # Load-balance auxiliary loss (Switch-style).
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # ---- dispatch: rank each (token, slot) within its expert via sort ----
    e_flat = idx.reshape(-1)                                  # (T*K,)
    order = jnp.argsort(e_flat, stable=True)                  # group by expert
    counts = jnp.bincount(e_flat, length=E)                   # (E,)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(T * K, dtype=jnp.int32) - starts[e_flat[order]]
    rank = jnp.zeros((T * K,), jnp.int32).at[order].set(rank_sorted)
    keep = (rank < C).reshape(T, K)
    slot = jnp.where(keep, idx * C + rank.reshape(T, K), E * C)  # drop->scratch

    # K scatters of (T, d) — never materializes a (T*K, d) intermediate
    buf = jnp.zeros((E * C + 1, d), x.dtype)
    for k in range(K):
        buf = buf.at[slot[:, k]].set(xt)
    buf = buf[: E * C].reshape(E, C, d)
    buf = shard(ctx, buf, "experts", "batch", None)

    # ---- grouped expert SwiGLU (the real FLOPs) ----
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = shard(ctx, silu(g) * u, "experts", "batch", "expert_mlp")
    eo = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    eo = shard(ctx, eo, "experts", "batch", None)

    # ---- combine: K gathers of (T, d), weighted sum ----
    eo_flat = jnp.concatenate([eo.reshape(E * C, d),
                               jnp.zeros((1, d), eo.dtype)], axis=0)
    w = (gate * keep).astype(x.dtype)                         # (T,K)
    out = jnp.zeros((T, d), x.dtype)
    for k in range(K):
        out = out + eo_flat[slot[:, k]] * w[:, k:k + 1]

    if cfg.shared_expert_d_ff:
        sp = p["shared"]
        sgate = jax.nn.sigmoid(jnp.einsum("td,do->to", xt, sp["gate"]).astype(jnp.float32))
        out = out + (sgate.astype(x.dtype) *
                     swiglu(xt, sp["w_gate"], sp["w_up"], sp["w_down"]))
    return out.reshape(B, S, d), aux
