"""Mamba-2 block: SSD (state-space duality) chunked algorithm [arXiv:2405.21060].

XLA reference path (used by train/prefill/dry-run); the Pallas TPU kernel in
:mod:`repro.kernels.ssd_scan` implements the same chunk-sequential algorithm
with the running state carried in VMEM scratch.

Shapes: x (B,S,H,P)  dt (B,S,H)  A (H,)<0  B_in/C_in (B,S,N) (one group).
Chunked: intra-chunk quadratic term + inter-chunk linear recurrence over
chunk states (H,P,N).  Decays computed in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import shard
from repro.models.layers import P, causal_conv1d, rms_norm, silu


def ssm_spec(cfg):
    d, din, H, N, W = (cfg.d_model, cfg.d_inner, cfg.ssm_heads,
                       cfg.ssm_state, cfg.conv_width)
    return {
        "w_z": P((d, din), ("embed", "ssm_inner")),
        "w_x": P((d, din), ("embed", "ssm_inner")),
        "w_B": P((d, N), ("embed", "ssm_state")),
        "w_C": P((d, N), ("embed", "ssm_state")),
        "w_dt": P((d, H), ("embed", "ssm_heads")),
        "conv_w": P((din + 2 * N, W), ("conv", None)),
        "dt_bias": P((H,), ("ssm_heads",), init="dt_bias"),
        "A_log": P((H,), ("ssm_heads",), init="a_log"),
        "D": P((H,), ("ssm_heads",), init="ones"),
        "norm_w": P((din,), ("ssm_inner",), init="zeros"),
        "w_out": P((din, d), ("ssm_inner", "embed")),
    }


def _segsum(dA):
    """dA (..., Q) -> cumulative sums; returns cums (..., Q) from chunk start."""
    return jnp.cumsum(dA, axis=-1)


def ssd_chunked(x, dt, A, B_in, C_in, chunk: int, init_state=None):
    """Returns (y, final_state).

    x (B,S,H,P)  dt (B,S,H) (post-softplus)  A (H,)  B_in/C_in (B,S,N).
    """
    Bz, S, H, Pd = x.shape
    N = B_in.shape[-1]
    Q = min(chunk, S)
    if S % Q:  # pad to a chunk multiple; dt=0 on pads => decay 1, contribution 0
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_in = jnp.pad(B_in, ((0, 0), (0, pad), (0, 0)))
        C_in = jnp.pad(C_in, ((0, 0), (0, pad), (0, 0)))
        y, final = ssd_chunked(x, dt, A, B_in, C_in, chunk, init_state)
        return y[:, :S], final
    nc = S // Q

    xc = x.reshape(Bz, nc, Q, H, Pd)
    dtc = dt.reshape(Bz, nc, Q, H).astype(jnp.float32)
    Bc = B_in.reshape(Bz, nc, Q, N)
    Cc = C_in.reshape(Bz, nc, Q, N)

    dA = dtc * A.astype(jnp.float32)                    # (B,nc,Q,H)
    cums = _segsum(jnp.swapaxes(dA, -1, -2))            # (B,nc,H,Q)
    cums = jnp.swapaxes(cums, -1, -2)                   # (B,nc,Q,H)

    # ---- intra-chunk (quadratic in Q) ----
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc, preferred_element_type=jnp.float32)
    Lmat = jnp.exp(cums[:, :, :, None, :] - cums[:, :, None, :, :])  # (B,nc,i,j,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(causal[None, None, :, :, None],
                  CB[..., None] * Lmat * dtc[:, :, None, :, :], 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M.astype(x.dtype), xc)

    # ---- chunk states ----
    dec_end = jnp.exp(cums[:, :, -1:, :] - cums)        # (B,nc,Q,H)
    wts = (dec_end * dtc).astype(x.dtype)
    states = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", wts, Bc.astype(x.dtype), xc)

    # ---- inter-chunk recurrence over chunk states ----
    chunk_decay = jnp.exp(dA.sum(axis=2))               # (B,nc,H)
    s0 = (jnp.zeros((Bz, H, Pd, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        dec, st = inp
        prev = carry
        new = dec[:, :, None, None] * prev + st.astype(jnp.float32)
        return new, prev

    final, prevs = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    prevs = jnp.moveaxis(prevs, 0, 1)                   # (B,nc,H,P,N) state before chunk

    dec_in = jnp.exp(cums).astype(x.dtype)              # decay from chunk start
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc.astype(x.dtype),
                         prevs.astype(x.dtype)) * dec_in[..., None]

    y = (y_intra + y_inter).reshape(Bz, S, H, Pd)
    return y, final.astype(x.dtype)


def ssm_forward(p, x_res, cfg, ctx=None, conv_state=None, ssm_state=None):
    """Full mamba2 mixer. x_res (B,S,d) -> (y (B,S,d), (conv_state, ssm_state))."""
    B, S, d = x_res.shape
    din, H, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    Pd = cfg.ssm_headdim

    z = jnp.einsum("bsd,di->bsi", x_res, p["w_z"])
    xb = jnp.einsum("bsd,di->bsi", x_res, p["w_x"])
    Bv = jnp.einsum("bsd,dn->bsn", x_res, p["w_B"])
    Cv = jnp.einsum("bsd,dn->bsn", x_res, p["w_C"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x_res, p["w_dt"])

    conv_in = jnp.concatenate([xb, Bv, Cv], axis=-1)
    conv_out, new_conv = causal_conv1d(conv_in, p["conv_w"], conv_state)
    xb, Bv, Cv = jnp.split(conv_out, [din, din + N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xb.reshape(B, S, H, Pd)
    xh = shard(ctx, xh, "batch", "seq", "ssm_heads", None)
    y, final_state = ssd_chunked(xh, dt, A, Bv, Cv, cfg.ssm_chunk, ssm_state)
    y = y + xh * p["D"].astype(x_res.dtype)[None, None, :, None]

    y = y.reshape(B, S, din)
    y = rms_norm(y * silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    return out, (new_conv, final_state)


def ssm_decode_step(p, x_res, cfg, conv_state, ssm_state, ctx=None):
    """One-token decode. x_res (B,1,d); conv_state (B,W-1,C); ssm_state (B,H,P,N)."""
    B = x_res.shape[0]
    din, H, N, Pd = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim

    z = jnp.einsum("bsd,di->bsi", x_res, p["w_z"])
    xb = jnp.einsum("bsd,di->bsi", x_res, p["w_x"])
    Bv = jnp.einsum("bsd,dn->bsn", x_res, p["w_B"])
    Cv = jnp.einsum("bsd,dn->bsn", x_res, p["w_C"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x_res, p["w_dt"])

    conv_in = jnp.concatenate([xb, Bv, Cv], axis=-1)
    conv_out, new_conv = causal_conv1d(conv_in, p["conv_w"], conv_state)
    xb, Bv, Cv = jnp.split(conv_out, [din, din + N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[:, 0, :] * A)                       # (B,H)

    xh = xb[:, 0].reshape(B, H, Pd)
    contrib = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0, :].astype(x_res.dtype),
                         Bv[:, 0].astype(x_res.dtype), xh)
    new_state = dA[:, :, None, None].astype(x_res.dtype) * ssm_state + contrib
    y = jnp.einsum("bn,bhpn->bhp", Cv[:, 0], new_state)
    y = y + xh * p["D"].astype(x_res.dtype)[None, :, None]

    y = y.reshape(B, 1, din)
    y = rms_norm(y * silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    return out, (new_conv, new_state)
