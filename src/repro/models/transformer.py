"""Decoder LM stack: scan-over-layers for every family (dense / moe / ssm /
hybrid / vlm), with train, prefill and decode paths and pytree KV/state caches.

Layer parameters are stacked on a leading "layers" (or "groups") axis so the
HLO stays small regardless of depth (94-layer qwen3 compiles as one scanned
block) — essential for dry-run compile times and standard MaxText practice.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.context import shard
from repro.models import attention as attn
from repro.models import griffin, moe, ssm
from repro.models.layers import (
    P, rms_norm, stack_spec, swiglu,
)

Axes = tuple


# ======================================================================
# Param specs
# ======================================================================

def mlp_spec(cfg):
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "w_gate": P((d, ff), ("embed", "mlp")),
        "w_up": P((d, ff), ("embed", "mlp")),
        "w_down": P((ff, d), ("mlp", "embed")),
    }


def layer_spec(cfg, kind: str):
    d = cfg.d_model
    ln = lambda: P((d,), ("embed",), init="zeros")
    if kind == "ssm":
        return {"ln": ln(), "mixer": ssm.ssm_spec(cfg)}
    if kind == "rec":
        return {"ln1": ln(), "mixer": griffin.rglru_spec(cfg),
                "ln2": ln(), "mlp": mlp_spec(cfg)}
    spec = {"ln1": ln(), "attn": attn.attn_spec(cfg), "ln2": ln()}
    spec["ffn"] = moe.moe_spec(cfg) if cfg.family == "moe" else mlp_spec(cfg)
    return spec


def decoder_spec(cfg):
    kinds = cfg.layer_kinds()
    L = cfg.num_layers
    if len(set(kinds)) == 1:
        return {"stack": stack_spec(layer_spec(cfg, kinds[0]), L)}
    pat = cfg.block_pattern
    G = L // len(pat)
    tail_kinds = kinds[G * len(pat):]
    group = {f"b{i}_{k}": layer_spec(cfg, k) for i, k in enumerate(pat)}
    spec: dict[str, Any] = {"groups": stack_spec(group, G, "groups")}
    if tail_kinds:
        spec["tail"] = {f"t{i}_{k}": layer_spec(cfg, k) for i, k in enumerate(tail_kinds)}
    return spec


# ======================================================================
# Single blocks (train/prefill mode)
# ======================================================================

def _o_proj(o, wo):
    return jnp.einsum("bshk,hkd->bsd", o, wo)


def attn_block_fwd(lp, x, cfg, ctx, positions, *, window: int = 0,
                   want_cache: bool = False, cache_len: int | None = None):
    """Returns (x, aux, cache_entry)."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = attn.qkv_project(lp["attn"], h, cfg, positions)
    S = x.shape[1]
    if window and S > window and S % window == 0:
        o = attn.banded_local_attention(q, k, v, window=window)
    else:
        cq = S if cfg.exact_costs else 1024
        o = attn.full_causal_attention(q, k, v, chunk_q=cq)
    x = x + _o_proj(o, lp["attn"]["wo"])
    x = shard(ctx, x, "batch", "seq", None)

    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        f, aux = moe.moe_ffn(lp["ffn"], h2, cfg, ctx)
    else:
        f, aux = swiglu(h2, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                        lp["ffn"]["w_down"]), 0.0
    x = x + f
    x = shard(ctx, x, "batch", "seq", None)

    entry = None
    if want_cache:
        target = min(window, cache_len or S) if window else (cache_len or S)
        if window and S >= window:
            # ring layout: global position p lives in slot p % W
            shift = (S - window) % window
            kc = jnp.swapaxes(jnp.roll(k[:, -window:], shift, axis=1), 1, 2)
            vc = jnp.swapaxes(jnp.roll(v[:, -window:], shift, axis=1), 1, 2)
        else:
            kc, vc = jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)
        if target > kc.shape[2]:  # pre-allocate future decode slots
            pad = ((0, 0), (0, 0), (0, target - kc.shape[2]), (0, 0))
            kc, vc = jnp.pad(kc, pad), jnp.pad(vc, pad)
        entry = {"k": kc, "v": vc}
    return x, aux, entry


def ssm_block_fwd(lp, x, cfg, ctx, *, want_cache: bool = False):
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    y, (conv_st, ssm_st) = ssm.ssm_forward(lp["mixer"], h, cfg, ctx)
    x = x + y
    x = shard(ctx, x, "batch", "seq", None)
    entry = {"conv": conv_st, "ssm": ssm_st} if want_cache else None
    return x, 0.0, entry


def rec_block_fwd(lp, x, cfg, ctx, *, want_cache: bool = False):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    y, (conv_st, lru_st) = griffin.recurrent_forward(lp["mixer"], h, cfg, ctx)
    x = x + y
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
    x = shard(ctx, x, "batch", "seq", None)
    entry = {"conv": conv_st, "lru": lru_st} if want_cache else None
    return x, 0.0, entry


def block_fwd(kind, lp, x, cfg, ctx, positions, want_cache, cache_len=None):
    if kind == "ssm":
        return ssm_block_fwd(lp, x, cfg, ctx, want_cache=want_cache)
    if kind == "rec":
        return rec_block_fwd(lp, x, cfg, ctx, want_cache=want_cache)
    window = cfg.local_window if cfg.block_pattern else 0
    return attn_block_fwd(lp, x, cfg, ctx, positions, window=window,
                          want_cache=want_cache, cache_len=cache_len)


# ======================================================================
# Single blocks (decode mode)
# ======================================================================

def attn_block_dec(lp, x, cfg, ctx, pos, cache, *, window: int = 0):
    from repro.distributed.decode_attn import sp_decode_attention

    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = attn.qkv_project(lp["attn"], h, cfg, pos[:, None])
    if window:
        kc, vc = attn.cache_write_window(cache["k"], cache["v"], k, v, pos)
        o = attn.decode_attention_window(q, kc, vc, pos, window=window)
    elif ctx is not None and ctx.sp_decode:
        o, kc, vc = sp_decode_attention(ctx, q, cache["k"], cache["v"], k, v, pos)
    else:
        kc, vc = attn.cache_write_plain(cache["k"], cache["v"], k, v, pos)
        o = attn.decode_attention_plain(q, kc, vc, pos)
    x = x + _o_proj(o, lp["attn"]["wo"])

    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        f, _ = moe.moe_ffn(lp["ffn"], h2, cfg, ctx)
    else:
        f = swiglu(h2, lp["ffn"]["w_gate"], lp["ffn"]["w_up"], lp["ffn"]["w_down"])
    return x + f, {"k": kc, "v": vc}


def ssm_block_dec(lp, x, cfg, ctx, cache):
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    y, (conv_st, ssm_st) = ssm.ssm_decode_step(
        lp["mixer"], h, cfg, cache["conv"], cache["ssm"], ctx)
    return x + y, {"conv": conv_st, "ssm": ssm_st}


def rec_block_dec(lp, x, cfg, ctx, cache):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    y, (conv_st, lru_st) = griffin.recurrent_forward(
        lp["mixer"], h, cfg, ctx, conv_state=cache["conv"],
        lru_state=cache["lru"], decode=True)
    x = x + y
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
    return x, {"conv": conv_st, "lru": lru_st}


def block_dec(kind, lp, x, cfg, ctx, pos, cache):
    if kind == "ssm":
        return ssm_block_dec(lp, x, cfg, ctx, cache)
    if kind == "rec":
        return rec_block_dec(lp, x, cfg, ctx, cache)
    window = cfg.local_window if cfg.block_pattern else 0
    return attn_block_dec(lp, x, cfg, ctx, pos, cache, window=window)


# ======================================================================
# Stacked decoder forward
# ======================================================================

def _remat_wrap(f, remat: str):
    if remat == "none":
        return f
    policy = (jax.checkpoint_policies.dots_saveable if remat == "dots"
              else jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(f, policy=policy)


def decoder_forward(params, x, cfg, ctx, positions, *, remat: str = "none",
                    want_cache: bool = False, cache_len: int | None = None):
    """Returns (x, aux_total, cache_or_None)."""
    kinds = cfg.layer_kinds()

    if "stack" in params:
        kind = kinds[0]

        def body(carry, lp):
            x, aux = carry
            x, a, entry = block_fwd(kind, lp, x, cfg, ctx, positions, want_cache,
                                    cache_len)
            return (x, aux + a), entry

        if cfg.exact_costs:  # unrolled python loop: exact HLO cost analysis
            step = _remat_wrap(body, remat)
            aux, entries_l = 0.0, []
            for i in range(cfg.num_layers):
                lp = jax.tree_util.tree_map(lambda a: a[i], params["stack"])
                (x, aux), e = step((x, aux), lp)
                entries_l.append(e)
            entries = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *entries_l)
                       if want_cache else None)
            return x, aux, ({"stack": entries} if want_cache else None)

        (x, aux), entries = jax.lax.scan(
            _remat_wrap(body, remat), (x, 0.0), params["stack"])
        return x, aux, ({"stack": entries} if want_cache else None)

    # hybrid: scan over pattern groups, then unrolled tail
    pat = cfg.block_pattern
    names = [f"b{i}_{k}" for i, k in enumerate(pat)]

    def gbody(carry, gp):
        x, aux = carry
        entries = {}
        for name, kind in zip(names, pat):
            x, a, e = block_fwd(kind, gp[name], x, cfg, ctx, positions,
                                want_cache, cache_len)
            aux = aux + a
            entries[name] = e
        return (x, aux), entries

    if cfg.exact_costs:
        G = cfg.num_layers // len(pat)
        gstep = _remat_wrap(gbody, remat)
        aux, gl = 0.0, []
        for i in range(G):
            gp = jax.tree_util.tree_map(lambda a: a[i], params["groups"])
            (x, aux), ge = gstep((x, aux), gp)
            gl.append(ge)
        gentries = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *gl)
                    if want_cache else None)
    else:
        (x, aux), gentries = jax.lax.scan(
            _remat_wrap(gbody, remat), (x, 0.0), params["groups"])

    tentries = {}
    if "tail" in params:
        G = cfg.num_layers // len(pat)
        tail_kinds = kinds[G * len(pat):]
        for i, kind in enumerate(tail_kinds):
            name = f"t{i}_{kind}"
            x, a, e = block_fwd(kind, params["tail"][name], x, cfg, ctx,
                                positions, want_cache, cache_len)
            aux = aux + a
            tentries[name] = e
    cache = {"groups": gentries, "tail": tentries} if want_cache else None
    return x, aux, cache


def decoder_decode(params, x, cfg, ctx, pos, cache):
    """One-token decode through the stack. Returns (x, new_cache)."""
    kinds = cfg.layer_kinds()

    if "stack" in params:
        kind = kinds[0]

        def body(x, lp_cache):
            lp, c = lp_cache
            x, nc = block_dec(kind, lp, x, cfg, ctx, pos, c)
            return x, nc

        if cfg.exact_costs:
            outs = []
            for i in range(cfg.num_layers):
                sl = jax.tree_util.tree_map(lambda a: a[i],
                                            (params["stack"], cache["stack"]))
                x, nc = body(x, sl)
                outs.append(nc)
            new_entries = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
            return x, {"stack": new_entries}

        x, new_entries = jax.lax.scan(body, x, (params["stack"], cache["stack"]))
        return x, {"stack": new_entries}

    pat = cfg.block_pattern
    names = [f"b{i}_{k}" for i, k in enumerate(pat)]

    def gbody(x, gp_c):
        gp, gc = gp_c
        out = {}
        for name, kind in zip(names, pat):
            x, out[name] = block_dec(kind, gp[name], x, cfg, ctx, pos, gc[name])
        return x, out

    if cfg.exact_costs:
        G = cfg.num_layers // len(pat)
        outs = []
        for i in range(G):
            sl = jax.tree_util.tree_map(lambda a: a[i],
                                        (params["groups"], cache["groups"]))
            x, nc = gbody(x, sl)
            outs.append(nc)
        g_new = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    else:
        x, g_new = jax.lax.scan(gbody, x, (params["groups"], cache["groups"]))

    t_new = {}
    if "tail" in params:
        G = cfg.num_layers // len(pat)
        tail_kinds = kinds[G * len(pat):]
        for i, kind in enumerate(tail_kinds):
            name = f"t{i}_{kind}"
            x, t_new[name] = block_dec(kind, params["tail"][name], x, cfg, ctx,
                                       pos, cache["tail"][name])
    return x, {"groups": g_new, "tail": t_new}


# ======================================================================
# Cache construction (zeros; abstract under jax.eval_shape)
# ======================================================================

def _attn_cache(cfg, L_axis: str, L: int, B: int, S: int, window: int, dtype):
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    Sc = min(window, S) if window else S
    return {"k": jnp.zeros((L, B, KV, Sc, hd), dtype),
            "v": jnp.zeros((L, B, KV, Sc, hd), dtype)}


def init_cache(cfg, B: int, cache_len: int, dtype=jnp.bfloat16):
    """Zeros cache pytree for the decoder stack (call under eval_shape for
    abstract specs)."""
    kinds = cfg.layer_kinds()
    L = cfg.num_layers
    if len(set(kinds)) == 1:
        kind = kinds[0]
        if kind == "ssm":
            C = cfg.d_inner + 2 * cfg.ssm_state
            entry = {"conv": jnp.zeros((L, B, cfg.conv_width - 1, C), dtype),
                     "ssm": jnp.zeros((L, B, cfg.ssm_heads, cfg.ssm_headdim,
                                       cfg.ssm_state), dtype)}
        else:
            entry = _attn_cache(cfg, "layers", L, B, cache_len, 0, dtype)
        return {"stack": entry, "pos": jnp.zeros((B,), jnp.int32)}

    pat = cfg.block_pattern
    G = L // len(pat)
    gcache, tcache = {}, {}

    def one(kind, n):
        if kind == "rec":
            return {"conv": jnp.zeros((n, B, cfg.conv_width - 1, cfg.lru_width), dtype),
                    "lru": jnp.zeros((n, B, cfg.lru_width), dtype)}
        return _attn_cache(cfg, "groups", n, B, cache_len, cfg.local_window, dtype)

    for i, kind in enumerate(pat):
        gcache[f"b{i}_{kind}"] = one(kind, G)
    tail_kinds = kinds[G * len(pat):]
    for i, kind in enumerate(tail_kinds):
        e = one(kind, 1)
        tcache[f"t{i}_{kind}"] = jax.tree_util.tree_map(lambda a: a[0], e)
    return {"groups": gcache, "tail": tcache, "pos": jnp.zeros((B,), jnp.int32)}


def cache_axes(cfg, ctx) -> Any:
    """Logical axes tree matching init_cache output (for shardings)."""
    sp = ctx is not None and ctx.sp_decode
    full_attn = ("layers", "batch", None, "cache_seq" if sp else None, None)
    win_attn = ("layers", "batch", None, None, None)
    kinds = cfg.layer_kinds()
    L = cfg.num_layers
    if len(set(kinds)) == 1:
        if kinds[0] == "ssm":
            entry = {"conv": ("layers", "batch", None, None),
                     "ssm": ("layers", "batch", "ssm_heads", None, None)}
        else:
            entry = {"k": full_attn, "v": full_attn}
        return {"stack": entry, "pos": ("batch",)}
    pat = cfg.block_pattern
    G = L // len(pat)

    def one(kind, stacked=True):
        if kind == "rec":
            c = {"conv": ("groups", "batch", None, "lru"),
                 "lru": ("groups", "batch", "lru")}
        else:
            c = {"k": win_attn, "v": win_attn}
        if not stacked:
            c = jax.tree_util.tree_map(lambda ax: ax[1:], c,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return c

    g = {f"b{i}_{k}": one(k) for i, k in enumerate(pat)}
    tail_kinds = kinds[G * len(pat):]
    t = {f"t{i}_{k}": one(k, stacked=False) for i, k in enumerate(tail_kinds)}
    return {"groups": g, "tail": t, "pos": ("batch",)}
