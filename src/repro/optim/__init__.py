from repro.optim.optimizer import (
    OptState, adamw_update, global_norm, init_opt_state, make_schedule,
)

__all__ = ["OptState", "adamw_update", "global_norm", "init_opt_state",
           "make_schedule"]
