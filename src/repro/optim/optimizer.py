"""AdamW with cosine / WSD schedules, fp32 master weights, global-norm clip.

Optimizer state is a pytree mirroring the params; under ZeRO-1 the moments
and master weights are additionally sharded over the data axes (see
``repro.distributed.sharding.zero1_axes``) — GSPMD then emits
reduce-scatter(grads) / all-gather(params) around the update.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict
    master: dict


def make_schedule(tc: TrainConfig):
    """cosine: warmup -> cosine to 10%.  wsd (minicpm): warmup -> stable ->
    linear decay over the last ``wsd_decay_frac`` of training."""
    base = tc.learning_rate

    def sched(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = jnp.minimum(s / jnp.maximum(tc.warmup_steps, 1), 1.0)
        if tc.schedule == "wsd":
            decay_steps = max(int(tc.total_steps * tc.wsd_decay_frac), 1)
            start = tc.total_steps - decay_steps
            frac = jnp.clip((s - start) / decay_steps, 0.0, 1.0)
            return base * warm * (1.0 - 0.9 * frac)
        prog = jnp.clip(s / max(tc.total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return base * warm * (0.1 + 0.9 * cos)

    return sched


def init_opt_state(params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree_util.tree_map(jnp.copy, zeros), master)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(tc: TrainConfig, state: OptState, grads, params):
    """Returns (new_state, new_params(bf16-cast), metrics)."""
    sched = make_schedule(tc)
    step = state.step + 1
    lr = sched(step)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1, b2, eps, wd = tc.beta1, tc.beta2, tc.eps, tc.weight_decay
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * clip
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        update = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps) + wd * w
        w2 = w - lr * update
        return m2, v2, w2

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_w = treedef.flatten_up_to(state.master)
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_w = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])

    pdtype = jax.tree_util.tree_leaves(params)[0].dtype
    new_params = jax.tree_util.tree_map(lambda w: w.astype(pdtype), new_w)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return OptState(step, new_m, new_v, new_w), new_params, metrics
