"""Hypothesis shim: the container image doesn't bundle ``hypothesis``, and
tier-1 must not pip-install.  When it is available we use it unchanged; when
it is missing, property tests run against a fixed number of seeded random
samples instead of being collection errors."""
from __future__ import annotations

import random

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings  # noqa: F401 — re-export shim
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class _St:
        @staticmethod
        def integers(lo=0, hi=100):
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def floats(lo=0.0, hi=1.0, allow_nan=None, allow_infinity=None):
            return _Strategy(lambda rng: rng.uniform(lo, hi))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def sample(rng):
                n = rng.randint(min_size, max_size)
                return [elem.sample(rng) for _ in range(n)]
            return _Strategy(sample)

    st = _St()

    def given(*strats):
        def deco(fn):
            def wrapper(*args, **kw):
                rng = random.Random(0)
                for _ in range(25):
                    fn(*args, *(s.sample(rng) for s in strats), **kw)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(**kw):
        return lambda fn: fn
