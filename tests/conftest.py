# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
