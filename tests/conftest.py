# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.
import signal

import jax
import pytest

# Per-test watchdog (120 s) so an event-loop livelock fails fast instead of
# hanging the whole run.  CI installs pytest-timeout and passes --timeout=120;
# when the plugin is absent (local runs) fall back to a SIGALRM alarm.
_TEST_TIMEOUT_S = 120

try:
    import pytest_timeout  # noqa: F401
    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False

if not _HAVE_PYTEST_TIMEOUT and hasattr(signal, "SIGALRM"):

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        def _alarm(signum, frame):
            raise TimeoutError(
                f"{item.nodeid} exceeded {_TEST_TIMEOUT_S}s "
                f"(livelock watchdog; see tests/conftest.py)")

        old = signal.signal(signal.SIGALRM, _alarm)
        signal.setitimer(signal.ITIMER_REAL, _TEST_TIMEOUT_S)
        try:
            yield
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0)
            signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
