"""Migration analyzer: policies + Algorithm 2 (paper §II-C)."""
import numpy as np

from repro.core import (
    ContextDetector, KnowledgeBase, MigrationAnalyzer, Notebook, PerfModel,
    fit_linear, intersection, substitute_kwarg,
)


def test_substitute_kwarg():
    src = "m = model.fit(x, epochs=50, bs=4)"
    out = substitute_kwarg(src, "epochs", 2)
    assert "epochs=2" in out and "bs=4" in out


def test_intersection_paper_fig11():
    # local slope 21.5, remote slope 21.5/4.43=4.85, migration 120s:
    # paper: "for epochs e > 7, the migration pays off"
    ml = (21.5, 30.0)
    mr = (21.5 / 4.43, 30.0 / 1.0)
    e = intersection(ml, mr, migration_time=120.0)
    assert 6.0 < e < 8.5


def test_intersection_remote_never_wins():
    assert intersection((1.0, 0.0), (2.0, 5.0)) == float("inf")


def test_knowledge_policy_decision():
    kb = KnowledgeBase()
    kb.seed("epochs", 7.0)
    an = MigrationAnalyzer(kb, ContextDetector())
    nb = Notebook("nb")
    hi = nb.add_cell("m = fit(x, epochs=50)")
    lo = nb.add_cell("m = fit(x, epochs=3)")
    assert an.decide(nb, hi).env == "remote"
    assert an.decide(nb, lo).env == "local"
    assert any("knowledge" in a for a in hi.annotations)  # explainability


def test_performance_single_policy():
    kb = KnowledgeBase()
    perf = PerfModel()
    an = MigrationAnalyzer(kb, ContextDetector(), perf, policy="single",
                           use_knowledge=False, migration_latency=1.0,
                           migration_bandwidth=1e9)
    nb = Notebook("nb")
    cell = nb.add_cell("z = crunch(x)")
    # no history -> local
    assert an.decide(nb, cell).env == "local"
    perf.observe(cell.cell_id, "local", 60.0)
    perf.observe(cell.cell_id, "remote", 2.0)
    an.observe_state_size("nb", 1e6)
    assert an.decide(nb, cell).env == "remote"
    # huge state -> migration dominates -> stay local
    an.observe_state_size("nb", 1e12)
    assert an.decide(nb, cell).env == "local"


def test_performance_block_policy_uses_context():
    kb = KnowledgeBase()
    ctxd = ContextDetector()
    perf = PerfModel()
    an = MigrationAnalyzer(kb, ctxd, perf, policy="block", use_knowledge=False,
                           migration_latency=5.0, migration_bandwidth=1e9)
    nb = Notebook("nb")
    cells = [nb.add_cell(f"s{i} = work_{i}()") for i in range(3)]
    # history: block (0,1,2) repeatedly, plus a distinct (0,1) run so the
    # evidence guard (>=2 candidate sequences) is satisfied
    for _ in range(3):
        for o in range(3):
            ctxd.record("nb", o)
    ctxd.record("nb", 0)
    ctxd.record("nb", 1)
    for c in cells:  # cheap individually, worthwhile as a block
        perf.observe(c.cell_id, "local", 8.0)
        perf.observe(c.cell_id, "remote", 0.4)
    an.observe_state_size("nb", 1e6)
    d = an.decide(nb, cells[0])
    # Algorithm-1 scoring prefers the most frequent subsequence (0,1)
    assert d.env == "remote" and d.block in ((0, 1), (0, 1, 2))


def test_algorithm2_updates_kb():
    kb = KnowledgeBase()
    kb.seed("epochs", 50.0)
    an = MigrationAnalyzer(kb, ContextDetector(), migration_latency=120.0,
                           migration_bandwidth=1e12)
    an.state_size_estimate["default"] = 0.0
    nb = Notebook("nb")
    cell = nb.add_cell("m = fit(x, epochs=20)")

    class RT:
        def probe(self, src, env):
            import re
            e = int(re.search(r"epochs=(\d+)", src).group(1))
            return 30 + 21.5 * e if env == "local" else 30 + (21.5 / 4.43) * e

    updated = an.update_parameters(cell, RT())
    assert 6.0 < updated["epochs"] < 8.5
    assert kb.get("epochs").source == "learned"
    assert kb.get("epochs").threshold == updated["epochs"]
    assert kb.records("kb-update")
    # linear fit sanity
    a, b = fit_linear([1, 2, 3], [51.5, 73.0, 94.5])
    assert abs(a - 21.5) < 1e-6 and abs(b - 30.0) < 1e-6
