"""Migration analyzer: policies + Algorithm 2 (paper §II-C)."""

from repro.core import (
    ContextDetector, KnowledgeBase, MigrationAnalyzer, Notebook, PerfModel,
    fit_linear, intersection, substitute_kwarg,
)


def test_substitute_kwarg():
    src = "m = model.fit(x, epochs=50, bs=4)"
    out = substitute_kwarg(src, "epochs", 2)
    assert "epochs=2" in out and "bs=4" in out


def test_intersection_paper_fig11():
    # local slope 21.5, remote slope 21.5/4.43=4.85, migration 120s:
    # paper: "for epochs e > 7, the migration pays off"
    ml = (21.5, 30.0)
    mr = (21.5 / 4.43, 30.0 / 1.0)
    e = intersection(ml, mr, migration_time=120.0)
    assert 6.0 < e < 8.5


def test_intersection_remote_never_wins():
    assert intersection((1.0, 0.0), (2.0, 5.0)) == float("inf")


def test_knowledge_policy_decision():
    kb = KnowledgeBase()
    kb.seed("epochs", 7.0)
    an = MigrationAnalyzer(kb, ContextDetector())
    nb = Notebook("nb")
    hi = nb.add_cell("m = fit(x, epochs=50)")
    lo = nb.add_cell("m = fit(x, epochs=3)")
    assert an.decide(nb, hi).env == "remote"
    assert an.decide(nb, lo).env == "local"
    assert any("knowledge" in a for a in hi.annotations)  # explainability


def test_performance_single_policy():
    kb = KnowledgeBase()
    perf = PerfModel()
    an = MigrationAnalyzer(kb, ContextDetector(), perf, policy="single",
                           use_knowledge=False, migration_latency=1.0,
                           migration_bandwidth=1e9)
    nb = Notebook("nb")
    cell = nb.add_cell("z = crunch(x)")
    # no history -> local
    assert an.decide(nb, cell).env == "local"
    perf.observe(cell.cell_id, "local", 60.0)
    perf.observe(cell.cell_id, "remote", 2.0)
    an.observe_state_size("nb", 1e6)
    assert an.decide(nb, cell).env == "remote"
    # huge state -> migration dominates -> stay local
    an.observe_state_size("nb", 1e12)
    assert an.decide(nb, cell).env == "local"


def test_performance_block_policy_uses_context():
    kb = KnowledgeBase()
    ctxd = ContextDetector()
    perf = PerfModel()
    an = MigrationAnalyzer(kb, ctxd, perf, policy="block", use_knowledge=False,
                           migration_latency=5.0, migration_bandwidth=1e9)
    nb = Notebook("nb")
    cells = [nb.add_cell(f"s{i} = work_{i}()") for i in range(3)]
    # history: block (0,1,2) repeatedly, plus a distinct (0,1) run so the
    # evidence guard (>=2 candidate sequences) is satisfied
    for _ in range(3):
        for o in range(3):
            ctxd.record("nb", o)
    ctxd.record("nb", 0)
    ctxd.record("nb", 1)
    for c in cells:  # cheap individually, worthwhile as a block
        perf.observe(c.cell_id, "local", 8.0)
        perf.observe(c.cell_id, "remote", 0.4)
    an.observe_state_size("nb", 1e6)
    d = an.decide(nb, cells[0])
    # Algorithm-1 scoring prefers the most frequent subsequence (0,1)
    assert d.env == "remote" and d.block in ((0, 1), (0, 1, 2))


def test_algorithm2_updates_kb():
    kb = KnowledgeBase()
    kb.seed("epochs", 50.0)
    an = MigrationAnalyzer(kb, ContextDetector(), migration_latency=120.0,
                           migration_bandwidth=1e12)
    an.state_size_estimate["default"] = 0.0
    nb = Notebook("nb")
    cell = nb.add_cell("m = fit(x, epochs=20)")

    class RT:
        def probe(self, src, env):
            import re
            e = int(re.search(r"epochs=(\d+)", src).group(1))
            return 30 + 21.5 * e if env == "local" else 30 + (21.5 / 4.43) * e

    updated = an.update_parameters(cell, RT())
    assert 6.0 < updated["epochs"] < 8.5
    assert kb.get("epochs").source == "learned"
    assert kb.get("epochs").threshold == updated["epochs"]
    assert kb.records("kb-update")
    # linear fit sanity
    a, b = fit_linear([1, 2, 3], [51.5, 73.0, 94.5])
    assert abs(a - 21.5) < 1e-6 and abs(b - 30.0) < 1e-6


def _horizon_fixture():
    from repro.core import EnvironmentRegistry
    reg = EnvironmentRegistry(default_bandwidth=1e9, default_latency=2.0)
    from repro.core import ExecutionEnvironment
    reg.register(ExecutionEnvironment("local"), home=True)
    reg.register(ExecutionEnvironment("remote", speedup=10.0))
    kb = KnowledgeBase()
    ctxd = ContextDetector("markov")
    perf = PerfModel()
    an = MigrationAnalyzer(kb, ctxd, perf, policy="horizon",
                           use_knowledge=False, registry=reg, horizon=4)
    an.observe_state_size("nb", 1.0)
    nb = Notebook("nb")
    cells = [nb.add_cell(f"s{i} = work_{i}()", cost=8.0) for i in range(4)]
    for c in cells:
        perf.observe(c.cell_id, "local", 8.0)
        perf.observe(c.cell_id, "remote", 0.8)
    return an, ctxd, nb, cells


def test_horizon_policy_amortizes_over_expected_block():
    """Each cell alone is NOT worth a round trip (0.8 + 2x2s > 8s is false —
    make migration heavy enough that a single cell loses but the expected
    4-cell block wins): the DP must see the predicted continuation."""
    an, ctxd, nb, cells = _horizon_fixture()
    # migration latency 2s: single-cell 0.8 + 4.0 < 8.0 still wins, so
    # raise the bar: latency such that one cell loses, four cells win
    an.registry.connect("local", "remote", latency=10.0)
    # history: the 0-1-2-3 loop, strongly predicted by the markov model
    for _ in range(5):
        for o in range(4):
            ctxd.record("nb", o)
    d = an.decide(nb, cells[0], current_env="local")
    # expected block cost remote: 4*0.8 + 10 + 10 = 23.2 < local 32
    assert d.env == "remote" and d.migrate
    assert d.policy == "horizon"
    assert 1 in d.block and len(d.block) >= 2
    assert "horizon" in cells[0].annotations[-1]

    # a single isolated cell (no predicted continuation) must NOT migrate:
    # 0.8 + 10 + 10 > 8
    ctxd2 = ContextDetector("markov")
    an2, _, nb2, cells2 = _horizon_fixture()
    an2.registry.connect("local", "remote", latency=10.0)
    an2.context = ctxd2                  # fresh model: no history at all
    d2 = an2.decide(nb2, cells2[0], current_env="local")
    assert d2.env == "local" and not d2.migrate


def test_horizon_policy_no_history_stays_home():
    an, ctxd, nb, cells = _horizon_fixture()
    nb2 = Notebook("nb2")
    c = nb2.add_cell("q = 1")            # no cost, no perf history
    d = an.decide(nb2, c, current_env="local")
    assert d.env == "local" and not d.migrate
    assert "no history" in d.reason


def test_horizon_requires_registry():
    import pytest
    with pytest.raises(ValueError):
        MigrationAnalyzer(KnowledgeBase(), ContextDetector(),
                          policy="horizon")

def test_horizon_memoization_is_bit_identical_and_cheaper():
    """Within one decision the chained step distributions re-query the
    interaction model for the same cell many times; the memo must change
    the model-call count, never the decision."""
    runs = {}
    for memo in (False, True):
        an, ctxd, nb, cells = _horizon_fixture()
        an.registry.connect("local", "remote", latency=10.0)
        for _ in range(5):
            for o in range(4):
                ctxd.record("nb", o)
        pol = an._chain[-1]
        pol.memoize = memo
        pol.model_calls = 0
        decisions = [an.decide(nb, c, current_env="local", peek=True)
                     for c in cells]
        runs[memo] = (
            [(d.env, d.migrate, d.reason, tuple(d.block), d.policy)
             for d in decisions],
            pol.model_calls)
    assert runs[True][0] == runs[False][0]          # bit-identical outcomes
    assert runs[True][1] < runs[False][1]           # strictly fewer queries


def test_horizon_memo_scope_is_one_decision():
    """The cache must not leak across decisions: new history between two
    decide() calls changes the distributions and must be observed."""
    an, ctxd, nb, cells = _horizon_fixture()
    an.registry.connect("local", "remote", latency=10.0)
    d_cold = an.decide(nb, cells[0], current_env="local", peek=True)
    assert d_cold.env == "local"                    # no history: stay home
    for _ in range(5):
        for o in range(4):
            ctxd.record("nb", o)
    d_hot = an.decide(nb, cells[0], current_env="local", peek=True)
    assert d_hot.env == "remote"                    # fresh history respected


def test_offload_target_all_candidates_down_falls_back_home():
    """Every non-home env failed: placement stays put instead of crashing
    (regression: offload_target() indexed an empty candidate list, so any
    policy decision after the fleet's only offload env died raised)."""
    from repro.core import EnvironmentRegistry, ExecutionEnvironment
    reg = EnvironmentRegistry()
    reg.register(ExecutionEnvironment("local"), home=True)
    reg.register(ExecutionEnvironment("remote", speedup=10.0))
    an = MigrationAnalyzer(KnowledgeBase(), ContextDetector(), PerfModel(),
                           registry=reg)
    assert an.offload_target() == "remote"
    reg.set_status("remote", "failed")
    assert an.offload_target() == "local"
    nb = Notebook("nb")
    cell = nb.add_cell("x = 1", cost=1.0)
    d = an.decide(nb, cell, current_env="local")
    assert d.env == "local" and not d.migrate
