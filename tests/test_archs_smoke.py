"""Per-arch reduced-config smoke tests: one real train step on CPU with
shape + finiteness asserts (the FULL configs are exercised only abstractly
by the dry-run)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, TrainConfig, get_config
from repro.data import TokenPipeline
from repro.configs.base import ShapeConfig
from repro.models import LM
from repro.optim import adamw_update, init_opt_state

B, S = 2, 32


def _batch(cfg, seed=0):
    pipe = TokenPipeline(cfg, ShapeConfig("smoke", "train", S, B), seed=seed)
    hb = pipe.train_batch(0)
    return {k: jnp.asarray(v) for k, v in hb.items()}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, reduced=True)
    lm = LM(cfg, max_seq=S + 1)
    params = lm.init(jax.random.PRNGKey(0))
    tc = TrainConfig(total_steps=10, warmup_steps=2, schedule=cfg.schedule)
    opt = init_opt_state(params)
    batch = _batch(cfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, m), grads = jax.value_and_grad(lm.loss, has_aux=True)(params, batch)
        opt2, params2, om = adamw_update(tc, opt, grads, params)
        return params2, opt2, loss, om["grad_norm"]

    p2, o2, loss, gnorm = step(params, opt, batch)
    assert jnp.isfinite(loss), arch
    assert jnp.isfinite(gnorm) and float(gnorm) > 0, arch
    # params changed, structure/shapes preserved
    same = jax.tree_util.tree_map(lambda a, b: a.shape == b.shape, params, p2)
    assert all(jax.tree_util.tree_leaves(same))
    changed = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.any(a != b)), params, p2)
    assert any(jax.tree_util.tree_leaves(changed)), arch
    # loss near ln(vocab) at random init
    assert 0.5 * jnp.log(cfg.vocab_size) < loss < 2.0 * jnp.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-370m", "recurrentgemma-9b",
                                  "whisper-tiny", "qwen3-moe-235b-a22b",
                                  "internvl2-2b"])
def test_forward_output_shape(arch):
    cfg = get_config(arch, reduced=True)
    lm = LM(cfg, max_seq=S)
    params = lm.init(jax.random.PRNGKey(1))
    batch = _batch(cfg)
    batch["tokens"] = batch["tokens"][:, :S]
    logits, aux, _ = lm.forward(params, batch)
    S_tot = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_tot, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
