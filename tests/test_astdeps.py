"""AST dependency analysis (paper §II-D)."""
import numpy as np

from repro.core.astdeps import analyze_cell, cell_dependencies


def test_loads_stores_kwargs():
    info = analyze_cell("y = model.fit(x, epochs=10, batch_size=32)\nz = y + w")
    assert {"model", "x", "w"} <= info.loads
    assert {"y", "z"} <= info.stores
    assert info.call_kwargs["model.fit"] == {"epochs": 10, "batch_size": 32}


def test_imports_tracked():
    info = analyze_cell("import numpy as np\nfrom os import path")
    assert "numpy" in info.imports and "os" in info.imports
    assert "np" in info.stores


def test_closure_pulls_function_globals():
    ns = {}
    exec("""
import math
scale = 2.0
offset = 1.0
unused = list(range(100))
def inner(v):
    return v * scale
def outer(v):
    return inner(v) + offset
""", ns)
    needed, modules, _ = cell_dependencies("r = outer(3.0)", ns)
    assert {"outer", "inner", "scale", "offset"} <= needed
    assert "unused" not in needed
    assert "math" not in needed  # module: re-imported, not serialized


def test_module_use_recorded():
    ns = {}
    exec("import numpy as np\nx = np.arange(4)", ns)
    needed, modules, _ = cell_dependencies("y = np.sum(x)", ns)
    assert "x" in needed and "np" not in needed
    assert "numpy" in modules


def test_container_values_captured_via_name():
    ns = {"items": [np.zeros(4), np.ones(4)], "k": 3}
    needed, _, _ = cell_dependencies("total = sum(x.sum() for x in items) + k", ns)
    assert {"items", "k"} <= needed


def test_runtime_analysis_ignores_untaken_names():
    # only names that resolve in the live namespace become dependencies
    ns = {"a": 1}
    needed, _, _ = cell_dependencies("b = a + undefined_later", ns)
    assert needed == {"a"}
