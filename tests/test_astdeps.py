"""AST dependency analysis (paper §II-D)."""
import numpy as np

from repro.core.astdeps import analyze_cell, cell_dependencies


def test_loads_stores_kwargs():
    info = analyze_cell("y = model.fit(x, epochs=10, batch_size=32)\nz = y + w")
    assert {"model", "x", "w"} <= info.loads
    assert {"y", "z"} <= info.stores
    assert info.call_kwargs["model.fit"] == {"epochs": 10, "batch_size": 32}


def test_imports_tracked():
    info = analyze_cell("import numpy as np\nfrom os import path")
    assert "numpy" in info.imports and "os" in info.imports
    assert "np" in info.stores


def test_closure_pulls_function_globals():
    ns = {}
    exec("""
import math
scale = 2.0
offset = 1.0
unused = list(range(100))
def inner(v):
    return v * scale
def outer(v):
    return inner(v) + offset
""", ns)
    needed, modules, _ = cell_dependencies("r = outer(3.0)", ns)
    assert {"outer", "inner", "scale", "offset"} <= needed
    assert "unused" not in needed
    assert "math" not in needed  # module: re-imported, not serialized


def test_module_use_recorded():
    ns = {}
    exec("import numpy as np\nx = np.arange(4)", ns)
    needed, modules, _ = cell_dependencies("y = np.sum(x)", ns)
    assert "x" in needed and "np" not in needed
    assert "numpy" in modules


def test_container_values_captured_via_name():
    ns = {"items": [np.zeros(4), np.ones(4)], "k": 3}
    needed, _, _ = cell_dependencies("total = sum(x.sum() for x in items) + k", ns)
    assert {"items", "k"} <= needed


def test_runtime_analysis_ignores_untaken_names():
    # only names that resolve in the live namespace become dependencies
    ns = {"a": 1}
    needed, _, _ = cell_dependencies("b = a + undefined_later", ns)
    assert needed == {"a"}


# -- liveness over the remaining plan (replication pruning) -------------

from repro.core.astdeps import live_names, live_roots  # noqa: E402


def _exec_cells(ns, cells):
    g = dict(ns)
    g.setdefault("__builtins__", __builtins__)
    for src in cells:
        exec(src, g)
    g.pop("__builtins__", None)
    return g


def _assert_pruned_bit_identical(setup: str, remaining: list[str],
                                 expect_dead: set[str] = frozenset()):
    """Prune the namespace to the live set, run the remaining cells from
    both the full and the pruned namespace, and require every surviving
    value to be bit-identical."""
    full = {}
    exec(setup, full)
    full.pop("__builtins__", None)
    live = live_names(remaining, full)
    assert live is not None, "analysis unexpectedly conservative"
    assert expect_dead.isdisjoint(live)
    pruned = {k: v for k, v in full.items() if k in live}
    out_full = _exec_cells(full, remaining)
    out_pruned = _exec_cells(pruned, remaining)
    for k, v in out_pruned.items():
        ref = out_full[k]
        if isinstance(v, np.ndarray):
            assert v.tobytes() == ref.tobytes() and v.dtype == ref.dtype
        elif not callable(v) and not isinstance(v, type(np)):
            assert v == ref
    return live


def test_liveness_augmented_assignment_keeps_target_live():
    live = _assert_pruned_bit_identical(
        "x = 10\ndead = list(range(1000))",
        ["x += 5", "r = x * 2"],
        expect_dead={"dead"})
    assert "x" in live                    # += reads the old binding


def test_liveness_del_needs_binding_then_kills():
    # ``del tmp`` needs tmp bound (a use), and no later cell may read it
    live = _assert_pruned_bit_identical(
        "tmp = [1, 2, 3]\nkeep = 7",
        ["del tmp", "r = keep + 1"])
    assert "tmp" in live
    # a name rebound before any read is dead at entry
    live2 = live_roots(["x = 5", "y = x + 1"]).live
    assert "x" not in live2 and "y" not in live2


def test_liveness_comprehension_scoping():
    # the comprehension-local ``i`` must not keep an outer ``i`` alive,
    # but names read inside the element/filter expressions must
    live = _assert_pruned_bit_identical(
        "i = 999\nscale = 2.0\nn = 5\ndead = 'x' * 100",
        ["r = [j * scale for j in range(n)]"],
        expect_dead={"dead", "i"})
    assert {"scale", "n"} <= live and "i" not in live
    # first iterable evaluates in the enclosing scope: [x for x in x]
    assert "x" in live_roots(["r = [x for x in x]"]).live


def test_liveness_global_declaration_inside_function():
    live = _assert_pruned_bit_identical(
        "counter = 41\ndead = bytearray(100)",
        ["def bump():\n"
         "    global counter\n"
         "    counter += 1\n",
         "bump()",
         "r = counter"],
        expect_dead={"dead"})
    assert "counter" in live


def test_liveness_attribute_mutation_is_not_a_kill():
    # ``obj.field = v`` mutates, it does not rebind: obj stays live
    live = _assert_pruned_bit_identical(
        "import types\nobj = types.SimpleNamespace(field=1)\ndead = [0] * 50",
        ["obj.field = 2", "r = obj.field * 10"],
        expect_dead={"dead"})
    assert "obj" in live


def test_liveness_subscript_assignment_is_not_a_kill():
    live = _assert_pruned_bit_identical(
        "import numpy as np\narr = np.zeros(4)\ndead = np.ones(1000)",
        ["arr[0] = 5.0", "r = float(arr.sum())"],
        expect_dead={"dead"})
    assert "arr" in live


def test_liveness_conservative_on_dynamic_constructs():
    # exec / globals() / star-imports defeat static liveness: callers get
    # None and must treat every name as live
    assert live_names(["exec('r = q')"], {"q": 1}) is None
    assert live_names(["r = globals()['q']"], {"q": 1}) is None
    assert live_names(["from os.path import *", "r = 1"], {"q": 1}) is None
    assert live_names(["r = q ++"], {"q": 1}) is None   # unparseable
    res = live_roots(["exec('x = 1')"])
    assert res.conservative and res.reason


def test_liveness_conditional_assignment_is_not_a_kill():
    # an assignment under ``if`` may never run: the prior binding lives
    live = _assert_pruned_bit_identical(
        "flag = False\nv = 3",
        ["if flag:\n    v = 99\n", "r = v"])
    assert "v" in live


def test_liveness_function_pins_closure_globals():
    # a live function keeps the globals it reads via dependency_closure
    live = _assert_pruned_bit_identical(
        "gain = 4.0\n"
        "def amp(x):\n"
        "    return x * gain\n"
        "dead = list(range(200))",
        ["r = amp(2.5)"],
        expect_dead={"dead"})
    assert {"amp", "gain"} <= live
