"""The CI benchmark-regression gate must demonstrably fail on an injected
regression — and only then."""
import json
import os

import pytest

from benchmarks.check_regression import (
    check_all, check_file, lookup, main, update_baselines,
)


def _write(path, doc):
    with open(path, "w") as f:
        json.dump(doc, f)


@pytest.fixture
def rig(tmp_path):
    base = tmp_path / "baselines"
    cur = tmp_path / "current"
    base.mkdir()
    cur.mkdir()
    _write(base / "tolerances.json", {
        "BENCH_x.json": [
            {"metric": "w.bytes", "cmp": "max", "tol": 0.10},
            {"metric": "w.ratio", "cmp": "min", "tol": 0.10},
        ]})
    _write(base / "BENCH_x.json", {"w": {"bytes": 1000, "ratio": 8.0}})
    return str(base), str(cur)


def test_within_tolerance_passes(rig):
    base, cur = rig
    _write(os.path.join(cur, "BENCH_x.json"),
           {"w": {"bytes": 1099, "ratio": 7.3}})
    assert check_all(base, cur) == []
    assert main(["--baselines", base, "--current", cur]) == 0


def test_injected_regression_fails_the_gate(rig, capsys):
    base, cur = rig
    # bytes ballooned 3x and the ratio collapsed: both rules must fire
    _write(os.path.join(cur, "BENCH_x.json"),
           {"w": {"bytes": 3000, "ratio": 2.0}})
    failures = check_all(base, cur)
    assert len(failures) == 2
    assert all("REGRESSION" in f for f in failures)
    assert main(["--baselines", base, "--current", cur]) == 1
    assert "REGRESSION" in capsys.readouterr().err


def test_direction_matters(rig):
    base, cur = rig
    # improvements never fail: fewer bytes, higher ratio
    _write(os.path.join(cur, "BENCH_x.json"),
           {"w": {"bytes": 10, "ratio": 80.0}})
    assert check_all(base, cur) == []


def test_missing_fresh_report_fails(rig):
    base, cur = rig
    failures = check_all(base, cur)
    assert len(failures) == 1 and "missing" in failures[0]


def test_metric_that_stopped_being_emitted_fails(rig):
    base, cur = rig
    _write(os.path.join(cur, "BENCH_x.json"), {"w": {"bytes": 900}})
    failures = check_all(base, cur)
    assert any("ratio" in f and "fresh report" in f for f in failures)


def test_lookup_handles_list_indices_and_rejects_non_numbers():
    doc = {"a": [{"b": 2.5}], "s": "nope"}
    assert lookup(doc, "a.0.b") == 2.5
    with pytest.raises(KeyError):
        lookup(doc, "a.1.b")
    with pytest.raises(KeyError):
        lookup(doc, "a.x")
    with pytest.raises(TypeError):
        lookup(doc, "s")


def test_check_file_reports_unknown_cmp():
    fails = check_file([{"metric": "m", "cmp": "exact", "tol": 0}],
                       {"m": 1}, {"m": 1}, "f.json")
    assert fails and "unknown cmp" in fails[0]


def test_update_rewrites_baselines_from_current(rig):
    base, cur = rig
    _write(os.path.join(cur, "BENCH_x.json"),
           {"w": {"bytes": 500, "ratio": 16.0}})
    update_baselines(base, cur)
    with open(os.path.join(base, "BENCH_x.json")) as f:
        assert json.load(f)["w"]["bytes"] == 500
    assert check_all(base, cur) == []


def test_repo_tolerances_are_well_formed():
    """Every committed rule parses and points at a committed baseline."""
    from benchmarks.check_regression import DEFAULT_BASELINES
    with open(os.path.join(DEFAULT_BASELINES, "tolerances.json")) as f:
        spec = json.load(f)
    assert spec, "tolerances.json must gate at least one report"
    for fname, rules in spec.items():
        base = os.path.join(DEFAULT_BASELINES, fname)
        assert os.path.exists(base), f"no committed baseline for {fname}"
        with open(base) as f:
            doc = json.load(f)
        for rule in rules:
            assert rule["cmp"] in ("max", "min")
            lookup(doc, rule["metric"])      # raises if the path is dead
