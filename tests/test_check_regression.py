"""The CI benchmark-regression gate must demonstrably fail on an injected
regression — and only then."""
import json
import os

import pytest

from benchmarks.check_regression import (
    check_all, check_file, lookup, main, render_summary, update_baselines,
)


def _write(path, doc):
    with open(path, "w") as f:
        json.dump(doc, f)


@pytest.fixture
def rig(tmp_path):
    base = tmp_path / "baselines"
    cur = tmp_path / "current"
    base.mkdir()
    cur.mkdir()
    _write(base / "tolerances.json", {
        "BENCH_x.json": [
            {"metric": "w.bytes", "cmp": "max", "tol": 0.10},
            {"metric": "w.ratio", "cmp": "min", "tol": 0.10},
        ]})
    _write(base / "BENCH_x.json", {"w": {"bytes": 1000, "ratio": 8.0}})
    return str(base), str(cur)


def test_within_tolerance_passes(rig):
    base, cur = rig
    _write(os.path.join(cur, "BENCH_x.json"),
           {"w": {"bytes": 1099, "ratio": 7.3}})
    assert check_all(base, cur) == []
    assert main(["--baselines", base, "--current", cur]) == 0


def test_injected_regression_fails_the_gate(rig, capsys):
    base, cur = rig
    # bytes ballooned 3x and the ratio collapsed: both rules must fire
    _write(os.path.join(cur, "BENCH_x.json"),
           {"w": {"bytes": 3000, "ratio": 2.0}})
    failures = check_all(base, cur)
    assert len(failures) == 2
    assert all("REGRESSION" in f for f in failures)
    assert main(["--baselines", base, "--current", cur]) == 1
    assert "REGRESSION" in capsys.readouterr().err


def test_direction_matters(rig):
    base, cur = rig
    # improvements never fail: fewer bytes, higher ratio
    _write(os.path.join(cur, "BENCH_x.json"),
           {"w": {"bytes": 10, "ratio": 80.0}})
    assert check_all(base, cur) == []


def test_missing_fresh_report_fails(rig):
    base, cur = rig
    failures = check_all(base, cur)
    assert len(failures) == 1 and "missing" in failures[0]


def test_metric_that_stopped_being_emitted_fails(rig):
    base, cur = rig
    _write(os.path.join(cur, "BENCH_x.json"), {"w": {"bytes": 900}})
    failures = check_all(base, cur)
    assert any("ratio" in f and "fresh report" in f for f in failures)


def test_lookup_handles_list_indices_and_rejects_non_numbers():
    doc = {"a": [{"b": 2.5}], "s": "nope"}
    assert lookup(doc, "a.0.b") == 2.5
    with pytest.raises(KeyError):
        lookup(doc, "a.1.b")
    with pytest.raises(KeyError):
        lookup(doc, "a.x")
    with pytest.raises(TypeError):
        lookup(doc, "s")


def test_check_file_reports_unknown_cmp():
    fails = check_file([{"metric": "m", "cmp": "exact", "tol": 0}],
                       {"m": 1}, {"m": 1}, "f.json")
    assert fails and "unknown cmp" in fails[0]


def test_update_rewrites_baselines_from_current(rig):
    base, cur = rig
    _write(os.path.join(cur, "BENCH_x.json"),
           {"w": {"bytes": 500, "ratio": 16.0}})
    update_baselines(base, cur)
    with open(os.path.join(base, "BENCH_x.json")) as f:
        assert json.load(f)["w"]["bytes"] == 500
    assert check_all(base, cur) == []


def test_summary_rows_and_markdown_rendering(rig):
    base, cur = rig
    # bytes pass, ratio regresses: the table must carry one PASS row with
    # both numbers and one FAIL row
    _write(os.path.join(cur, "BENCH_x.json"),
           {"w": {"bytes": 900, "ratio": 2.0}})
    rows = []
    failures = check_all(base, cur, rows=rows)
    assert len(failures) == 1
    assert [r["ok"] for r in rows] == [True, False]
    assert rows[0] == {"file": "BENCH_x.json", "metric": "w.bytes",
                       "cmp": "max", "tol": 0.10,
                       "baseline": 1000.0, "observed": 900.0, "ok": True}
    md = render_summary(rows, failures)
    assert "| metric | baseline | observed | tolerance | verdict |" in md
    assert "| BENCH_x.json:w.bytes | 1000 | 900 | +10% (max) | PASS |" in md
    assert "| BENCH_x.json:w.ratio | 8 | 2 | -10% (min) | **FAIL** |" in md
    assert "1 failure(s)" in md


def test_summary_marks_missing_metrics_without_numbers(rig):
    base, cur = rig
    _write(os.path.join(cur, "BENCH_x.json"), {"w": {"bytes": 900}})
    rows = []
    failures = check_all(base, cur, rows=rows)
    md = render_summary(rows, failures)
    # the missing metric renders a dash for the observed value and the
    # spec-level failure line follows the table as a bullet
    assert "| BENCH_x.json:w.ratio | 8 | — |" in md
    assert "- BENCH_x.json:w.ratio: missing in fresh report" in md


def test_all_green_summary_says_so(rig):
    base, cur = rig
    _write(os.path.join(cur, "BENCH_x.json"),
           {"w": {"bytes": 1000, "ratio": 8.0}})
    rows = []
    md = render_summary(rows := [], check_all(base, cur, rows=rows))
    assert "All metrics within tolerance." in md
    assert "FAIL" not in md


def test_main_appends_summary_when_env_set(rig, tmp_path, monkeypatch):
    base, cur = rig
    _write(os.path.join(cur, "BENCH_x.json"),
           {"w": {"bytes": 3000, "ratio": 8.0}})
    summary = tmp_path / "step_summary.md"
    summary.write_text("# earlier step\n")
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    assert main(["--baselines", base, "--current", cur]) == 1
    text = summary.read_text()
    assert text.startswith("# earlier step\n")          # appended, not clobbered
    assert "## Benchmark regression gate" in text
    assert "**FAIL**" in text and "PASS" in text


def test_main_skips_summary_when_env_unset(rig, monkeypatch):
    base, cur = rig
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
    _write(os.path.join(cur, "BENCH_x.json"),
           {"w": {"bytes": 1000, "ratio": 8.0}})
    assert main(["--baselines", base, "--current", cur]) == 0


def test_repo_tolerances_are_well_formed():
    """Every committed rule parses and points at a committed baseline."""
    from benchmarks.check_regression import DEFAULT_BASELINES
    with open(os.path.join(DEFAULT_BASELINES, "tolerances.json")) as f:
        spec = json.load(f)
    assert spec, "tolerances.json must gate at least one report"
    for fname, rules in spec.items():
        base = os.path.join(DEFAULT_BASELINES, fname)
        assert os.path.exists(base), f"no committed baseline for {fname}"
        with open(base) as f:
            doc = json.load(f)
        for rule in rules:
            assert rule["cmp"] in ("max", "min")
            lookup(doc, rule["metric"])      # raises if the path is dead
