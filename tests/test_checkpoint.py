"""Delta checkpointing + restart."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, Checkpointer


def _tree(x=0.0):
    return {"params": {"w": jnp.arange(100, dtype=jnp.float32) + x,
                       "frozen": jnp.ones((50,), jnp.float32)},
            "meta": {"step": np.int64(3)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(1, {"state": t})
    out, step = ck.restore({"state": t})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(out["state"]["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_delta_skips_unchanged_leaves(tmp_path):
    ck = Checkpointer(str(tmp_path))
    i1 = ck.save(1, {"state": _tree(0.0)})
    assert i1.n_leaves_written == i1.n_leaves_total
    i2 = ck.save(2, {"state": _tree(1.0)})   # only "w" changed
    assert i2.n_leaves_written < i2.n_leaves_total
    out, step = ck.restore({"state": _tree()})
    assert step == 2
    np.testing.assert_array_equal(np.asarray(out["state"]["params"]["w"]),
                                  np.arange(100, dtype=np.float32) + 1.0)
    np.testing.assert_array_equal(np.asarray(out["state"]["params"]["frozen"]),
                                  np.ones(50, np.float32))


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"state": _tree(0.0)})
    ck.save(2, {"state": _tree(5.0)})
    out, step = ck.restore({"state": _tree()}, step=1)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(out["state"]["params"]["w"]),
                                  np.arange(100, dtype=np.float32))


def test_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"state": _tree()})
    blob = [f for f in os.listdir(tmp_path) if f.endswith(".bin")][0]
    p = os.path.join(tmp_path, blob)
    data = bytearray(open(p, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(p, "wb").write(bytes(data))
    with pytest.raises(Exception):
        ck.restore({"state": _tree()})


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(Checkpointer(str(tmp_path)))
    ck.save(1, {"state": _tree()})
    ck.wait()
    assert ck.last_info is not None and ck.last_info.step == 1
    out, step = ck.inner.restore({"state": _tree()})
    assert step == 1


def test_gc_rebase_chain(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, rebase_every=5)
    for s in range(1, 7):
        ck.save(s, {"state": _tree(float(s))})
    steps = ck._steps()
    # save #6 is a FULL rebase -> everything older is GC-safe to drop
    assert steps[-1] == 6
    assert ck._manifest(6)["full"]
    out, step = ck.restore({"state": _tree()})
    assert step == 6
    np.testing.assert_array_equal(np.asarray(out["state"]["params"]["w"]),
                                  np.arange(100, dtype=np.float32) + 6.0)


def test_tombstone_through_storage_checkpoint_cycle(tmp_path):
    """A leaf dropped between saves is a tombstone on the storage env: the
    next manifest records it deleted, the storage namespace drops it, and a
    restore of the later step never resurrects it."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"state": {"a": np.arange(10.0), "b": np.ones(5)}})
    info = ck.save(2, {"state": {"a": np.arange(10.0) + 1.0}})
    assert info.n_leaves_total == 1
    m2 = ck._manifest(2)
    dead = [n for n in m2["deleted"] if n.startswith("state/")]
    assert len(dead) == 1                      # the vanished "b" leaf
    assert dead[0] not in m2["names"] and dead[0] not in m2["digests"]
    # storage envs are manifest + CAS only — no leaf is ever materialized
    # into the namespace, deleted or otherwise
    assert dead[0] not in ck.storage.state.ns
    assert not ck.storage.state.ns
    out, step = ck.restore({"state": {"a": np.arange(10.0)}})
    assert step == 2
    np.testing.assert_array_equal(np.asarray(out["state"]["a"]),
                                  np.arange(10.0) + 1.0)
    # the earlier step still restores the full structure from its manifest
    out1, step1 = ck.restore({"state": {"a": np.arange(10.0),
                                        "b": np.ones(5)}}, step=1)
    assert step1 == 1
    np.testing.assert_array_equal(np.asarray(out1["state"]["b"]), np.ones(5))


def test_checkpoint_chunk_delta_reships_only_changed_chunks(tmp_path):
    """A 1-element update to a large leaf writes ~one chunk, not the leaf."""
    ck = Checkpointer(str(tmp_path), codec="zstd", chunk_bytes=16 << 10)
    big = np.arange(1 << 18, dtype=np.float32)          # 1 MiB, 64 chunks
    i1 = ck.save(1, {"state": {"big": big}})
    big2 = big.copy()
    big2[3] += 1.0
    i2 = ck.save(2, {"state": {"big": big2}})
    assert i2.n_leaves_written == 1                     # leaf digest changed
    assert i2.nbytes < i1.nbytes / 10                   # but ~1 chunk moved
    out, step = ck.restore({"state": {"big": big}})
    assert step == 2
    np.testing.assert_array_equal(np.asarray(out["state"]["big"]), big2)


def test_restart_mid_chain(tmp_path):
    ck = Checkpointer(str(tmp_path), rebase_every=10)
    for s in range(1, 5):
        ck.save(s, {"state": _tree(float(s))})
    # fresh process: new Checkpointer over the same dir
    ck2 = Checkpointer(str(tmp_path), rebase_every=10)
    out, step = ck2.restore({"state": _tree()})
    assert step == 4
    np.testing.assert_array_equal(np.asarray(out["state"]["params"]["w"]),
                                  np.arange(100, dtype=np.float32) + 4.0)
